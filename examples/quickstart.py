"""Quickstart: the two-line Parallax API (paper Table 2) on a tiny LM.

    PYTHONPATH=src python examples/quickstart.py
"""
import repro
from repro.configs import RunConfig, ShapeConfig

# 1. a single-device model config (any assigned arch; reduced for CPU)
cfg = repro.reduced(repro.get_config("phi3-medium-14b"))
shape = ShapeConfig("quickstart", seq_len=64, global_batch=4, kind="train")

# 2. data, with the paper's shard() API
ds = repro.shard(repro.SyntheticLM(cfg.vocab_size, shape.seq_len,
                                   shape.global_batch),
                 replica_id=0, num_replicas=1)

# 3. get_runner transforms the single-device step into the distributed one
#    (on this CPU box there's one device; pass mesh=make_production_mesh()
#    on a pod — the model code is identical)
runner = repro.get_runner(cfg, shape,
                          RunConfig(attention_impl="naive", remat="none",
                                    learning_rate=3e-3))

print(f"comm plan: {runner.plan.methods()}  "
      f"(sparse α={runner.plan.alpha:.3f}, embed via "
      f"{runner.plan.embed_method})")
for step in range(20):
    metrics = runner.run(ds.batch(step))
    if step % 5 == 0:
        print(f"step {step:3d}  loss {float(metrics['loss']):.4f}")
print("done — loss should have dropped by ~0.5 from step 0")
