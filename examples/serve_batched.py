"""Batched serving example: the continuous-batching engine — one jitted
prefill per admission (cached per prompt-length bucket), slot-paged decode
with device-side sampling, detokenization off the critical path.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs import RunConfig, get_config, reduced
from repro.runtime.server import Request, Server, ServerConfig

cfg = reduced(get_config("phi3-medium-14b"))
server = Server(cfg, RunConfig(attention_impl="naive"),
                ServerConfig(max_batch=4, max_seq=128))
rng = np.random.default_rng(0)
for i in range(12):
    server.submit(Request(
        uid=i, prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(3, 9)),
                                   dtype=np.int32),
        max_new_tokens=16))

t0 = time.time()
done = server.run_until_drained()
dt = time.time() - t0
toks = sum(len(r.out_tokens) for r in done)
print(f"served {len(done)} requests / {toks} tokens in {dt:.1f}s "
      f"({toks/dt:.1f} tok/s, batch={server.scfg.max_batch})")
print(f"  {server.stats['prefill_calls']} prefill dispatches over buckets "
      f"{sorted(server.stats['buckets'])} "
      f"({server.stats['prefill_traces']} traces), "
      f"{server.stats['decode_steps']} decode steps")
for r in done[:3]:
    print(f"  req {r.uid}: {len(r.prompt)}-token prompt -> "
          f"{r.out_tokens[:8]}... (TTFT {r.ttft*1e3:.0f} ms)")
server.close()
