"""The paper's headline result, reproduced on fake devices: compare the
bytes-on-wire of PS / MPI / hybrid communication for a sparse LM, straight
from the compiled HLO.

    PYTHONPATH=src python examples/hybrid_comm_demo.py
"""
import json
import os
import subprocess
import sys
import textwrap

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import jax
from repro.configs import RunConfig
from repro.launch.dryrun import run_cell

out = {}
for mode in ("ps", "mpi", "hybrid"):
    res = run_cell("parallax-lm", "train_4k", multi_pod=False,
                   run_cfg=RunConfig(comm_mode=mode, capacity_mode="capped",
                                     remat="full"),
                   verbose=False)
    r = res["roofline"]
    out[mode] = {"collective_GB": r["per_chip_collective_bytes"] / 1e9,
                 "bound_ms": max(r["compute_s"], r["memory_s"],
                                 r["collective_s"]) * 1e3}
    jax.clear_caches()
print("RESULT:" + json.dumps(out))
"""

env = dict(os.environ)
env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
proc = subprocess.run([sys.executable, "-c", textwrap.dedent(CODE)],
                      capture_output=True, text=True, env=env, timeout=900)
if proc.returncode != 0:
    sys.exit(f"failed: {proc.stderr[-2000:]}")
res = json.loads([l for l in proc.stdout.splitlines()
                  if l.startswith("RESULT:")][0][len("RESULT:"):])
print("paper's LM (800k vocab, 1-layer LSTM) on the 16x16 mesh, train_4k:")
for mode, d in res.items():
    print(f"  {mode:7s}: {d['collective_GB']:8.2f} GB/chip on the wire, "
          f"roofline-bound step {d['bound_ms']:.0f} ms")
hyb, mpi = res["hybrid"]["bound_ms"], res["mpi"]["bound_ms"]
print(f"hybrid vs MPI bound speedup: {mpi/hyb:.2f}x "
      f"(paper Fig 12(c): PS-family beats MPI on sparse models)")
