"""End-to-end training driver (deliverable b): train a language model for a
few hundred steps with checkpointing, resume, and throughput accounting.

Default is a ~10M-parameter phi3-family model sized for this CPU container;
``--size 100m`` selects a ~100M model (same code path — on TPU hardware this
is the config you'd launch).

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import RunConfig, ShapeConfig, get_config
from repro.data import SyntheticLM
from repro.runtime.trainer import Trainer, TrainerConfig

SIZES = {
    # layers, d_model, heads, kv, d_ff, vocab  (~params)
    "10m": (4, 256, 8, 4, 1024, 8192),
    "100m": (12, 768, 12, 4, 3072, 32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="10m", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--zipf-a", type=float, default=1.3,
                    help="token skew (natural-text-like embedding sparsity)")
    ap.add_argument("--bucket-bytes", type=int, default=4 * 1024 * 1024,
                    help="fused dense-gradient bucket size; 0 = per-tensor")
    ap.add_argument("--replan-every", type=int, default=0,
                    help="profile->replan period in steps (0 = static plan)")
    args = ap.parse_args()

    L, d, h, kv, f, v = SIZES[args.size]
    cfg = dataclasses.replace(
        get_config("phi3-medium-14b"), name=f"lm-{args.size}",
        n_layers=L, d_model=d, n_heads=h, n_kv_heads=kv, d_ff=f,
        vocab_size=v, head_dim=d // h)
    print(f"model: {cfg.param_count()/1e6:.1f}M params")
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    rc = RunConfig(attention_impl="chunked", attention_chunk=128,
                   remat="none", learning_rate=1e-3,
                   capacity_mode="capped" if args.replan_every else "exact",
                   capacity_factor=1.5, bucket_bytes=args.bucket_bytes)
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                     zipf_a=args.zipf_a)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=100, log_every=20,
                         replan_every=args.replan_every)
    trainer = Trainer(cfg, shape, rc, tcfg, ds)
    if args.resume:
        trainer.maybe_restore()
        print(f"resumed at step {trainer.step}")

    losses = []

    def on_metrics(step, m):
        losses.append(m.get("loss"))
        if step % 20 == 0:
            extra = ""
            if "observed_alpha" in m:
                extra = (f"  alpha {m['observed_alpha']:.4f}"
                         f"  replans {int(m.get('replans', 0))}")
            print(f"step {step:4d}  loss {m['loss']:.4f}  "
                  f"{m['tokens_per_s']:.0f} tok/s  "
                  f"step_time {m['step_time_s']*1e3:.0f} ms{extra}")

    trainer.run(on_metrics=on_metrics)
    if trainer.ckpt:
        trainer.ckpt.wait()
    if trainer.monitor.replans:
        print(f"adaptive replans: {trainer.monitor.replans}  "
              f"(plan alpha {trainer.plan.alpha:.4f}, "
              f"capacity {trainer.plan.capacity})")
        for t, e in sorted(trainer.plan.tables().items()):
            print(f"  table {t}: method={e['method']} "
                  f"capacity={e['capacity']} wire={e['wire_dtype']}"
                  + ("  [overflow-grown]" if e["grown"] else ""))
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
