"""Serving engine benchmark: batched prefill vs the teacher-forced toy loop.

Drives the rebuilt engine (``runtime.server.Server`` — one jitted prefill
dispatch per admission, slot-paged decode with device-side sampling) and the
pre-engine baseline (``ToyServer`` — token-at-a-time teacher-forced prefill
through the shared decode step, host argmax) over the same mixed-length
workload at three offered loads, and reports per load:

  * decode throughput (generated tokens / wall-clock drain time);
  * TTFT p50/p99 (submit -> first generated token materialized);
  * per-token decode latency p50/p99 (gaps between materialized tokens);
  * engine hygiene: prefill calls == requests (one dispatch per admission),
    prefill traces == distinct length buckets, cross-slot mismatches == 0.

Everything lands in ``BENCH_serve.json`` next to the repo root.

    PYTHONPATH=src python -m benchmarks.serve_bench
"""
from __future__ import annotations

import json
import math
import os
import time

import numpy as np

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

LOADS = (2, 6, 12)          # offered load: requests per burst
MAX_NEW = 16
PROMPT_LENS = (5, 11, 23, 37)   # spans buckets 8/16/32/64


def _workload(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 200, size=PROMPT_LENS[i % len(PROMPT_LENS)])
            .astype(np.int32) for i in range(n)]


def _percentiles(xs):
    if not xs:
        return {"p50": float("inf"), "p99": float("inf")}
    return {"p50": float(np.percentile(xs, 50)),
            "p99": float(np.percentile(xs, 99))}


def _drive(server, prompts):
    from repro.runtime.server import Request
    reqs = [Request(i, p, max_new_tokens=MAX_NEW)
            for i, p in enumerate(prompts)]
    t0 = time.perf_counter()
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    wall = time.perf_counter() - t0
    undone = [r.uid for r in reqs if not r.done]
    assert not undone, f"requests never completed: {undone}"
    toks = sum(len(r.out_tokens) for r in reqs)
    ttft = [r.ttft for r in reqs]
    gaps = [b - a for r in reqs
            for a, b in zip(r.token_times, r.token_times[1:])]
    return {"requests": len(reqs), "tokens": toks, "wall_s": wall,
            "tok_per_s": toks / wall, "ttft_s": _percentiles(ttft),
            "per_token_s": _percentiles(gaps)}


def main():
    from repro.configs import RunConfig, get_config, reduced
    from repro.runtime.server import Server, ServerConfig, ToyServer

    cfg = reduced(get_config("phi3-medium-14b"), layers=2, vocab=512)
    rc = RunConfig(attention_impl="naive")
    scfg = ServerConfig(max_batch=4, max_seq=128)

    engine = Server(cfg, rc, scfg, seed=0)
    params = engine.params

    # warm every length bucket + the decode step so per-load numbers are
    # steady-state (first-trace compile otherwise dominates TTFT)
    _drive(engine, _workload(len(PROMPT_LENS), seed=123))

    by_load = {}
    for n in LOADS:
        calls0 = engine.stats["prefill_calls"]
        r = _drive(engine, _workload(n))
        r["prefill_calls"] = engine.stats["prefill_calls"] - calls0
        by_load[n] = r
        print(f"engine load={n:3d}: {r['tok_per_s']:8.1f} tok/s, "
              f"TTFT p50 {r['ttft_s']['p50'] * 1e3:6.1f} ms / p99 "
              f"{r['ttft_s']['p99'] * 1e3:6.1f} ms, per-token p50 "
              f"{r['per_token_s']['p50'] * 1e3:5.1f} ms")
    engine.close()

    toy = ToyServer(cfg, rc, scfg, params=params, seed=0)
    _drive(toy, _workload(2, seed=123))          # same courtesy warmup
    toy_res = _drive(toy, _workload(max(LOADS)))
    print(f"toy    load={max(LOADS):3d}: {toy_res['tok_per_s']:8.1f} tok/s, "
          f"TTFT p50 {toy_res['ttft_s']['p50'] * 1e3:6.1f} ms "
          f"(teacher-forced prefill, host argmax)")

    top = by_load[max(LOADS)]
    speedup = top["tok_per_s"] / toy_res["tok_per_s"]
    measured_calls = sum(r["prefill_calls"] for r in by_load.values())
    print(f"engine vs toy at load {max(LOADS)}: {speedup:.2f}x tok/s, "
          f"{measured_calls} prefill calls for {sum(LOADS)} requests "
          f"({engine.stats['prefill_traces']} traces over buckets "
          f"{sorted(engine.stats['buckets'])})")

    # CI smoke contract
    for n, r in by_load.items():
        assert r["prefill_calls"] == n, \
            f"load {n}: {r['prefill_calls']} prefill dispatches (want one " \
            "per admitted request)"
    assert math.isfinite(top["ttft_s"]["p99"]), \
        "p99 TTFT not finite at the highest offered load"
    assert top["tok_per_s"] > toy_res["tok_per_s"], \
        "rebuilt engine slower than the teacher-forced toy loop"
    assert engine.stats["cross_slot_mismatches"] == 0, \
        "slot-paged decode leaked tokens across slots"
    assert engine.stats["prefill_traces"] == len(engine.stats["buckets"]), \
        "prefill retraced inside a length bucket"

    out = {"loads": list(LOADS), "max_new_tokens": MAX_NEW,
           "prompt_lens": list(PROMPT_LENS),
           "engine": {str(n): r for n, r in by_load.items()},
           "toy": toy_res, "speedup_vs_toy": speedup,
           "stats": {k: (sorted(v) if isinstance(v, set) else v)
                     for k, v in engine.stats.items()},
           "tables": engine.plan.tables()}
    with open(OUT, "w") as f:
        json.dump(out, f, indent=2)
    print(f"OK: wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
