"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run table4       # one table

Prints ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import sys


def main() -> None:
    which = set(sys.argv[1:]) or {"table1", "table3", "table4", "fig13",
                                  "roofline", "kernels", "adaptive",
                                  "buckets", "elastic", "serve"}
    if "table1" in which:
        from benchmarks import table1_census
        table1_census.main()
    if "table3" in which:
        from benchmarks import table3_transfer
        table3_transfer.main()
    if "table4" in which:
        from benchmarks import table4_ablation
        table4_ablation.main()
    if "fig13" in which:
        from benchmarks import fig13_scaling
        fig13_scaling.main()
    if "roofline" in which:
        from benchmarks import roofline_table
        roofline_table.main()
    if "kernels" in which:
        from benchmarks import kernel_bench
        kernel_bench.main()
    if "adaptive" in which:
        from benchmarks import adaptive_replan
        adaptive_replan.main()
    if "buckets" in which:
        from benchmarks import bucket_exchange
        bucket_exchange.main()
    if "elastic" in which:
        from benchmarks import elastic_remesh
        elastic_remesh.main()
    if "serve" in which:
        from benchmarks import serve_bench
        serve_bench.main()


if __name__ == "__main__":
    main()
