"""Paper Fig 12/13: throughput scaling vs worker count.

Lower the same arch on growing data-axis meshes (model axis fixed at 16) and
derive the roofline-bound throughput; normalized throughput = T(N)/T(1-group)
— the static-analysis analogue of the paper's normalized-throughput plot.
"""
from __future__ import annotations

from benchmarks.common import emit, run_with_devices

CODE = """
from repro.configs import RunConfig, ShapeConfig, SHAPES, get_config
from repro.core.runtime import Runtime
from repro.core.transform import (analyze, batch_shardings, make_train_step,
                                  state_shardings)
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.optim.optimizer import make_optimizer
from repro.utils.hlo import analyze_hlo
from repro.utils.traffic import estimate_traffic
from repro.utils.roofline import HW

data = __DATA__
arch = "__ARCH__"
cfg = get_config(arch)
shape = ShapeConfig("scale", 4096, 16 * data, "train")
mesh = make_mesh((data, 16), ("data", "model"))
rc = RunConfig(capacity_mode="capped", remat="full")
rt = Runtime(cfg, rc, shape, mesh=mesh)
model = build_model(cfg, rt)
plan = analyze(model, rt)
rt.plan = plan
opt = make_optimizer(rt)
step = make_train_step(model, opt, rt, plan)
state = jax.eval_shape(opt.init, model.abstract_params())
sh = state_shardings(plan, state)
bs = batch_shardings(plan, model.input_specs(shape))
with use_mesh(mesh):
    compiled = jax.jit(step, in_shardings=(sh, bs), out_shardings=(sh, None),
                       donate_argnums=0).lower(
        state, model.input_specs(shape)).compile()
h = analyze_hlo(compiled.as_text(), f32_collective_scale=0.5)
chips = data * 16
tr = estimate_traffic(cfg, shape, chips=chips, model_shards=rt.model_shards,
                      remat="full", zero_stage=plan.zero_stage)
bound = max(h.dot_flops / HW.peak_flops, tr.total / HW.hbm_bw,
            h.collective_bytes / HW.link_bw)
print("RESULT:" + json.dumps({"tok_s": shape.tokens / bound,
                              "chips": chips}))
"""


def main(archs=("phi3-medium-14b", "command-r-35b", "parallax-lm")):
    for arch in archs:
        base = None
        for data in (1, 2, 4, 8, 16):
            res = run_with_devices(
                CODE.replace("__DATA__", str(data)).replace("__ARCH__", arch))
            if base is None:
                base = res["tok_s"]
            emit(f"fig13/{arch}/chips{res['chips']}", 0.0,
                 f"tok_s={res['tok_s']:.0f};"
                 f"normalized={res['tok_s']/base:.2f}x_of_16chip")


if __name__ == "__main__":
    main()
