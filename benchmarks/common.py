"""Benchmark helpers: subprocess lowering (512 fake devices) + wall-clock
micro-timing (single device — the bench process itself never forces devices).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run_with_devices(code: str, devices: int = 512, timeout: int = 900) -> dict:
    """Run code in a subprocess with N fake devices; expects RESULT: json."""
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import json
        import jax
        from repro.compat import (AxisType, NamedSharding, PartitionSpec,
                                  make_mesh, use_mesh)
        P = PartitionSpec
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-3000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise RuntimeError(f"no RESULT line:\n{proc.stdout[-2000:]}")


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (after jit warmup)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
