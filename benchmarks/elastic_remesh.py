"""Elastic straggler response: chaos benchmark for the auto-remesh loop.

One replica slice of a (4 data x 2 model) mesh turns into a sustained
straggler (an injected per-step sleep standing in for a thermally-throttled
/ contended host gating every collective). The monitor escalates —
sustained outlier run -> ``remesh_suggested`` — and ``Trainer`` acts:
commits a checkpoint (manifest carries the live plan record), drops the
slow data slice via ``launch/mesh.shrink_mesh``, re-runs ``analyze()`` so
methods/capacities/buckets are re-priced for the smaller world, and resumes
on the live state. Reported:

  * tokens/s healthy -> straggled -> after the remesh (the recovery is the
    whole point: post-remesh throughput must beat the straggled plateau);
  * f32 loss divergence vs a never-straggled run: **0.0 over the shared
    (pre-remesh) step range** — the escalation machinery is math-inert —
    and the small reduction-order delta after the swap (3 vs 4 replicas sum
    partial gradients in a different association) reported separately;
  * the plan re-priced across the remesh (per-replica tokens grow when a
    replica leaves, so dedupe capacities move), plus a second phase showing
    an N-dependent *method* flip: at a declared α=0.3 on a (4, 1) mesh the
    sparse table exchanges as dense allreduce, and the shrink to N=3 flips
    it to mpi_gatherv (2(N-1)αb undercuts 2(N-1)/N·b exactly there).

Two chaos phases close the elasticity loop:

  * **flap/return** — the straggler is *attributed*: per-slice heartbeat
    scalars ride the fused metrics psum, the monitor names the slow slice,
    and the eviction drops that slice (not the last by convention). The
    host then recovers and ``readmit()`` grows the mesh back at the
    original grid position on probation. A control run applying the same
    shrink/grow schedule manually shows **0.0** f32 loss divergence over
    all steps — the whole flap is math-inert on the synchronous path;
  * **jitter → bounded staleness** — intermittent contention too spiky to
    evict anyone flips the sparse table to bounded-stale pushes (the step
    applies the previous step's exchanged gradient; staleness asserted
    in-graph against ``max_staleness``), and flips back — with an
    automatic drain — once the jitter drains.

Everything lands in ``BENCH_elastic.json`` next to the repo root.

    PYTHONPATH=src python -m benchmarks.elastic_remesh
"""
from __future__ import annotations

import json
import math
import os

from benchmarks.common import run_with_devices

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_elastic.json")

_CHAOS_CODE = """
import tempfile
import time
import numpy as np
from repro.checkpoint.ckpt import latest_step
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=2.0, link_latency=0.0)
STEPS, SLOW_FROM, SLEEP = 20, 6, 0.3

def drive(straggle, ckpt_dir):
    ds = SyntheticLM(cfg.vocab_size, 32, 8)
    mesh = make_mesh((4, 2), ("data", "model"))
    tcfg = TrainerConfig(total_steps=STEPS, ckpt_dir=ckpt_dir,
                         ckpt_every=100, remesh_on_straggle=straggle,
                         remesh_cooldown=20, min_data_parallel=2)
    t = Trainer(cfg, shape, RunConfig(**kw), tcfg, ds, mesh=mesh)
    t.monitor.sustained = 3
    t.monitor.min_samples = 4
    if straggle:
        orig = t.train_step
        def slow(state, batch):
            if t.step >= SLOW_FROM:
                time.sleep(SLEEP)     # the slow host gating every collective
            return orig(state, batch)
        t.train_step = slow           # evicted with its slice at the remesh
    tables0 = dict(t.plan.tables())
    hist = []
    with use_mesh(mesh):
        t.run(on_metrics=lambda s, m: hist.append(dict(
            step=s, loss=float(m["loss"]), tok_s=m["tokens_per_s"],
            remeshes=int(m.get("remeshes", 0)))))
    return t, tables0, hist

ck = tempfile.mkdtemp()
base_t, base_tables, base_hist = drive(False, None)
t, tables0, hist = drive(True, ck)

remesh_at = next((h["step"] for h in hist if h["remeshes"] == 1), -1)
assert remesh_at > 0, "escalation never fired: no remesh in the chaos run"
losses = [h["loss"] for h in hist]
base_losses = [h["loss"] for h in base_hist]
tok = lambda lo, hi: float(np.median([h["tok_s"] for h in hist
                                      if lo <= h["step"] <= hi]))
print("RESULT:" + json.dumps(dict(
    steps=STEPS, slow_from=SLOW_FROM, sleep_s=SLEEP,
    remesh_at=remesh_at, remeshes=t.monitor.remeshes,
    mesh_before={"data": 4, "model": 2}, mesh_after=dict(t.mesh.shape),
    tables_before=tables0, tables_after=t.plan.tables(),
    latest_ckpt=latest_step(ck),
    tokens_per_s=dict(
        healthy=tok(2, SLOW_FROM - 1),           # skip the compile step
        straggled=tok(SLOW_FROM + 1, remesh_at),
        after_remesh=tok(remesh_at + 2, STEPS)), # skip the recompile step
    losses=losses, base_losses=base_losses,
    prefix_divergence=max(abs(a - b) for a, b in
                          zip(losses[:remesh_at],
                              base_losses[:remesh_at])),
    post_divergence=max(abs(a - b) for a, b in
                        zip(losses[remesh_at:], base_losses[remesh_at:])))))
"""

# ---------------------------------------------------------------------------
# phase: flap/return — attributed evict -> probationary re-admission
# ---------------------------------------------------------------------------

_FLAP_CODE = """
import time
import numpy as np
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.launch.mesh import grow_mesh, shrink_mesh
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=2.0, link_latency=0.0,
          heartbeat=True)
STEPS, SLOW_FROM, SLOW, SLEEP, RETURN_AFTER = 26, 4, 1, 0.3, 4

def make_trainer(ckpt_dir, straggle):
    ds = SyntheticLM(cfg.vocab_size, 32, 8)
    mesh = make_mesh((4, 2), ("data", "model"))
    tcfg = TrainerConfig(total_steps=STEPS, ckpt_dir=ckpt_dir,
                         ckpt_every=100, remesh_on_straggle=straggle,
                         remesh_cooldown=20, min_data_parallel=2,
                         probation_steps=50, probation_sustained=2)
    t = Trainer(cfg, shape, RunConfig(**kw), tcfg, ds, mesh=mesh)
    t.monitor.sustained = 3
    t.monitor.min_samples = 4
    return t, mesh

# --- chaos run: slice SLOW straggles (wall clock + heartbeat), the monitor
# attributes it, the trainer evicts it; RETURN_AFTER steps later the host
# is healthy again and readmit() grows the mesh back on probation ---
sched = {"evict_at": None, "readmit_at": None}
t, mesh = make_trainer(None, True)
orig_step = t.train_step
def slow_step(state, batch):
    if sched["evict_at"] is None and t.step >= SLOW_FROM:
        time.sleep(SLEEP)      # the slow host gating every collective
    return orig_step(state, batch)
t.train_step = slow_step       # replaced by the rebuild at the evict
def hb(step, n):
    v = np.full((n,), 0.01, np.float32)
    if sched["evict_at"] is None and step >= SLOW_FROM and SLOW < n:
        v[SLOW] = 0.2          # ...and its heartbeat says so
    return v
t.heartbeat_fn = hb
hist = []
def cb(s, m):
    hist.append(dict(step=s, loss=float(m["loss"]), tok_s=m["tokens_per_s"],
                     straggler_slice=m.get("straggler_slice"),
                     remeshes=int(m.get("remeshes", 0)),
                     regrows=int(m.get("regrows", 0))))
    if sched["evict_at"] is None and m.get("remeshes"):
        sched["evict_at"] = s
    elif sched["readmit_at"] is None and sched["evict_at"] is not None \\
            and s == sched["evict_at"] + RETURN_AFTER:
        assert t.readmit() is not None
        sched["readmit_at"] = s
with use_mesh(mesh):
    t.run(on_metrics=cb)
E, R = sched["evict_at"], sched["readmit_at"]
assert E and R, sched
assert not t._evicted          # the one evicted slice was consumed back

# --- control run: no straggler, no escalation machinery — the SAME mesh
# schedule applied by hand at the recorded steps. Bit-equal f32 losses
# prove the whole flap (attributed evict -> probationary re-admission) is
# math-inert on the synchronous path ---
c, mesh = make_trainer(None, False)
ctl = []
import dataclasses
segments = [(E, None), (R, "shrink"), (STEPS, "grow")]
dropped = None
for upto, action in segments:
    if action == "shrink":
        devs = np.asarray(c.mesh.devices)
        dropped = np.take(devs, SLOW, axis=0)
        c.remesh(shrink_mesh(c.mesh, drop_axis_index=SLOW))
    elif action == "grow":
        c.remesh(grow_mesh(c.mesh, dropped, insert_axis_index=SLOW))
    c.tcfg = dataclasses.replace(c.tcfg, total_steps=upto)
    with use_mesh(c.mesh):
        c.run(on_metrics=lambda s, m: ctl.append(float(m["loss"])))

losses = [h["loss"] for h in hist]
tok = lambda lo, hi: float(np.median([h["tok_s"] for h in hist
                                      if lo <= h["step"] <= hi]))
print("RESULT:" + json.dumps(dict(
    steps=STEPS, slow_from=SLOW_FROM, sleep_s=SLEEP, slow_slice=SLOW,
    evict_at=E, readmit_at=R,
    attributed=[h["straggler_slice"] for h in hist if h["step"] == E],
    remeshes=t.monitor.remeshes, regrows=t.monitor.regrows,
    probation=(t.monitor._probation or (None,))[0],
    mesh_final=dict(t.mesh.shape),
    tokens_per_s=dict(healthy=tok(2, SLOW_FROM - 1),
                      straggled=tok(SLOW_FROM + 1, E),
                      shrunk=tok(E + 2, R),
                      regrown=tok(R + 2, STEPS)),
    losses=losses, control_losses=ctl,
    divergence=max(abs(a - b) for a, b in zip(losses, ctl)))))
"""

# ---------------------------------------------------------------------------
# phase: jitter -> bounded-staleness fallback -> recovery
# ---------------------------------------------------------------------------

_JITTER_CODE = """
import time
import numpy as np
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
rc = RunConfig(attention_impl="naive", remat="none", param_dtype="float32",
               compute_dtype="float32", wire_dtype="float32",
               link_latency=0.0, table_alpha=(("embed", 0.1),),
               max_staleness=2)
STEPS, JIT_FROM, JIT_TO, SLEEP = 26, 4, 14, 0.3
ds = SyntheticLM(cfg.vocab_size, 32, 8)
mesh = make_mesh((4, 1), ("data", "model"))
tcfg = TrainerConfig(total_steps=STEPS, stale_on_jitter=True)
t = Trainer(cfg, shape, rc, tcfg, ds, mesh=mesh)
t.monitor.sustained = 8        # jitter must stay BELOW eviction
t.monitor.min_samples = 6      # ...and one recompile outlier after a flip
t.monitor.window = 10          # must not re-trigger; short window so the
                               # exit hysteresis can drain within the run
method0 = t.plan.table_methods["embed"]
assert not t.plan.stale_tables

def wrap():
    orig = t.train_step
    def jittery(state, batch):
        # intermittent contention: every other step stalls — too spiky
        # for the sustained-run eviction, plenty for the jitter ratio
        if JIT_FROM <= t.step < JIT_TO and t.step % 2 == 0:
            time.sleep(SLEEP)
        return orig(state, batch)
    jittery._wrapped = True
    t.train_step = jittery
wrap()
hist = []
def cb(s, m):
    hist.append(dict(step=s, loss=float(m["loss"]), tok_s=m["tokens_per_s"],
                     jitter=m.get("jitter_ratio"),
                     stale=m.get("stale_mode"),
                     age=m.get("staleness_age"),
                     violation=m.get("staleness_violation"),
                     flips=int(m.get("stale_flips", 0))))
    if not getattr(t.train_step, "_wrapped", False):
        wrap()                 # a stale flip rebuilt the step: re-arm
with use_mesh(mesh):
    t.run(on_metrics=cb)
on_at = next((h["step"] for h in hist if h["flips"] == 1), -1)
off_at = next((h["step"] for h in hist if h["flips"] == 2), -1)
assert on_at > 0 and off_at > on_at, (on_at, off_at)
stale_steps = [h for h in hist if on_at < h["step"] <= off_at]
tok = lambda lo, hi: float(np.median([h["tok_s"] for h in hist
                                      if lo <= h["step"] <= hi]))
print("RESULT:" + json.dumps(dict(
    steps=STEPS, jitter_from=JIT_FROM, jitter_to=JIT_TO, sleep_s=SLEEP,
    method=method0, stale_on_at=on_at, stale_off_at=off_at,
    stale_flips=t.monitor.stale_flips,
    final_stale_tables=list(t.plan.stale_tables),
    evictions=t.monitor.remeshes,
    max_staleness_applied=max((h["age"] or 0) for h in stale_steps),
    violations=sum((h["violation"] or 0) for h in stale_steps),
    tokens_per_s=dict(healthy=tok(2, JIT_FROM - 1),
                      jittery=tok(JIT_FROM + 1, on_at),
                      stale=tok(on_at + 2, off_at),
                      recovered=tok(off_at + 2, STEPS)),
    losses=[h["loss"] for h in hist])))
"""

# ---------------------------------------------------------------------------
# phase 2: the N-dependent method flip across a remesh
# ---------------------------------------------------------------------------

_REPRICE_CODE = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.launch.mesh import shrink_mesh
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
rc = RunConfig(attention_impl="naive", remat="none", param_dtype="float32",
               compute_dtype="float32", wire_dtype="float32",
               link_latency=0.0, table_alpha=(("embed", 0.3),))
ds = SyntheticLM(cfg.vocab_size, 32, 8)
mesh = make_mesh((4, 1), ("data", "model"))
t = Trainer(cfg, shape, rc, TrainerConfig(total_steps=2), ds, mesh=mesh)
method_n4 = t.plan.table_methods["embed"]
with use_mesh(mesh):
    t.run()
mesh3 = shrink_mesh(mesh, drop_axis_index=3)
t.remesh(mesh3)
t.tcfg = TrainerConfig(total_steps=4)
losses = []
with use_mesh(mesh3):
    t.run(on_metrics=lambda s, m: losses.append(float(m["loss"])))
print("RESULT:" + json.dumps(dict(
    method_n4=method_n4, method_n3=t.plan.table_methods["embed"],
    losses=losses)))
"""


def main():
    res = run_with_devices(_CHAOS_CODE, devices=8)
    tp = res["tokens_per_s"]
    print(f"chaos run: {res['steps']} steps, slice straggles from step "
          f"{res['slow_from']} (+{res['sleep_s'] * 1e3:.0f} ms/step)")
    print(f"auto-remesh at step {res['remesh_at']}: mesh "
          f"{res['mesh_before']} -> {res['mesh_after']} "
          f"(checkpoint committed at step {res['remesh_at']})")
    print(f"tokens/s: healthy {tp['healthy']:.0f} -> straggled "
          f"{tp['straggled']:.0f} -> after remesh {tp['after_remesh']:.0f}")
    print(f"embed capacity re-priced: "
          f"{res['tables_before']['embed']['capacity']} -> "
          f"{res['tables_after']['embed']['capacity']} "
          f"(observed census carried across the remesh, re-priced at N=3)")
    print(f"f32 loss divergence vs never-straggled run: "
          f"{res['prefix_divergence']:.1e} over the shared pre-remesh "
          f"range, {res['post_divergence']:.1e} after the swap "
          f"(3-vs-4-replica reduction order)")

    # CI smoke contract
    assert res["remeshes"] == 1, "escalation never fired (or thrashed)"
    assert res["mesh_after"] == {"data": 3, "model": 2}, res["mesh_after"]
    assert res["prefix_divergence"] == 0.0, \
        "the escalation machinery perturbed the shared trajectory"
    assert res["post_divergence"] < 5e-2, "post-remesh trajectory diverged"
    assert tp["after_remesh"] > 2.0 * tp["straggled"], \
        "evicting the slow slice did not recover throughput"
    assert res["latest_ckpt"] == res["steps"]

    flap = run_with_devices(_FLAP_CODE, devices=8)
    fp = flap["tokens_per_s"]
    print(f"flap run: slice {flap['slow_slice']} straggles from step "
          f"{flap['slow_from']}, heartbeat-attributed evict at step "
          f"{flap['evict_at']} (attributed slice "
          f"{flap['attributed']}), readmit at step {flap['readmit_at']} "
          f"-> final mesh {flap['mesh_final']}")
    print(f"tokens/s: healthy {fp['healthy']:.0f} -> straggled "
          f"{fp['straggled']:.0f} -> shrunk {fp['shrunk']:.0f} -> "
          f"regrown {fp['regrown']:.0f}")
    print(f"f32 loss divergence vs manual-schedule control run: "
          f"{flap['divergence']:.1e} over all {flap['steps']} steps "
          f"(evict + probationary re-admission are math-inert)")
    assert flap["attributed"] == [flap["slow_slice"]], \
        "the heartbeat attribution did not name the injected straggler"
    assert flap["remeshes"] == 1 and flap["regrows"] == 1, flap
    assert flap["mesh_final"] == {"data": 4, "model": 2}, flap["mesh_final"]
    assert flap["probation"] == flap["slow_slice"], \
        "readmit() did not arm a probation window on the returned slice"
    assert flap["divergence"] == 0.0, \
        "the flap machinery perturbed the synchronous trajectory"
    assert fp["shrunk"] > 2.0 * fp["straggled"], \
        "evicting the attributed slice did not recover throughput"

    jit = run_with_devices(_JITTER_CODE, devices=8)
    jp = jit["tokens_per_s"]
    print(f"jitter run: {jit['method']} table flips stale at step "
          f"{jit['stale_on_at']}, back to synchronous at step "
          f"{jit['stale_off_at']} (max staleness applied "
          f"{jit['max_staleness_applied']:.0f} <= bound 2, "
          f"violations {jit['violations']:.0f})")
    print(f"tokens/s: healthy {jp['healthy']:.0f} -> jittery "
          f"{jp['jittery']:.0f} -> stale {jp['stale']:.0f} -> recovered "
          f"{jp['recovered']:.0f}")
    assert jit["method"] == "mpi_gatherv", jit["method"]
    assert jit["stale_flips"] == 2, \
        f"expected exactly one on+off flip pair, got {jit['stale_flips']}"
    assert jit["evictions"] == 0, "jitter must not escalate to an eviction"
    assert 1 <= jit["max_staleness_applied"] <= 2, jit
    assert jit["violations"] == 0, \
        "the in-graph staleness bound was violated"
    assert not jit["final_stale_tables"], \
        "the run did not recover to the synchronous plan"
    assert all(math.isfinite(x) for x in jit["losses"]), \
        "stale pushes diverged"

    two = run_with_devices(_REPRICE_CODE, devices=8)
    print(f"re-pricing flip: embed exchanged as {two['method_n4']} at N=4, "
          f"{two['method_n3']} at N=3 (2(N-1)alpha*b vs 2(N-1)/N*b at "
          f"alpha=0.3)")
    assert (two["method_n4"], two["method_n3"]) == \
        ("allreduce", "mpi_gatherv"), two

    with open(OUT, "w") as f:
        json.dump(dict(chaos=res, flap=flap, jitter=jit, reprice=two),
                  f, indent=2)
    print(f"OK: straggle -> checkpoint -> shrink -> re-price -> resume, "
          f"flap -> attributed evict -> probationary re-admit, "
          f"jitter -> bounded-stale -> drain; wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
