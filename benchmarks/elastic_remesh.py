"""Elastic straggler response: chaos benchmark for the auto-remesh loop.

One replica slice of a (4 data x 2 model) mesh turns into a sustained
straggler (an injected per-step sleep standing in for a thermally-throttled
/ contended host gating every collective). The monitor escalates —
sustained outlier run -> ``remesh_suggested`` — and ``Trainer`` acts:
commits a checkpoint (manifest carries the live plan record), drops the
slow data slice via ``launch/mesh.shrink_mesh``, re-runs ``analyze()`` so
methods/capacities/buckets are re-priced for the smaller world, and resumes
on the live state. Reported:

  * tokens/s healthy -> straggled -> after the remesh (the recovery is the
    whole point: post-remesh throughput must beat the straggled plateau);
  * f32 loss divergence vs a never-straggled run: **0.0 over the shared
    (pre-remesh) step range** — the escalation machinery is math-inert —
    and the small reduction-order delta after the swap (3 vs 4 replicas sum
    partial gradients in a different association) reported separately;
  * the plan re-priced across the remesh (per-replica tokens grow when a
    replica leaves, so dedupe capacities move), plus a second phase showing
    an N-dependent *method* flip: at a declared α=0.3 on a (4, 1) mesh the
    sparse table exchanges as dense allreduce, and the shrink to N=3 flips
    it to mpi_gatherv (2(N-1)αb undercuts 2(N-1)/N·b exactly there).

Everything lands in ``BENCH_elastic.json`` next to the repo root.

    PYTHONPATH=src python -m benchmarks.elastic_remesh
"""
from __future__ import annotations

import json
import os

from benchmarks.common import run_with_devices

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_elastic.json")

_CHAOS_CODE = """
import tempfile
import time
import numpy as np
from repro.checkpoint.ckpt import latest_step
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=2.0, link_latency=0.0)
STEPS, SLOW_FROM, SLEEP = 20, 6, 0.3

def drive(straggle, ckpt_dir):
    ds = SyntheticLM(cfg.vocab_size, 32, 8)
    mesh = make_mesh((4, 2), ("data", "model"))
    tcfg = TrainerConfig(total_steps=STEPS, ckpt_dir=ckpt_dir,
                         ckpt_every=100, remesh_on_straggle=straggle,
                         remesh_cooldown=20, min_data_parallel=2)
    t = Trainer(cfg, shape, RunConfig(**kw), tcfg, ds, mesh=mesh)
    t.monitor.sustained = 3
    t.monitor.min_samples = 4
    if straggle:
        orig = t.train_step
        def slow(state, batch):
            if t.step >= SLOW_FROM:
                time.sleep(SLEEP)     # the slow host gating every collective
            return orig(state, batch)
        t.train_step = slow           # evicted with its slice at the remesh
    tables0 = dict(t.plan.tables())
    hist = []
    with use_mesh(mesh):
        t.run(on_metrics=lambda s, m: hist.append(dict(
            step=s, loss=float(m["loss"]), tok_s=m["tokens_per_s"],
            remeshes=int(m.get("remeshes", 0)))))
    return t, tables0, hist

ck = tempfile.mkdtemp()
base_t, base_tables, base_hist = drive(False, None)
t, tables0, hist = drive(True, ck)

remesh_at = next((h["step"] for h in hist if h["remeshes"] == 1), -1)
assert remesh_at > 0, "escalation never fired: no remesh in the chaos run"
losses = [h["loss"] for h in hist]
base_losses = [h["loss"] for h in base_hist]
tok = lambda lo, hi: float(np.median([h["tok_s"] for h in hist
                                      if lo <= h["step"] <= hi]))
print("RESULT:" + json.dumps(dict(
    steps=STEPS, slow_from=SLOW_FROM, sleep_s=SLEEP,
    remesh_at=remesh_at, remeshes=t.monitor.remeshes,
    mesh_before={"data": 4, "model": 2}, mesh_after=dict(t.mesh.shape),
    tables_before=tables0, tables_after=t.plan.tables(),
    latest_ckpt=latest_step(ck),
    tokens_per_s=dict(
        healthy=tok(2, SLOW_FROM - 1),           # skip the compile step
        straggled=tok(SLOW_FROM + 1, remesh_at),
        after_remesh=tok(remesh_at + 2, STEPS)), # skip the recompile step
    losses=losses, base_losses=base_losses,
    prefix_divergence=max(abs(a - b) for a, b in
                          zip(losses[:remesh_at],
                              base_losses[:remesh_at])),
    post_divergence=max(abs(a - b) for a, b in
                        zip(losses[remesh_at:], base_losses[remesh_at:])))))
"""

# ---------------------------------------------------------------------------
# phase 2: the N-dependent method flip across a remesh
# ---------------------------------------------------------------------------

_REPRICE_CODE = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.launch.mesh import shrink_mesh
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
rc = RunConfig(attention_impl="naive", remat="none", param_dtype="float32",
               compute_dtype="float32", wire_dtype="float32",
               link_latency=0.0, table_alpha=(("embed", 0.3),))
ds = SyntheticLM(cfg.vocab_size, 32, 8)
mesh = make_mesh((4, 1), ("data", "model"))
t = Trainer(cfg, shape, rc, TrainerConfig(total_steps=2), ds, mesh=mesh)
method_n4 = t.plan.table_methods["embed"]
with use_mesh(mesh):
    t.run()
mesh3 = shrink_mesh(mesh, drop_axis_index=3)
t.remesh(mesh3)
t.tcfg = TrainerConfig(total_steps=4)
losses = []
with use_mesh(mesh3):
    t.run(on_metrics=lambda s, m: losses.append(float(m["loss"])))
print("RESULT:" + json.dumps(dict(
    method_n4=method_n4, method_n3=t.plan.table_methods["embed"],
    losses=losses)))
"""


def main():
    res = run_with_devices(_CHAOS_CODE, devices=8)
    tp = res["tokens_per_s"]
    print(f"chaos run: {res['steps']} steps, slice straggles from step "
          f"{res['slow_from']} (+{res['sleep_s'] * 1e3:.0f} ms/step)")
    print(f"auto-remesh at step {res['remesh_at']}: mesh "
          f"{res['mesh_before']} -> {res['mesh_after']} "
          f"(checkpoint committed at step {res['remesh_at']})")
    print(f"tokens/s: healthy {tp['healthy']:.0f} -> straggled "
          f"{tp['straggled']:.0f} -> after remesh {tp['after_remesh']:.0f}")
    print(f"embed capacity re-priced: "
          f"{res['tables_before']['embed']['capacity']} -> "
          f"{res['tables_after']['embed']['capacity']} "
          f"(observed census carried across the remesh, re-priced at N=3)")
    print(f"f32 loss divergence vs never-straggled run: "
          f"{res['prefix_divergence']:.1e} over the shared pre-remesh "
          f"range, {res['post_divergence']:.1e} after the swap "
          f"(3-vs-4-replica reduction order)")

    # CI smoke contract
    assert res["remeshes"] == 1, "escalation never fired (or thrashed)"
    assert res["mesh_after"] == {"data": 3, "model": 2}, res["mesh_after"]
    assert res["prefix_divergence"] == 0.0, \
        "the escalation machinery perturbed the shared trajectory"
    assert res["post_divergence"] < 5e-2, "post-remesh trajectory diverged"
    assert tp["after_remesh"] > 2.0 * tp["straggled"], \
        "evicting the slow slice did not recover throughput"
    assert res["latest_ckpt"] == res["steps"]

    two = run_with_devices(_REPRICE_CODE, devices=8)
    print(f"re-pricing flip: embed exchanged as {two['method_n4']} at N=4, "
          f"{two['method_n3']} at N=3 (2(N-1)alpha*b vs 2(N-1)/N*b at "
          f"alpha=0.3)")
    assert (two["method_n4"], two["method_n3"]) == \
        ("allreduce", "mpi_gatherv"), two

    with open(OUT, "w") as f:
        json.dump(dict(chaos=res, reprice=two), f, indent=2)
    print(f"OK: straggle -> checkpoint -> shrink -> re-price -> resume; "
          f"wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
