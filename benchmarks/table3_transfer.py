"""Paper Table 3: analytic per-replica transfer bytes vs HLO-measured
collective bytes for the embedding exchange, per communication method.

Measurement: lower the paper's LM on the production mesh under each method
and diff the HLO collective totals against a no-embedding-exchange baseline
is noisy; instead we lower a minimal embedding-only step (lookup -> loss ->
grad -> sgd) so every collective belongs to the exchange under test.
"""
from __future__ import annotations

import json

from benchmarks.common import emit, run_with_devices
from repro.core import cost_model as cm

CODE = """
import jax.numpy as jnp
from repro.core.embedding import EmbedCtx, lookup
from repro.utils.hlo import analyze_hlo

V, E, B, S = 65536, 512, 256, 256     # ~64k-row table, 512-dim rows
mesh = make_mesh((16, 16), ("data", "model"))
ctx = EmbedCtx(mesh=mesh, method="__METHOD__", batch_axes=("data",),
               model_axis="model", vocab_padded=V, wire_dtype=jnp.bfloat16,
               local_agg=__LA__, exact=False)

def step(table, ids):
    out, _ = lookup(table, ids, ctx=ctx, capacity=__CAP__)
    loss = jnp.sum(out.astype(jnp.float32) ** 2)
    return loss

tspec = P(None, None) if ctx.method == "mpi_gatherv" else P("model", None)
table = jax.ShapeDtypeStruct((V, E), jnp.bfloat16)
ids = jax.ShapeDtypeStruct((B, S), jnp.int32)
with use_mesh(mesh):
    g = jax.jit(jax.grad(step), in_shardings=(
        NamedSharding(mesh, tspec), NamedSharding(mesh, P("data", None))))
    compiled = g.lower(table, ids).compile()
s = analyze_hlo(compiled.as_text(), f32_collective_scale=0.5)
print("RESULT:" + json.dumps({"bytes": s.collective_bytes,
                              "by_kind": s.collective_by_kind}))
"""


def main():
    V, E, B, S = 65536, 512, 256, 256
    b = V * E * 2                        # table bytes (bf16)
    local_tokens = B * S // 16
    import math
    uniq = V * (1 - math.exp(local_tokens * math.log1p(-1 / V)))
    alpha = uniq / V
    cap = int(uniq * 1.0) + 1
    dims = cm.MeshDims(model=16, data=16)
    analytic = {
        "ps": cm.sparse_ps_bytes(b, alpha, dims),
        "ps_gather": cm.sparse_ps_gather_bytes(b, alpha, dims),
        "mpi_gatherv": cm.sparse_mpi_bytes(b, alpha, dims),
    }
    for method in ("ps", "ps_gather", "mpi_gatherv"):
        res = run_with_devices(
            CODE.replace("__METHOD__", method)
                .replace("__LA__", "True").replace("__CAP__", str(cap)))
        emit(f"table3/{method}", 0.0,
             f"hlo_MB={res['bytes']/1e6:.1f};analytic_MB={analytic[method]/1e6:.1f};"
             f"alpha={alpha:.3f}")
    # LA off: raw token buffers instead of deduped rows
    res = run_with_devices(
        CODE.replace("__METHOD__", "ps").replace("__LA__", "False")
            .replace("__CAP__", str(cap)))
    emit("table3/ps_noLA", 0.0, f"hlo_MB={res['bytes']/1e6:.1f};"
         f"tokens_per_replica={local_tokens}")


if __name__ == "__main__":
    main()
