"""Paper Table 4: cumulative optimization ablation on the sparse models.

BASE (ps everywhere, no LA/OPAU/OPSW) -> +HYB -> +LA -> +OPAU -> +OPSW,
lowered on the 16x16 production mesh; reported as per-chip collective bytes
and the roofline-bound step time (the CPU-measurable throughput analogue —
wall-time ratios on real TPUs follow the dominant-term ratios).
"""
from __future__ import annotations

from benchmarks.common import emit, run_with_devices

CODE = """
from repro.configs import RunConfig, SHAPES, get_config
from repro.launch.dryrun import run_cell

res = run_cell("__ARCH__", "train_4k", multi_pod=False,
               run_cfg=RunConfig(comm_mode="__MODE__", local_agg=__LA__,
                                 opau=__OPAU__, opsw=__OPSW__,
                                 capacity_mode="capped", remat="full"),
               verbose=False)
r = res["roofline"]
print("RESULT:" + json.dumps({
    "collective_GB": r["per_chip_collective_bytes"] / 1e9,
    "bound_ms": max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e3,
    "tok_s": SHAPES["train_4k"].tokens /
             max(r["compute_s"], r["memory_s"], r["collective_s"]),
}))
"""

STAGES = [
    ("BASE", dict(mode="ps", la=False, opau=False, opsw=False)),
    ("+HYB", dict(mode="hybrid", la=False, opau=False, opsw=False)),
    ("+LA", dict(mode="hybrid", la=True, opau=False, opsw=False)),
    ("+OPAU", dict(mode="hybrid", la=True, opau=True, opsw=False)),
    ("+OPSW", dict(mode="hybrid", la=True, opau=True, opsw=True)),
]


def main(archs=("parallax-lm", "command-r-35b")):
    for arch in archs:
        base_tok = None
        for name, f in STAGES:
            code = (CODE.replace("__ARCH__", arch)
                    .replace("__MODE__", f["mode"])
                    .replace("__LA__", str(f["la"]))
                    .replace("__OPAU__", str(f["opau"]))
                    .replace("__OPSW__", str(f["opsw"])))
            res = run_with_devices(code)
            if base_tok is None:
                base_tok = res["tok_s"]
            emit(f"table4/{arch}/{name}", res["bound_ms"] * 1e3,
                 f"collective_GB={res['collective_GB']:.2f};"
                 f"tok_s={res['tok_s']:.0f};"
                 f"speedup_vs_base={res['tok_s']/base_tok:.2f}")


if __name__ == "__main__":
    main()
