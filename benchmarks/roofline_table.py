"""§Roofline aggregation: read results/dryrun/*.json -> markdown + CSV."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS, emit


def rows(pattern="*.json", out_dir=None):
    out_dir = out_dir or os.path.join(RESULTS, "dryrun")
    out = []
    for f in sorted(glob.glob(os.path.join(out_dir, pattern))):
        d = json.load(open(f))
        if not d.get("ok"):
            out.append(d)
            continue
        out.append(d)
    return out


def main():
    for d in rows():
        tag = f"{d['arch']}/{d['shape']}/{d['mesh']}"
        if not d.get("ok"):
            emit(f"roofline/{tag}", 0.0, "FAILED")
            continue
        r = d["roofline"]
        emit(f"roofline/{tag}", r["bound_s"] * 1e6 if "bound_s" in r else
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"compute_ms={r['compute_s']*1e3:.1f};"
             f"memory_ms={r['memory_s']*1e3:.1f};"
             f"collective_ms={r['collective_s']*1e3:.1f};"
             f"dominant={r['dominant']};"
             f"useful_flops={r['useful_flops_fraction']:.2f};"
             f"roofline_frac={r['roofline_fraction']:.3f};"
             f"peak_GB={d['memory_analysis']['peak_bytes']/1e9:.1f}")


def markdown(out_dir=None) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "dominant | MODEL/HLO flops | roofline frac | peak GB/chip |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for d in rows(out_dir=out_dir):
        if not d.get("ok"):
            lines.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                         f"FAILED: {d['error'][:40]} | | | | | | |")
            continue
        r = d["roofline"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['dominant']} "
            f"| {r['useful_flops_fraction']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {d['memory_analysis']['peak_bytes']/1e9:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    main()
