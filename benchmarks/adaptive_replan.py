"""Static vs adaptive planning on a Zipf-skewed workload (the paper's
profile -> re-optimize loop, §5).

The build-time plan prices the sparse exchange from the uniform-draw α upper
bound; synthetic corpora draw Zipf(a) ids, so the planned α is systematically
high. This benchmark runs the same skewed workload twice on 8 fake devices —
once with the static build-time plan, once with the profile->replan loop —
and reports:

  * estimated α (uniform), analytic Zipf α, and the observed EMA α;
  * the embedding exchange method and capacity before/after the replan;
  * loss continuity: the adaptive run must reproduce the static trajectory
    (the correctness contract holds across a hot-swap);
  * median step wall time before vs after the replan (smaller dedupe
    buffers + cheaper exchange on the measured workload).

    PYTHONPATH=src python -m benchmarks.adaptive_replan
"""
from __future__ import annotations

from benchmarks.common import run_with_devices

_CODE = """
import time
import numpy as np
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.sparsity import (SparsityProfile, expected_unique,
                                 expected_unique_zipf, observed_census)
from repro.core.transform import estimate_census, get_runner
from repro.data import SyntheticLM

ZIPF_A = 1.3
cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=1.5)
ds = SyntheticLM(cfg.vocab_size, 32, 8, zipf_a=ZIPF_A)
mesh = make_mesh((4, 2), ("data", "model"))

STEPS, PROFILE_STEPS = 16, 4

def drive(adaptive):
    with use_mesh(mesh):
        run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
        before = dict(method=run.plan.embed_method, capacity=run.plan.capacity,
                      alpha=run.plan.alpha)
        prof = SparsityProfile()
        losses, times, replan = [], [], None
        for i in range(STEPS):
            t0 = time.perf_counter()
            m = run.run(ds.batch(i))
            loss = float(m["loss"])          # host sync closes the step
            times.append(time.perf_counter() - t0)
            losses.append(loss)
            prof.update({k: float(v) for k, v in m.items()
                         if getattr(v, "ndim", 0) == 0})
            if adaptive and i + 1 == PROFILE_STEPS:
                census = observed_census(
                    prof, estimate_census(run.model, run.rt),
                    cfg.vocab_size, run.rt.run_cfg)
                d = run.replan(census)
                replan = dict(step=i + 1, flips=d["flips"],
                              capacity=list(d["capacity"]),
                              alpha=list(d["alpha"]),
                              rebuilt=d["rebuilt"])
        after = dict(method=run.plan.embed_method, capacity=run.plan.capacity,
                     alpha=run.plan.alpha)
        return dict(before=before, after=after, replan=replan,
                    losses=losses, observed_alpha=prof.alpha(cfg.vocab_size),
                    # drop the compile step (0) and the recompile step
                    pre_ms=float(np.median(times[1:PROFILE_STEPS]) * 1e3),
                    post_ms=float(np.median(times[PROFILE_STEPS + 1:]) * 1e3))

static = drive(adaptive=False)
adaptive = drive(adaptive=True)
local_tokens = shape.tokens // 4
print("RESULT:" + json.dumps(dict(
    local_tokens=local_tokens, vocab=cfg.vocab_size,
    alpha_uniform=expected_unique(local_tokens, cfg.vocab_size)
        / cfg.vocab_size,
    alpha_zipf_analytic=expected_unique_zipf(local_tokens, cfg.vocab_size,
                                             ZIPF_A) / cfg.vocab_size,
    static=static, adaptive=adaptive,
    max_loss_divergence=max(abs(a - b) for a, b in
                            zip(static["losses"], adaptive["losses"])))))
"""


def main():
    res = run_with_devices(_CODE, devices=8)
    st, ad = res["static"], res["adaptive"]
    print(f"workload: {res['local_tokens']} local tokens, "
          f"vocab {res['vocab']}, Zipf a=1.3")
    print(f"alpha estimate  uniform={res['alpha_uniform']:.4f}  "
          f"zipf-analytic={res['alpha_zipf_analytic']:.4f}  "
          f"observed={ad['observed_alpha']:.4f}")
    print(f"static plan:    method={st['before']['method']} "
          f"capacity={st['before']['capacity']} "
          f"alpha={st['before']['alpha']:.4f} (never changes)")
    r = ad["replan"]
    print(f"adaptive plan:  {ad['before']['method']} -> "
          f"{ad['after']['method']}  capacity {ad['before']['capacity']} -> "
          f"{ad['after']['capacity']}  (replanned at step {r['step']}, "
          f"flips={r['flips']})")
    print(f"step time:      static {st['pre_ms']:.1f} ms -> {st['post_ms']:.1f} ms | "
          f"adaptive {ad['pre_ms']:.1f} ms -> {ad['post_ms']:.1f} ms")
    print(f"max loss divergence static vs adaptive: "
          f"{res['max_loss_divergence']:.2e}")
    assert r is not None and r["rebuilt"], "adaptive run never replanned"
    assert res["max_loss_divergence"] < 5e-3, \
        "replan changed the math, not just the wire schedule"
    print("OK: replan changed the exchange plan without changing the math")


if __name__ == "__main__":
    main()
