"""Static vs adaptive planning on a Zipf-skewed workload (the paper's
profile -> re-optimize loop, §5) — now per-parameter.

The build-time plan prices the sparse exchange from the uniform-draw α upper
bound; synthetic corpora draw Zipf(a) ids, so the planned α is systematically
high. This benchmark runs the same skewed workload twice on 8 fake devices —
once with the static build-time plan, once with the profile->replan loop —
and reports:

  * estimated α (uniform), analytic Zipf α, and the observed EMA α;
  * the embedding exchange method and capacity before/after the replan;
  * loss continuity: the adaptive run must reproduce the static trajectory
    (the correctness contract holds across a hot-swap);
  * median step wall time before vs after the replan (smaller dedupe
    buffers + cheaper exchange on the measured workload).

A second phase drives the per-parameter planner on a two-table NMT model
(Zipf-skewed decoder vocab + near-dense encoder table) through a workload
burst: the tables land on different methods/capacities from one analyze()
call, and the replan loop grows the overflowing table's capacity. Everything
is written to ``BENCH_replan.json`` (per-table plan entries + the capacity
trajectory across replans) next to the repo root.

    PYTHONPATH=src python -m benchmarks.adaptive_replan
"""
from __future__ import annotations

import json
import os

from benchmarks.common import run_with_devices

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_replan.json")

_CODE = """
import time
import numpy as np
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.sparsity import (SparsityProfile, expected_unique,
                                 expected_unique_zipf, observed_census)
from repro.core.transform import estimate_census, get_runner
from repro.data import SyntheticLM

ZIPF_A = 1.3
cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
# link_latency=0 pins the paper's pure-byte Table-3 argmin so the toy-sized
# table plans onto the row-sharded ps path (at 64KB the per-message latency
# term otherwise swamps bytes and legitimately argmins to dense allreduce)
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=1.5, link_latency=0.0)
ds = SyntheticLM(cfg.vocab_size, 32, 8, zipf_a=ZIPF_A)
mesh = make_mesh((4, 2), ("data", "model"))

STEPS, PROFILE_STEPS = 16, 4

def drive(adaptive):
    with use_mesh(mesh):
        run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
        before = dict(method=run.plan.embed_method, capacity=run.plan.capacity,
                      alpha=run.plan.alpha)
        prof = SparsityProfile()
        losses, times, replan = [], [], None
        for i in range(STEPS):
            t0 = time.perf_counter()
            m = run.run(ds.batch(i))
            loss = float(m["loss"])          # host sync closes the step
            times.append(time.perf_counter() - t0)
            losses.append(loss)
            prof.update({k: float(v) for k, v in m.items()
                         if getattr(v, "ndim", 0) == 0})
            if adaptive and i + 1 == PROFILE_STEPS:
                census = observed_census(
                    prof, estimate_census(run.model, run.rt),
                    cfg.vocab_size, run.rt.run_cfg)
                d = run.replan(census)
                replan = dict(step=i + 1, flips=d["flips"],
                              capacity=list(d["capacity"]),
                              alpha=list(d["alpha"]),
                              rebuilt=d["rebuilt"])
        after = dict(method=run.plan.embed_method, capacity=run.plan.capacity,
                     alpha=run.plan.alpha)
        return dict(before=before, after=after, replan=replan,
                    losses=losses, observed_alpha=prof.alpha(cfg.vocab_size),
                    # drop the compile step (0) and the recompile step
                    pre_ms=float(np.median(times[1:PROFILE_STEPS]) * 1e3),
                    post_ms=float(np.median(times[PROFILE_STEPS + 1:]) * 1e3))

static = drive(adaptive=False)
adaptive = drive(adaptive=True)
local_tokens = shape.tokens // 4
print("RESULT:" + json.dumps(dict(
    local_tokens=local_tokens, vocab=cfg.vocab_size,
    alpha_uniform=expected_unique(local_tokens, cfg.vocab_size)
        / cfg.vocab_size,
    alpha_zipf_analytic=expected_unique_zipf(local_tokens, cfg.vocab_size,
                                             ZIPF_A) / cfg.vocab_size,
    static=static, adaptive=adaptive,
    max_loss_divergence=max(abs(a - b) for a, b in
                            zip(static["losses"], adaptive["losses"])))))
"""

# ---------------------------------------------------------------------------
# phase 2: per-parameter planning on a two-table model + overflow growth
# ---------------------------------------------------------------------------

_TWO_TABLE_CODE = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.sparsity import SparsityProfile, observed_census
from repro.core.transform import estimate_census, get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("parallax-nmt"), vocab=256)
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
# decoder vocab table: declared steady skew zipf(2.0) -> tight capped
# buffer, overflowed by a zipf(1.3) burst in the first 4 batches;
# encoder table: declared near-dense (alpha 0.99), fed uniform src ids
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=2.0, link_latency=0.0,
          zipf_a=2.0, capacity_growth=1.5, overflow_tolerance=0.5,
          table_zipf=(("embed", 2.0),), table_alpha=(("enc_embed", 0.99),))
ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True, src_zipf_a=0.0,
                 zipf_a=2.0, burst_steps=4, burst_zipf_a=1.3)
mesh = make_mesh((4, 2), ("data", "model"))
STEPS, REPLAN_EVERY = 16, 4

with use_mesh(mesh):
    run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
    trajectory = [dict(step=0, tables=run.plan.tables(), replanned=False)]
    prof = SparsityProfile()
    losses = []
    for i in range(STEPS):
        m = run.run(ds.batch(i))
        losses.append(float(m["loss"]))
        prof.update({k: float(v) for k, v in m.items()
                     if getattr(v, "ndim", 0) == 0})
        if (i + 1) % REPLAN_EVERY == 0:
            census = observed_census(
                prof, estimate_census(run.model, run.rt),
                cfg.vocab_size, run.rt.run_cfg)
            d = run.replan(census)
            trajectory.append(dict(
                step=i + 1, tables=run.plan.tables(), replanned=d["rebuilt"],
                capacity_grown=d["capacity_grown"],
                dropped={t: prof.dropped_for(t)
                         for t in ("embed", "enc_embed")}))
print("RESULT:" + json.dumps(dict(
    trajectory=trajectory, losses=losses,
    final_tables=run.plan.tables())))
"""


def main():
    res = run_with_devices(_CODE, devices=8)
    st, ad = res["static"], res["adaptive"]
    print(f"workload: {res['local_tokens']} local tokens, "
          f"vocab {res['vocab']}, Zipf a=1.3")
    print(f"alpha estimate  uniform={res['alpha_uniform']:.4f}  "
          f"zipf-analytic={res['alpha_zipf_analytic']:.4f}  "
          f"observed={ad['observed_alpha']:.4f}")
    print(f"static plan:    method={st['before']['method']} "
          f"capacity={st['before']['capacity']} "
          f"alpha={st['before']['alpha']:.4f} (never changes)")
    r = ad["replan"]
    print(f"adaptive plan:  {ad['before']['method']} -> "
          f"{ad['after']['method']}  capacity {ad['before']['capacity']} -> "
          f"{ad['after']['capacity']}  (replanned at step {r['step']}, "
          f"flips={r['flips']})")
    print(f"step time:      static {st['pre_ms']:.1f} ms -> {st['post_ms']:.1f} ms | "
          f"adaptive {ad['pre_ms']:.1f} ms -> {ad['post_ms']:.1f} ms")
    print(f"max loss divergence static vs adaptive: "
          f"{res['max_loss_divergence']:.2e}")
    assert r is not None and r["rebuilt"], "adaptive run never replanned"
    assert res["max_loss_divergence"] < 5e-3, \
        "replan changed the math, not just the wire schedule"
    print("OK: replan changed the exchange plan without changing the math")

    two = run_with_devices(_TWO_TABLE_CODE, devices=8)
    final = two["final_tables"]
    print("\ntwo-table per-parameter plan (parallax-nmt reduced):")
    for t, e in sorted(final.items()):
        print(f"  {t:10s} method={e['method']:12s} capacity={e['capacity']:4d} "
              f"wire={e['wire_dtype']}  grown={e['grown']}")
    print("capacity trajectory (embed):  " + " -> ".join(
        str(p["tables"]["embed"]["capacity"]) for p in two["trajectory"]))
    grew = [p for p in two["trajectory"] if p.get("capacity_grown")]
    if grew:
        print(f"overflow-grown at step {grew[0]['step']} "
              f"(dropped EMA {grew[0]['dropped']['embed']:.1f} rows/step)")

    # CI smoke contract: the benchmark must report one plan entry per sparse
    # table, and the two tables must have genuinely diverged
    assert set(final) == {"embed", "enc_embed"}, final
    assert final["embed"]["method"] != final["enc_embed"]["method"], final
    assert final["embed"]["capacity"] != final["enc_embed"]["capacity"], final
    assert grew, "sustained overflow never grew the embed capacity"
    assert all(p["tables"].keys() == final.keys() for p in two["trajectory"])

    out = dict(single_table=res, two_table=two)
    with open(OUT, "w") as f:
        json.dump(out, f, indent=2)
    print(f"OK: per-table plans diverged and overflow grew capacity; "
          f"wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
