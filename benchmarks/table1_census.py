"""Paper Table 1 analogue: dense/sparse parameter census + per-iteration
touched subset (α·V rows) per architecture, plus measured single-device step
time on the reduced config (the CPU-measurable throughput quantity)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.configs import (ALL_ARCHS, PAPER_ARCHS, RunConfig, SHAPES,
                           ShapeConfig, get_config, reduced)
from repro.core.runtime import Runtime
from repro.core.sparsity import run_census
from repro.core.transform import get_runner
from repro.data import SyntheticLM
from repro.models.model import build_model


def main():
    shape = SHAPES["train_4k"]
    rc = RunConfig()
    for arch in ALL_ARCHS + PAPER_ARCHS:
        cfg = get_config(arch)
        rt = Runtime(cfg, rc, shape)
        model = build_model(cfg, rt)
        census = run_census(model.specs(), cfg, shape, rc, replicas=16)
        derived = (f"dense_M={census.dense_params/1e6:.0f};"
                   f"sparse_M={census.sparse_params/1e6:.0f};"
                   f"alpha={census.alpha:.4f};"
                   f"subset_M={census.alpha*census.sparse_params/1e6:.2f}")
        # measured: reduced-config train step wall time (single device)
        small = reduced(cfg)
        tiny = ShapeConfig("bench", 64, 2, "train")
        runner = get_runner(small, tiny,
                            RunConfig(attention_impl="naive", remat="none"))
        ds = SyntheticLM(small.vocab_size, 64, 2, is_encdec=small.is_encdec,
                         frames_dim=small.d_model if small.family == "audio"
                         else 0, frames_len=16)
        batch = ds.batch(0)

        def step(b):
            # runner.run replaces the (donated) state each call
            return runner.run(b)["loss"]

        sec = time_fn(step, batch)
        emit(f"table1/{arch}", sec * 1e6,
             derived + f";reduced_tok_s={tiny.tokens/sec:.0f}")


if __name__ == "__main__":
    main()
