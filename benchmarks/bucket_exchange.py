"""Bucketed vs per-tensor dense-gradient exchange (core/buckets.py).

Runs the same distributed train step twice on 8 fake devices — per-tensor
(bucket_bytes=0) and bucketed — and reports, straight from the compiled
post-SPMD HLO (utils/hlo.py):

  * all-reduce count per step (the α·messages term bucketing removes),
  * per-chip collective wire bytes (must stay ~equal: bucketing fuses
    messages, it does not change what is exchanged),
  * max |loss| divergence over 3 steps (must be float-noise),
  * the cost-model seconds for both exchanges (HW.link_latency model),
  * median wall step time for both (CPU wall time is only a sanity signal).

Emits the CSV lines every benchmark emits plus machine-readable
``BENCH_exchange.json`` next to the repo root.

    PYTHONPATH=src python -m benchmarks.run buckets
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, run_with_devices

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_exchange.json")

_CODE = """
import time
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.plan import ParamPlan
from repro.core.transform import get_runner
from repro.data import SyntheticLM
from repro.utils.hlo import analyze_hlo

cfg = reduced(get_config("seamless-m4t-medium"))    # 26 dense param tensors
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32")
ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True,
                 frames_dim=cfg.d_model, frames_len=8)
mesh = make_mesh((8, 1), ("data", "model"))

def drive(bucket_bytes):
    with use_mesh(mesh):
        run = get_runner(cfg, shape,
                         RunConfig(**kw, bucket_bytes=bucket_bytes),
                         mesh=mesh)
        hlo = analyze_hlo(
            run.train_step.lower(run.state, ds.batch(0)).compile().as_text())
        losses, times = [], []
        for i in range(6):
            t0 = time.perf_counter()
            m = run.run(ds.batch(i))
            losses.append(float(m["loss"]))
            times.append(time.perf_counter() - t0)
        bp = run.plan.bucket_plan
        return {
            "all_reduce_count": hlo.collective_count.get("all-reduce", 0),
            "all_gather_count": hlo.collective_count.get("all-gather", 0),
            "collective_wire_bytes": hlo.collective_bytes,
            "losses": losses[:3],
            "median_step_s": sorted(times[3:])[len(times[3:]) // 2],
            "bucket_stats": bp.stats() if bp else None,
        }

flat = drive(0)
fused = drive(4 * 1024 * 1024)
n_dense = 26
print("RESULT:" + json.dumps({
    "n_dense_params": n_dense,
    "per_tensor": flat,
    "bucketed": fused,
    "loss_divergence": max(abs(a - b) for a, b in
                           zip(flat["losses"], fused["losses"])),
}))
"""


def main() -> None:
    res = run_with_devices(_CODE, devices=8)
    flat, fused = res["per_tensor"], res["bucketed"]
    stats = fused["bucket_stats"]
    emit("buckets/all_reduce_count",
         fused["all_reduce_count"],
         f"per_tensor={flat['all_reduce_count']};"
         f"n_dense={res['n_dense_params']}")
    emit("buckets/wire_bytes", fused["collective_wire_bytes"],
         f"per_tensor={flat['collective_wire_bytes']:.0f}")
    emit("buckets/est_exchange_us", stats["est_seconds"] * 1e6,
         f"per_tensor_us={stats['est_seconds_unbucketed'] * 1e6:.1f};"
         f"n_buckets={stats['n_buckets']}")
    emit("buckets/loss_divergence", res["loss_divergence"],
         f"steps=3;dtype=f32")
    with open(OUT, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
