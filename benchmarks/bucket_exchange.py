"""Bucketed vs per-tensor dense-gradient exchange (core/buckets.py).

Runs the same distributed train step on 8 fake devices in four
configurations and reports, straight from the compiled post-SPMD HLO
(utils/hlo.py):

  * per-tensor (bucket_bytes=0) vs bucketed: all-reduce count per step
    (the α·messages term bucketing removes) and per-chip collective wire
    bytes (must stay ~equal: bucketing fuses messages, it does not change
    what is exchanged), with max |loss| divergence over 3 steps at
    float-noise;
  * overlap on vs off at the same bucket layout (equal wire bytes):
    ready-order collectives inside the backward vs all collectives pinned
    after it — median wall step time for both and a 0.0 f32 loss
    divergence (the exchange math is identical, only the schedule moves);
  * flat ring vs hierarchical two-level on a multi-host ("pod") mesh with
    a fitted inter-tier profile: the cost-model seconds for both
    schedules, how many buckets the argmin sends two-level, and the loss
    divergence against the single-tier ring on the same mesh (reduction
    order changes, so float-noise rather than 0.0).

Emits the CSV lines every benchmark emits plus machine-readable
``BENCH_exchange.json`` next to the repo root.

    PYTHONPATH=src python -m benchmarks.run buckets
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, run_with_devices

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_exchange.json")

_CODE = """
import tempfile
import time
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.plan import ParamPlan
from repro.core.transform import get_runner
from repro.data import SyntheticLM
from repro.utils.hlo import analyze_hlo

cfg = reduced(get_config("seamless-m4t-medium"))    # 26 dense param tensors
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32")
ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True,
                 frames_dim=cfg.d_model, frames_len=8)
mesh = make_mesh((8, 1), ("data", "model"))
# same 8 devices regrouped as 2 hosts x 4 local replicas: the layout the
# two-level reduce-scatter -> inter psum -> all-gather schedule targets
pod_mesh = make_mesh((2, 4, 1), ("pod", "data", "model"))

# synthetic inter-host tier (DCN-ish: 12.5 GB/s, 10 us) — only the inter
# keys, so the intra tier keeps the roofline defaults.  On real hardware
# this file comes from `tools/profile_collectives.py fit`.
hw_path = tempfile.mktemp(suffix=".json")
with open(hw_path, "w") as f:
    json.dump({"inter_bw": 12.5e9, "inter_latency": 10e-6}, f)

def drive(bucket_bytes, overlap=True, hw_profile=None, on_mesh=None):
    m = on_mesh if on_mesh is not None else mesh
    with use_mesh(m):
        run = get_runner(cfg, shape,
                         RunConfig(**kw, bucket_bytes=bucket_bytes,
                                   overlap=overlap, hw_profile=hw_profile),
                         mesh=m)
        hlo = analyze_hlo(
            run.train_step.lower(run.state, ds.batch(0)).compile().as_text())
        losses, times = [], []
        for i in range(6):
            t0 = time.perf_counter()
            m_ = run.run(ds.batch(i))
            losses.append(float(m_["loss"]))
            times.append(time.perf_counter() - t0)
        bp = run.plan.bucket_plan
        # every candidate schedule priced under THIS run's resolved hw, so
        # the ring-vs-two-level contrast compares on the same constants
        prices = {}
        if bp is not None:
            from repro.core import cost_model
            for b in bp.buckets:
                for k, v in cost_model.dense_schedule_seconds(
                        b.nbytes, bp.dims, bp.hw).items():
                    prices[k] = prices.get(k, 0.0) + v
        return {
            "all_reduce_count": hlo.collective_count.get("all-reduce", 0),
            "all_gather_count": hlo.collective_count.get("all-gather", 0),
            "collective_wire_bytes": hlo.collective_bytes,
            "losses": losses[:3],
            "median_step_s": sorted(times[3:])[len(times[3:]) // 2],
            "bucket_stats": bp.stats() if bp else None,
            "schedule_prices_s": prices,
        }

def diverge(a, b):
    return max(abs(x - y) for x, y in zip(a["losses"], b["losses"]))

flat = drive(0)
fused = drive(4 * 1024 * 1024)

# overlap contrast: 256 KiB -> several buckets, so the ready-order
# schedule has something to interleave.  Same buckets, same wire bytes
# (the pinned baseline adds one f32 per gradient leaf per bucket).
ov = drive(256 * 1024, overlap=True)
base = drive(256 * 1024, overlap=False)

# topology contrast on the pod mesh: identical buckets priced and
# executed flat-ring (no profile) vs two-level (fitted inter tier)
ring_pod = drive(1024 * 1024, on_mesh=pod_mesh)
two_level = drive(1024 * 1024, hw_profile=hw_path, on_mesh=pod_mesh)

print("RESULT:" + json.dumps({
    "n_dense_params": 26,
    "per_tensor": flat,
    "bucketed": fused,
    "loss_divergence": diverge(flat, fused),
    "overlap": {
        "on": ov,
        "off": base,
        "loss_divergence": diverge(ov, base),
        "step_time_ratio": base["median_step_s"] / ov["median_step_s"],
    },
    "topology": {
        "ring": ring_pod,
        "two_level": two_level,
        "loss_divergence": diverge(ring_pod, two_level),
    },
}))
"""


def main() -> None:
    res = run_with_devices(_CODE, devices=8)
    flat, fused = res["per_tensor"], res["bucketed"]
    stats = fused["bucket_stats"]
    emit("buckets/all_reduce_count",
         fused["all_reduce_count"],
         f"per_tensor={flat['all_reduce_count']};"
         f"n_dense={res['n_dense_params']}")
    emit("buckets/wire_bytes", fused["collective_wire_bytes"],
         f"per_tensor={flat['collective_wire_bytes']:.0f}")
    emit("buckets/est_exchange_us", stats["est_seconds"] * 1e6,
         f"per_tensor_us={stats['est_seconds_unbucketed'] * 1e6:.1f};"
         f"n_buckets={stats['n_buckets']}")
    emit("buckets/loss_divergence", res["loss_divergence"],
         f"steps=3;dtype=f32")
    ov = res["overlap"]
    emit("buckets/overlap_step_us", ov["on"]["median_step_s"] * 1e6,
         f"no_overlap_us={ov['off']['median_step_s'] * 1e6:.1f};"
         f"ratio={ov['step_time_ratio']:.3f};"
         f"divergence={ov['loss_divergence']}")
    topo = res["topology"]
    ring_s, two_s = topo["ring"]["bucket_stats"], topo["two_level"]["bucket_stats"]
    prices = topo["two_level"]["schedule_prices_s"]     # same fitted hw
    emit("buckets/two_level_est_us", prices["two_level"] * 1e6,
         f"ring_same_hw_us={prices['ring'] * 1e6:.1f};"
         f"n_two_level={two_s['n_two_level']};hosts={two_s['hosts']}")
    # structural smoke: fusing must cut launches at ~equal wire bytes, the
    # overlap schedule must be math-identical, and the fitted inter tier
    # must actually flip buckets onto the two-level schedule
    assert fused["all_reduce_count"] < flat["all_reduce_count"]
    assert res["loss_divergence"] < 2e-5
    assert ov["loss_divergence"] == 0.0, ov["loss_divergence"]
    assert ov["on"]["bucket_stats"]["overlap"] is True
    assert ov["off"]["bucket_stats"]["overlap"] is False
    assert two_s["n_two_level"] >= 1 and two_s["hosts"] == 2
    assert ring_s["n_two_level"] == 0
    assert prices["two_level"] < prices["ring"], prices
    assert topo["loss_divergence"] < 2e-5
    with open(OUT, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
