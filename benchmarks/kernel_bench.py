"""Kernel micro-benchmarks: interpret-mode correctness timing is meaningless
for perf, so we report the kernel's analytic VMEM working set + MXU-aligned
tile shapes and the wall time of the *reference* path on CPU (the quantity
that is measurable here), per shape.

Plus the PR-level numbers, written to ``BENCH_kernels.json``:

  * fused bucket-apply vs per-param optimizer apply (optim/optimizer.py
    ``update_fused``): wall time over the same flat post-psum buffers — the
    per-param path pays the exchange boundary's per-leaf materialisation
    before the update (modelled as a separate jitted unflatten stage);
    bit-equality of the resulting states is asserted;
  * the measured autotune sweep (kernels/autotune.py) on a small shape: the
    argmin is taken over a candidate set that always contains the fixed
    block 0, so tuned can never lose to fixed — asserted — plus the
    roofline ranking at TPU constants for a production-sized table (what
    the sweep targets on real hardware);
  * distributed switch contrasts on 8 fake devices: fused_apply on/off and
    kernel_autotune on/off (Pallas path, cache pre-seeded with a 128-lane
    feature tile) must both hold a 0.0 f32 loss divergence — neither switch
    may change the math, only the schedule/layout.

    PYTHONPATH=src python -m benchmarks.run kernels
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, run_with_devices, time_fn
from repro.kernels import ref

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")


def _ref_paths() -> None:
    for (b, s, h, d) in [(1, 512, 8, 64), (1, 1024, 8, 128)]:
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, s, h, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, s, h, d), jnp.bfloat16)
        fn = jax.jit(lambda a, b2, c: ref.flash_attention_ref(a, b2, c))
        sec = time_fn(fn, q, k, v)
        vmem_kb = (128 * d * 2 * 3 + 128 * d * 4 + 128 * 8) / 1024
        emit(f"kernels/flash_ref/b{b}s{s}h{h}d{d}", sec * 1e6,
             f"kernel_vmem_kb={vmem_kb:.0f};blocks=128x128")
    # sparse hot path: PS pull (embed_gather) / push (embed_scatter_add).
    # Interpret-mode wall time is meaningless, so we time the jnp reference
    # (what a TPU-less run executes) and report the kernel's analytic DMA
    # working set: ids live in SMEM, one (1, E) row block moves per grid
    # step — n_ids·E·itemsize streamed, never the (Vs, E) table.
    for (vs, e, n) in [(4096, 512, 1024), (32768, 1024, 4096)]:
        ks = jax.random.split(jax.random.key(2), 3)
        table = jax.random.normal(ks[0], (vs, e), jnp.float32)
        ids = jax.random.randint(ks[1], (n,), -vs // 2, 2 * vs)
        rows = jax.random.normal(ks[2], (n, e), jnp.float32)
        uids = jnp.sort(jnp.unique(ids, size=n, fill_value=2 * vs))
        gfn = jax.jit(lambda t, i: ref.embed_gather_ref(t, i, 0))
        sec = time_fn(gfn, table, ids)
        emit(f"kernels/embed_gather_ref/v{vs}e{e}n{n}", sec * 1e6,
             f"dma_kb={n * e * 4 / 1024:.0f};ids_smem_kb={n * 4 / 1024:.0f}")
        sfn = jax.jit(lambda i, r: ref.embed_scatter_add_ref(i, r, vs))
        sec = time_fn(sfn, uids, rows)
        emit(f"kernels/embed_scatter_ref/v{vs}e{e}n{n}", sec * 1e6,
             f"dma_kb={n * e * 4 / 1024:.0f};blocks=1x{e}")
    for (b, s, h, e) in [(2, 512, 4, 64)]:
        ks = jax.random.split(jax.random.key(1), 5)
        r = jax.random.normal(ks[0], (b, s, h, e), jnp.float32)
        kk = jax.random.normal(ks[1], (b, s, h, e), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, h, e), jnp.float32)
        lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, e)) * 0.3 - 1)
        u = jax.random.normal(ks[4], (h, e)) * 0.1
        st = jnp.zeros((b, h, e, e), jnp.float32)
        fn = jax.jit(lambda *a: ref.wkv_ref(*a)[0])
        sec = time_fn(fn, r, kk, v, lw, u, st)
        emit(f"kernels/wkv_ref/b{b}s{s}h{h}e{e}", sec * 1e6,
             f"state_vmem_kb={e*e*4/1024:.0f};chunk=32")


def _fused_apply_bench() -> dict:
    """Optimizer apply over the same post-psum flat buffers. The non-fused
    step materialises per-leaf gradient arrays at the manual exchange
    region's boundary (one out_spec per leaf) before the optimizer walks
    them — modelled here as two jitted stages (unflatten, then update), so
    the leaf arrays hit memory exactly as the shard_map boundary forces
    them to. The fused path reads the flat buffers directly against the
    bucket-fused m/v layout in a single stage — the unflatten/reflatten
    write+read of the full param footprint never happens. Same math —
    states must be bitwise equal."""
    import numpy as np
    from repro.core.buckets import Bucket, BucketPlan
    from repro.optim.optimizer import adamw, fuse_state, unfuse_state

    shape, n_leaves, per_bucket = (512, 512), 16, 4
    sz = shape[0] * shape[1]
    ks = jax.random.split(jax.random.key(3), 2 * n_leaves)
    params = {f"w{i:02d}": jax.random.normal(ks[i], shape, jnp.float32)
              for i in range(n_leaves)}
    bufs = [jnp.concatenate(
        [jax.random.normal(ks[n_leaves + i], (sz,), jnp.float32)
         for i in range(k * per_bucket, (k + 1) * per_bucket)])
        for k in range(n_leaves // per_bucket)]
    buckets = [Bucket(key=("allreduce", "float32", ()),
                      idx=tuple(range(k * per_bucket, (k + 1) * per_bucket)),
                      sizes=(sz,) * per_bucket, nbytes=per_bucket * sz * 4)
               for k in range(n_leaves // per_bucket)]
    bp = BucketPlan(buckets=buckets, batch_axes=("data",), replicas=1,
                    n_params=n_leaves, wire_bytes=n_leaves * sz * 4,
                    bucket_bytes=per_bucket * sz * 4)
    opt = adamw(1e-2, weight_decay=0.1, clip_norm=1.0)
    _, tdef = jax.tree_util.tree_flatten(params)

    def unflatten(bufs):
        g = []
        for k, b in enumerate(bp.buckets):
            off = 0
            for _, s in zip(b.idx, b.sizes):
                g.append(bufs[k][off:off + s].reshape(shape))
                off += s
        return jax.tree_util.tree_unflatten(tdef, g)

    unflat = jax.jit(unflatten)
    apply_pp = jax.jit(opt.update)

    def pp(s, bufs):
        # two stages: the leaf grads materialise in between, as they do at
        # the exchange region's per-leaf output boundary in the real step
        return apply_pp(s, unflat(bufs))

    fu = jax.jit(lambda s, bufs: opt.update_fused(s, s.params, bufs, bp))
    state_pp = opt.init(params)
    state_fu = fuse_state(opt.init(params), bp)
    got_pp, _ = pp(state_pp, bufs)
    got_fu, _ = fu(state_fu, bufs)
    bit_equal = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(got_pp),
                        jax.tree.leaves(unfuse_state(got_fu, bp))))
    pp_s = time_fn(pp, state_pp, bufs)
    fu_s = time_fn(fu, state_fu, bufs)
    return {"per_param_us": pp_s * 1e6, "fused_us": fu_s * 1e6,
            "speedup": pp_s / fu_s, "bit_equal": bool(bit_equal),
            "n_leaves": n_leaves, "n_buckets": len(buckets),
            "param_bytes": n_leaves * sz * 4}


def _autotune_sweep() -> dict:
    """The measured sweep on a shape small enough for interpret mode, plus
    the roofline ranking at TPU constants for a production-sized table
    (the measured argmin decides on real hardware; here it demonstrates
    tuned-never-loses: block 0 is always a candidate)."""
    from repro.kernels import autotune
    from repro.utils import roofline

    vs, e, n = 4096, 256, 128
    out = {"sweep_shape": [vs, e, n], "kernels": {}}
    for kernel in ("gather", "scatter"):
        best, us = autotune.tune(kernel, vs, e, n, jnp.float32, cache={})
        fixed_us, tuned_us = us[0], us[best]
        out["kernels"][kernel] = {
            "best_block": best, "fixed_us": fixed_us, "tuned_us": tuned_us,
            "tok_s_tuned": n / (tuned_us * 1e-6),
            "tok_s_fixed": n / (fixed_us * 1e-6),
            "sweep_us": {str(k): v for k, v in us.items()},
        }
    # production shape, priced by the roofline the sweep prunes with
    pvs, pe, pn = 262144, 1024, 4096
    cands = roofline.kernel_tile_candidates(pe, 4)
    est = {be: roofline.embed_tile_seconds(pn, pe, be or pe, 4)
           for be in cands}
    best = min(est, key=est.get)
    out["roofline"] = {"shape": [pvs, pe, pn],
                       "candidates": cands,
                       "est_us": {str(k): v * 1e6 for k, v in est.items()},
                       "best_block": best,
                       "tuned_over_fixed": est[best] / est[0]}
    return out


_SWITCH_CODE = """
import os
import tempfile
import time
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.transform import get_runner
from repro.data import SyntheticLM

# d_model 256: wide enough for a 128-lane feature tile on the Pallas path
cfg = reduced(get_config("seamless-m4t-medium"), d_model=256, d_ff=512)
shape = ShapeConfig("bench", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32", comm_mode="mpi",
          bucket_bytes=256 * 1024)
ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True,
                 frames_dim=cfg.d_model, frames_len=8)
mesh = make_mesh((8, 1), ("data", "model"))

def drive(**over):
    with use_mesh(mesh):
        run = get_runner(cfg, shape, RunConfig(**{**kw, **over}), mesh=mesh)
        losses, times = [], []
        for i in range(5):
            t0 = time.perf_counter()
            m = run.run(ds.batch(i))
            losses.append(float(m["loss"]))
            times.append(time.perf_counter() - t0)
        return run, losses, sorted(times[2:])[1]

# fused bucket-apply on vs off: same exchange, same grads, only the
# optimizer-apply layout moves — a 0.0 f32 loss divergence is the contract
run_f, loss_f, t_f = drive(fused_apply=True)
run_p, loss_p, t_p = drive(fused_apply=False)
stats = run_f.plan.bucket_plan.stats()

# autotuned vs fixed tiles on the Pallas path: pre-seed the cache with a
# 128-lane feature tile (a measured sweep on this CPU backend would just
# re-pick 0 — interpret mode taxes every extra grid step), so the tuned
# run genuinely executes tiled kernels against the fixed-block baseline
cache = tempfile.mktemp(suffix=".json")
os.environ["REPRO_AUTOTUNE_CACHE"] = cache
run0, loss_fix, _ = drive(embed_impl="pallas")
from repro.kernels.autotune import _key
vs, e = run0.rt.padded_vocab, cfg.d_model
n = run0.plan.table_capacity["embed"]
seed = {_key(k, vs, e, n, "float32"):
        {"best": 128, "us": {"0": 2.0, "128": 1.0}}
        for k in ("gather", "scatter")}
with open(cache, "w") as f:
    json.dump(seed, f)
run1, loss_tuned, _ = drive(embed_impl="pallas", kernel_autotune=True)

print("RESULT:" + json.dumps({
    "fused": {
        "on_losses": loss_f[:3], "off_losses": loss_p[:3],
        "loss_divergence": max(abs(a - b)
                               for a, b in zip(loss_f, loss_p)),
        "step_us_on": t_f * 1e6, "step_us_off": t_p * 1e6,
        "fused_flag": bool(run_f.plan.fused_apply),
        "n_overlapped_sparse": stats["n_overlapped_sparse"],
    },
    "autotune": {
        "fixed_losses": loss_fix[:3], "tuned_losses": loss_tuned[:3],
        "loss_divergence": max(abs(a - b)
                               for a, b in zip(loss_fix, loss_tuned)),
        "tiles": list(run1.plan.table_tiles.get("embed", (0, 0))),
        "table": {"vs": vs, "e": e, "n": n},
    },
}))
"""


def main():
    _ref_paths()
    res = {"fused_apply": _fused_apply_bench(),
           "autotune": _autotune_sweep()}
    res["switches"] = run_with_devices(_SWITCH_CODE, devices=8)

    fa = res["fused_apply"]
    emit("kernels/apply_fused_us", fa["fused_us"],
         f"per_param_us={fa['per_param_us']:.1f};"
         f"speedup={fa['speedup']:.2f};bit_equal={fa['bit_equal']}")
    for kernel, r in res["autotune"]["kernels"].items():
        emit(f"kernels/autotune_{kernel}_us", r["tuned_us"],
             f"fixed_us={r['fixed_us']:.1f};block={r['best_block']};"
             f"tok_s={r['tok_s_tuned']:.0f}")
    ro = res["autotune"]["roofline"]
    emit("kernels/roofline_tile_us", ro["est_us"][str(ro["best_block"])],
         f"fixed_us={ro['est_us']['0']:.1f};block={ro['best_block']};"
         f"shape={'x'.join(str(x) for x in ro['shape'])}")
    sw = res["switches"]
    emit("kernels/fused_switch_divergence", sw["fused"]["loss_divergence"],
         f"steps=3;dtype=f32;"
         f"n_overlapped_sparse={sw['fused']['n_overlapped_sparse']}")
    emit("kernels/autotune_switch_divergence",
         sw["autotune"]["loss_divergence"],
         f"steps=3;dtype=f32;tiles={sw['autotune']['tiles']}")

    # the PR contracts: fused beats the per-param apply bitwise-identically,
    # the sweep's argmin can never lose to the fixed block, and neither
    # switch moves the f32 trajectory by a single ULP
    assert fa["bit_equal"], fa
    assert fa["fused_us"] < fa["per_param_us"], fa
    for r in res["autotune"]["kernels"].values():
        assert r["tuned_us"] <= r["fixed_us"], r
    assert ro["tuned_over_fixed"] <= 1.0, ro
    assert sw["fused"]["fused_flag"] is True
    assert sw["fused"]["n_overlapped_sparse"] >= 1, sw["fused"]
    assert sw["fused"]["loss_divergence"] == 0.0, sw["fused"]
    assert sw["autotune"]["tiles"] == [128, 128], sw["autotune"]
    assert sw["autotune"]["loss_divergence"] == 0.0, sw["autotune"]
    with open(OUT, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
