"""Kernel micro-benchmarks: interpret-mode correctness timing is meaningless
for perf, so we report the kernel's analytic VMEM working set + MXU-aligned
tile shapes and the wall time of the *reference* path on CPU (the quantity
that is measurable here), per shape."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels import ref


def main():
    for (b, s, h, d) in [(1, 512, 8, 64), (1, 1024, 8, 128)]:
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, s, h, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, s, h, d), jnp.bfloat16)
        fn = jax.jit(lambda a, b2, c: ref.flash_attention_ref(a, b2, c))
        sec = time_fn(fn, q, k, v)
        vmem_kb = (128 * d * 2 * 3 + 128 * d * 4 + 128 * 8) / 1024
        emit(f"kernels/flash_ref/b{b}s{s}h{h}d{d}", sec * 1e6,
             f"kernel_vmem_kb={vmem_kb:.0f};blocks=128x128")
    # sparse hot path: PS pull (embed_gather) / push (embed_scatter_add).
    # Interpret-mode wall time is meaningless, so we time the jnp reference
    # (what a TPU-less run executes) and report the kernel's analytic DMA
    # working set: ids live in SMEM, one (1, E) row block moves per grid
    # step — n_ids·E·itemsize streamed, never the (Vs, E) table.
    for (vs, e, n) in [(4096, 512, 1024), (32768, 1024, 4096)]:
        ks = jax.random.split(jax.random.key(2), 3)
        table = jax.random.normal(ks[0], (vs, e), jnp.float32)
        ids = jax.random.randint(ks[1], (n,), -vs // 2, 2 * vs)
        rows = jax.random.normal(ks[2], (n, e), jnp.float32)
        uids = jnp.sort(jnp.unique(ids, size=n, fill_value=2 * vs))
        gfn = jax.jit(lambda t, i: ref.embed_gather_ref(t, i, 0))
        sec = time_fn(gfn, table, ids)
        emit(f"kernels/embed_gather_ref/v{vs}e{e}n{n}", sec * 1e6,
             f"dma_kb={n * e * 4 / 1024:.0f};ids_smem_kb={n * 4 / 1024:.0f}")
        sfn = jax.jit(lambda i, r: ref.embed_scatter_add_ref(i, r, vs))
        sec = time_fn(sfn, uids, rows)
        emit(f"kernels/embed_scatter_ref/v{vs}e{e}n{n}", sec * 1e6,
             f"dma_kb={n * e * 4 / 1024:.0f};blocks=1x{e}")
    for (b, s, h, e) in [(2, 512, 4, 64)]:
        ks = jax.random.split(jax.random.key(1), 5)
        r = jax.random.normal(ks[0], (b, s, h, e), jnp.float32)
        kk = jax.random.normal(ks[1], (b, s, h, e), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, h, e), jnp.float32)
        lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, e)) * 0.3 - 1)
        u = jax.random.normal(ks[4], (h, e)) * 0.1
        st = jnp.zeros((b, h, e, e), jnp.float32)
        fn = jax.jit(lambda *a: ref.wkv_ref(*a)[0])
        sec = time_fn(fn, r, kk, v, lw, u, st)
        emit(f"kernels/wkv_ref/b{b}s{s}h{h}e{e}", sec * 1e6,
             f"state_vmem_kb={e*e*4/1024:.0f};chunk=32")


if __name__ == "__main__":
    main()
