"""Table 3 cost model: paper numbers, crossovers, and invariants."""
from types import SimpleNamespace

import pytest
from _prop import given, settings, st

from repro.core import cost_model as cm


def test_paper_table3_formulas():
    dims = cm.MeshDims(model=1, data=48, pod=1)     # paper: 48 GPUs
    b = 1.0
    assert cm.dense_allreduce_bytes(b, dims) == pytest.approx(2 * 47 / 48)
    assert cm.sparse_mpi_bytes(b, 0.01, dims) == pytest.approx(2 * 47 * 0.01)
    # PS pull for a sparse param ~ 2αb when the table is served off-worker
    dims_ps = cm.MeshDims(model=8, data=48)
    pull_push = cm.sparse_ps_bytes(b, 0.01, dims_ps)
    assert pull_push < cm.sparse_mpi_bytes(b, 0.01, dims_ps)


def test_hybrid_chooses_per_parameter():
    """The paper's headline: sparse params -> PS, dense params -> MPI."""
    dims = cm.MeshDims(model=16, data=16)
    m_dense, _ = cm.choose_method(b=1e9, sparse=False, alpha=1.0, dims=dims,
                                  comm_mode="hybrid")
    m_sparse, costs = cm.choose_method(b=1e9, sparse=True, alpha=0.01,
                                       dims=dims, comm_mode="hybrid")
    assert m_dense == "allreduce"
    assert m_sparse in ("ps", "ps_gather")
    assert costs[m_sparse] < costs["mpi_gatherv"]


def test_ps_variants_crossover():
    """Dense-shard push wins at high α, sparse gather push at low α."""
    dims = cm.MeshDims(model=16, data=16)
    lo = cm.sparse_ps_gather_bytes(1.0, 0.001, dims)
    hi_gather = cm.sparse_ps_gather_bytes(1.0, 0.5, dims)
    hi_dense = cm.sparse_ps_bytes(1.0, 0.5, dims)
    assert lo < cm.sparse_ps_bytes(1.0, 0.001, dims)
    assert hi_dense < hi_gather


@settings(max_examples=100, deadline=None)
@given(st.floats(1e3, 1e12), st.floats(1e-6, 1.0),
       st.integers(2, 64), st.integers(1, 64))
def test_costs_nonnegative_and_mpi_monotone_in_n(b, alpha, data, model):
    dims = cm.MeshDims(model=model, data=data)
    for fn in (cm.dense_allreduce_bytes, cm.dense_fsdp_bytes):
        assert fn(b, dims) >= 0
    assert cm.sparse_mpi_bytes(b, alpha, dims) >= 0
    # MPI gatherv cost grows with replica count; PS pull does not
    bigger = cm.MeshDims(model=model, data=data * 2)
    assert cm.sparse_mpi_bytes(b, alpha, bigger) > \
        cm.sparse_mpi_bytes(b, alpha, dims)


@settings(max_examples=60, deadline=None)
@given(st.floats(1e4, 1e11), st.floats(1e-6, 0.2))
def test_hybrid_never_worse_than_forced_modes(b, alpha):
    """The hybrid pick is argmin over its family by construction — in
    *seconds* (α·messages + bytes/bw), not raw bytes: a tiny gatherv can
    undercut on bytes yet lose to one fused all-reduce on launch count."""
    dims = cm.MeshDims(model=16, data=16, pod=2)
    method, _ = cm.choose_method(b=b, sparse=True, alpha=alpha,
                                 dims=dims, comm_mode="hybrid")
    secs = cm.method_seconds(b=b, alpha=alpha, dims=dims)
    assert secs[method] <= secs["mpi_gatherv"] + 1e-12


def test_latency_term_flips_small_params_dense():
    """Below the α·msg crossover, a sparse param rides the dense all-reduce
    (1 launch) even though gatherv moves fewer bytes."""
    dims = cm.MeshDims(model=1, data=8)
    small, _ = cm.choose_method(b=1e3, sparse=True, alpha=0.01, dims=dims,
                                comm_mode="hybrid", can_shard_rows=False)
    big, _ = cm.choose_method(b=1e9, sparse=True, alpha=0.01, dims=dims,
                              comm_mode="hybrid", can_shard_rows=False)
    assert small == "allreduce"
    assert big == "mpi_gatherv"


def test_exchange_seconds_rewards_fusion():
    """The bucketing argmin: same bytes in fewer messages is never slower,
    and strictly faster whenever messages actually drop."""
    total = 64 * 2**20
    fused = cm.exchange_seconds(total, 2)
    per_tensor = cm.exchange_seconds(total, 40)
    assert fused < per_tensor
    assert per_tensor - fused == pytest.approx(
        38 * cm.HW.link_latency, rel=1e-9)


def test_resolve_hw_link_latency_override():
    """RunConfig.link_latency=0 recovers the pure-byte Table-3 argmin
    without mutating the module-level HW."""
    rc = SimpleNamespace(link_latency=0.0)
    hw = cm.resolve_hw(rc)
    assert hw.link_latency == 0.0
    assert cm.HW.link_latency > 0                 # global untouched
    assert cm.resolve_hw(None) is cm.HW
    assert cm.resolve_hw(SimpleNamespace(link_latency=None)) is cm.HW
    dims = cm.MeshDims(model=1, data=8)
    # with α pinned to zero the tiny-param flip disappears
    m, _ = cm.choose_method(b=1e3, sparse=True, alpha=0.01, dims=dims,
                            comm_mode="hybrid", can_shard_rows=False, hw=hw)
    assert m == "mpi_gatherv"


HIER = cm.Hardware(inter_bw=12.5e9, inter_latency=10e-6)


def test_single_host_reduces_exactly_to_flat_model():
    """The hierarchy is strictly additive: with hosts == 1 (or the inter
    constants unset) every priced quantity equals the flat α + β·b model,
    bit for bit."""
    for b in (256.0, 1e5, 1e8):
        for hw in (cm.HW, HIER):
            dims = cm.MeshDims(model=1, data=8, hosts=1)
            assert cm.span_tier(dims, hw) == "intra"
            secs = cm.dense_schedule_seconds(b, dims, hw)
            assert set(secs) == {"ring"}
            assert secs["ring"] == cm.exchange_seconds(
                cm.dense_allreduce_bytes(b, dims), 1)
            assert cm.method_seconds(b=b, alpha=0.01, dims=dims, hw=hw) == \
                cm.method_seconds(b=b, alpha=0.01, dims=dims, hw=cm.HW)
        # multi-host but flat hardware: still the intra tier, still flat
        multi = cm.MeshDims(model=1, data=8, hosts=2)
        assert cm.span_tier(multi, cm.HW) == "intra"
        assert cm.dense_schedule_seconds(b, multi, cm.HW) == \
            cm.dense_schedule_seconds(b, cm.MeshDims(data=8), cm.HW)


def test_two_level_schedule_crossover():
    """Bandwidth-bound buckets prefer the two-level schedule (only b/L
    bytes cross the slow tier); latency-bound ones keep the flat ring
    (the extra 2α₁ launches dominate)."""
    dims = cm.MeshDims(model=1, data=8, hosts=2)        # L = 4
    big, secs_big = cm.choose_dense_schedule(1 << 20, dims, HIER)
    small, secs_small = cm.choose_dense_schedule(256, dims, HIER)
    assert big == "two_level" and small == "ring"
    assert secs_big["two_level"] < secs_big["ring"]
    # docstring formula, verbatim
    b, h, loc = float(1 << 20), 2, 4
    expect = (2 * HIER.link_latency + HIER.inter_latency
              + 2 * (loc - 1) / loc * b / HIER.link_bw
              + 2 * (h - 1) / h * (b / loc) / HIER.inter_bw)
    assert secs_big["two_level"] == pytest.approx(expect, rel=1e-12)


def test_inter_alpha_flips_a_method():
    """The hierarchical model changes planner decisions, not just prices:
    a sparse param whose gatherv (2 launches) beats one dense all-reduce
    at the intra α loses the argmin once every message pays the inter α."""
    dims1 = cm.MeshDims(model=1, data=8, hosts=1)
    dims2 = cm.MeshDims(model=1, data=8, hosts=2)
    costly = cm.Hardware(inter_bw=12.5e9, inter_latency=200e-6)
    b, alpha = 2e6, 0.01
    m1, _ = cm.choose_method(b=b, sparse=True, alpha=alpha, dims=dims1,
                             comm_mode="hybrid", can_shard_rows=False,
                             hw=costly)
    m2, _ = cm.choose_method(b=b, sparse=True, alpha=alpha, dims=dims2,
                             comm_mode="hybrid", can_shard_rows=False,
                             hw=costly)
    assert m1 == "mpi_gatherv"       # fewer bytes, cheap launches
    assert m2 == "allreduce"         # inter α makes the 2nd launch too dear


def test_local_replicas_and_mesh_hosts():
    assert cm.MeshDims(data=8, hosts=2).local_replicas == 4
    assert cm.MeshDims(data=8, hosts=1).local_replicas == 8
    assert cm.MeshDims(data=8, hosts=3).local_replicas == 1   # non-divisible
    fake = SimpleNamespace(shape={"pod": 2, "data": 4},
                           axis_names=("pod", "data"))
    assert cm.mesh_hosts(fake) == 2
    assert cm.mesh_hosts(None) == 1
    assert cm.mesh_hosts(SimpleNamespace(shape={"data": 8},
                                         axis_names=("data",))) == 1


def test_load_hw_profile_overlay(tmp_path):
    prof = tmp_path / "hw_profile.json"
    prof.write_text('{"link_bw": 45e9, "link_latency": 2e-6,'
                    ' "inter_bw": 10e9, "inter_latency": 15e-6,'
                    ' "fit_residual": 0.01}')       # extra keys ignored
    rc = SimpleNamespace(hw_profile=str(prof), link_latency=None)
    hw = cm.resolve_hw(rc)
    assert hw.link_bw == 45e9 and hw.link_latency == 2e-6
    assert hw.inter_bw == 10e9 and hw.inter_latency == 15e-6
    assert hw.hierarchical
    assert cm.HW.link_bw != 45e9                 # global untouched
    # link_latency still wins over the profile (most specific last)
    rc2 = SimpleNamespace(hw_profile=str(prof), link_latency=0.0)
    assert cm.resolve_hw(rc2).link_latency == 0.0


def test_method_messages_counts():
    dims = cm.MeshDims(model=8, data=4)
    assert cm.method_messages("allreduce", dims) == 1
    assert cm.method_messages("fsdp", dims) == 2
    assert cm.method_messages("ps", dims) == 2           # pull psum + push psum
    assert cm.method_messages("ps_gather", dims) == 3    # pull + (ids, rows)
    assert cm.method_messages("mpi_gatherv", dims) == 2
    one = cm.MeshDims(model=1, data=1)
    for m in ("allreduce", "fsdp", "ps", "ps_gather", "mpi_gatherv"):
        assert cm.method_messages(m, one) == 0
