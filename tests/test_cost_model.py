"""Table 3 cost model: paper numbers, crossovers, and invariants."""
from types import SimpleNamespace

import pytest
from _prop import given, settings, st

from repro.core import cost_model as cm


def test_paper_table3_formulas():
    dims = cm.MeshDims(model=1, data=48, pod=1)     # paper: 48 GPUs
    b = 1.0
    assert cm.dense_allreduce_bytes(b, dims) == pytest.approx(2 * 47 / 48)
    assert cm.sparse_mpi_bytes(b, 0.01, dims) == pytest.approx(2 * 47 * 0.01)
    # PS pull for a sparse param ~ 2αb when the table is served off-worker
    dims_ps = cm.MeshDims(model=8, data=48)
    pull_push = cm.sparse_ps_bytes(b, 0.01, dims_ps)
    assert pull_push < cm.sparse_mpi_bytes(b, 0.01, dims_ps)


def test_hybrid_chooses_per_parameter():
    """The paper's headline: sparse params -> PS, dense params -> MPI."""
    dims = cm.MeshDims(model=16, data=16)
    m_dense, _ = cm.choose_method(b=1e9, sparse=False, alpha=1.0, dims=dims,
                                  comm_mode="hybrid")
    m_sparse, costs = cm.choose_method(b=1e9, sparse=True, alpha=0.01,
                                       dims=dims, comm_mode="hybrid")
    assert m_dense == "allreduce"
    assert m_sparse in ("ps", "ps_gather")
    assert costs[m_sparse] < costs["mpi_gatherv"]


def test_ps_variants_crossover():
    """Dense-shard push wins at high α, sparse gather push at low α."""
    dims = cm.MeshDims(model=16, data=16)
    lo = cm.sparse_ps_gather_bytes(1.0, 0.001, dims)
    hi_gather = cm.sparse_ps_gather_bytes(1.0, 0.5, dims)
    hi_dense = cm.sparse_ps_bytes(1.0, 0.5, dims)
    assert lo < cm.sparse_ps_bytes(1.0, 0.001, dims)
    assert hi_dense < hi_gather


@settings(max_examples=100, deadline=None)
@given(st.floats(1e3, 1e12), st.floats(1e-6, 1.0),
       st.integers(2, 64), st.integers(1, 64))
def test_costs_nonnegative_and_mpi_monotone_in_n(b, alpha, data, model):
    dims = cm.MeshDims(model=model, data=data)
    for fn in (cm.dense_allreduce_bytes, cm.dense_fsdp_bytes):
        assert fn(b, dims) >= 0
    assert cm.sparse_mpi_bytes(b, alpha, dims) >= 0
    # MPI gatherv cost grows with replica count; PS pull does not
    bigger = cm.MeshDims(model=model, data=data * 2)
    assert cm.sparse_mpi_bytes(b, alpha, bigger) > \
        cm.sparse_mpi_bytes(b, alpha, dims)


@settings(max_examples=60, deadline=None)
@given(st.floats(1e4, 1e11), st.floats(1e-6, 0.2))
def test_hybrid_never_worse_than_forced_modes(b, alpha):
    """The hybrid pick is argmin over its family by construction — in
    *seconds* (α·messages + bytes/bw), not raw bytes: a tiny gatherv can
    undercut on bytes yet lose to one fused all-reduce on launch count."""
    dims = cm.MeshDims(model=16, data=16, pod=2)
    method, _ = cm.choose_method(b=b, sparse=True, alpha=alpha,
                                 dims=dims, comm_mode="hybrid")
    secs = cm.method_seconds(b=b, alpha=alpha, dims=dims)
    assert secs[method] <= secs["mpi_gatherv"] + 1e-12


def test_latency_term_flips_small_params_dense():
    """Below the α·msg crossover, a sparse param rides the dense all-reduce
    (1 launch) even though gatherv moves fewer bytes."""
    dims = cm.MeshDims(model=1, data=8)
    small, _ = cm.choose_method(b=1e3, sparse=True, alpha=0.01, dims=dims,
                                comm_mode="hybrid", can_shard_rows=False)
    big, _ = cm.choose_method(b=1e9, sparse=True, alpha=0.01, dims=dims,
                              comm_mode="hybrid", can_shard_rows=False)
    assert small == "allreduce"
    assert big == "mpi_gatherv"


def test_exchange_seconds_rewards_fusion():
    """The bucketing argmin: same bytes in fewer messages is never slower,
    and strictly faster whenever messages actually drop."""
    total = 64 * 2**20
    fused = cm.exchange_seconds(total, 2)
    per_tensor = cm.exchange_seconds(total, 40)
    assert fused < per_tensor
    assert per_tensor - fused == pytest.approx(
        38 * cm.HW.link_latency, rel=1e-9)


def test_resolve_hw_link_latency_override():
    """RunConfig.link_latency=0 recovers the pure-byte Table-3 argmin
    without mutating the module-level HW."""
    rc = SimpleNamespace(link_latency=0.0)
    hw = cm.resolve_hw(rc)
    assert hw.link_latency == 0.0
    assert cm.HW.link_latency > 0                 # global untouched
    assert cm.resolve_hw(None) is cm.HW
    assert cm.resolve_hw(SimpleNamespace(link_latency=None)) is cm.HW
    dims = cm.MeshDims(model=1, data=8)
    # with α pinned to zero the tiny-param flip disappears
    m, _ = cm.choose_method(b=1e3, sparse=True, alpha=0.01, dims=dims,
                            comm_mode="hybrid", can_shard_rows=False, hw=hw)
    assert m == "mpi_gatherv"


def test_method_messages_counts():
    dims = cm.MeshDims(model=8, data=4)
    assert cm.method_messages("allreduce", dims) == 1
    assert cm.method_messages("fsdp", dims) == 2
    assert cm.method_messages("ps", dims) == 2           # pull psum + push psum
    assert cm.method_messages("ps_gather", dims) == 3    # pull + (ids, rows)
    assert cm.method_messages("mpi_gatherv", dims) == 2
    one = cm.MeshDims(model=1, data=1)
    for m in ("allreduce", "fsdp", "ps", "ps_gather", "mpi_gatherv"):
        assert cm.method_messages(m, one) == 0
