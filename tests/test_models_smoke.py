"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED same-family config, runs one forward + one train step on CPU with
shape and finiteness assertions. The FULL configs are exercised only by the
dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ALL_ARCHS, PAPER_ARCHS, RunConfig, ShapeConfig,
                           get_config, reduced)
from repro.core.runtime import Runtime
from repro.core.transform import analyze, get_runner
from repro.data import SyntheticLM
from repro.models.model import build_model

RC = RunConfig(attention_impl="naive", remat="none")
SHAPE = ShapeConfig("tiny", seq_len=32, global_batch=2, kind="train")


def _dataset(cfg):
    return SyntheticLM(cfg.vocab_size, SHAPE.seq_len, SHAPE.global_batch,
                       is_encdec=cfg.is_encdec,
                       frames_dim=cfg.d_model if cfg.family == "audio" else 0,
                       frames_len=8)


@pytest.mark.parametrize("arch", ALL_ARCHS + PAPER_ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    runner = get_runner(cfg, SHAPE, RC)
    ds = _dataset(cfg)
    m = runner.run(ds.batch(0))
    assert np.isfinite(float(m["loss"])), (arch, m)
    m = runner.run(ds.batch(1))
    assert np.isfinite(float(m["loss"])), (arch, m)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes(arch):
    cfg = reduced(get_config(arch))
    rt = Runtime(cfg, RC, SHAPE)
    model = build_model(cfg, rt)
    params = model.init(jax.random.key(0))
    batch = {k: jnp.asarray(v) for k, v in _dataset(cfg).batch(0).items()}
    logits, _, _ = model.prefill_fn(params, batch)
    assert logits.shape[0] == SHAPE.global_batch
    assert logits.shape[1] == SHAPE.seq_len
    assert logits.shape[2] >= cfg.vocab_size          # padded vocab allowed
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "rwkv6-7b",
                                  "hymba-1.5b", "grok-1-314b",
                                  "seamless-m4t-medium"])
def test_decode_step_smoke(arch):
    """One serve_step against a small cache: shapes + finiteness."""
    cfg = reduced(get_config(arch))
    rt = Runtime(cfg, RC, ShapeConfig("d", 32, 2, "decode"))
    model = build_model(cfg, rt)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(2, 32)
    toks = jnp.zeros((2, 1), jnp.int32)
    logits, new_cache = model.decode_fn(params, cache, toks,
                                        jnp.asarray(3, jnp.int32))
    assert logits.shape[:2] == (2, 1)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_decode_matches_prefill_logits():
    """Teacher-forced decode reproduces the prefill logits (KV-cache path
    equals the parallel path) — the serving-correctness invariant."""
    cfg = reduced(get_config("phi3-medium-14b"))
    rc32 = RunConfig(attention_impl="naive", remat="none",
                     param_dtype="float32", compute_dtype="float32")
    rt = Runtime(cfg, rc32, ShapeConfig("d", 16, 2, "decode"))
    model = build_model(cfg, rt)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    full_logits, _, _ = model.prefill_fn(params, {"tokens": toks})
    cache = model.init_cache(2, 16)
    outs = []
    for t in range(8):
        lg, cache = model.decode_fn(params, cache, toks[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)
