"""The shard() API invariants: disjoint, union-complete, resumable."""
import numpy as np
from _prop import given, settings, st

from repro.data.pipeline import SyntheticLM, shard


def test_shard_partitions_global_batch():
    ds = SyntheticLM(vocab=100, seq_len=8, global_batch=8, seed=3)
    full = ds.batch(0)["tokens"]
    parts = [shard(ds, i, 4).batch(0)["tokens"] for i in range(4)]
    assert all(p.shape == (2, 8) for p in parts)


def test_deterministic_and_step_addressed():
    ds = SyntheticLM(vocab=1000, seq_len=16, global_batch=4, seed=1)
    a, b = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4, 8]))
def test_replica_union_equals_global_stream(step, nrep):
    """Disjoint slices whose union is exactly the single-device batch —
    the paper's correctness precondition for shard()."""
    ds = SyntheticLM(vocab=50_000, seq_len=32, global_batch=8, seed=0)
    full = ds.batch(step)["tokens"]
    for r in range(nrep):
        part = shard(ds, r, nrep).batch(step)["tokens"]
        np.testing.assert_array_equal(part, full[r::nrep])


def test_labels_are_shifted_tokens():
    ds = SyntheticLM(vocab=100, seq_len=16, global_batch=2, seed=2)
    b = ds.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_zipf_distribution_is_skewed():
    """Sparsity realism: low ids should dominate (α < uniform-draw α)."""
    ds = SyntheticLM(vocab=10_000, seq_len=512, global_batch=8, seed=0)
    t = ds.batch(0)["tokens"].ravel()
    assert (t < 100).mean() > 0.5
    assert len(np.unique(t)) < 0.5 * len(t)
