"""Shared test fixtures. NOTE: no XLA_FLAGS here — the main test session
keeps its single CPU device; multi-device tests run in subprocesses
(see distributed_run)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="session")
def tiny_shape():
    from repro.configs import ShapeConfig
    return ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")


def distributed_run(code: str, devices: int = 8, timeout: int = 300) -> dict:
    """Run `code` in a subprocess with N fake devices; the snippet must
    print a single json line prefixed with RESULT:."""
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import json
        import jax
        import numpy as np
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, \
        f"subprocess failed:\nSTDOUT:{proc.stdout[-3000:]}\nSTDERR:{proc.stderr[-3000:]}"
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT line in output: {proc.stdout[-2000:]}")
