"""Shared test fixtures. NOTE: no XLA_FLAGS here — the main test session
keeps its single CPU device; multi-device tests run in subprocesses
(see distributed_run)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "distributed: spawns a multi-device subprocess (deselect with "
        "-m 'not distributed' for a fast single-device pass)")


@pytest.fixture(scope="session")
def tiny_shape():
    from repro.configs import ShapeConfig
    return ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")


# Every snippet gets the version-portable sharding helpers; snippets must
# never spell the version-dependent sharding API (AxisType / set_mesh /
# shard_map) via jax directly — repro.compat owns those spellings, enforced
# by test_compat.py.
_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
import json
import jax
import numpy as np
from repro.compat import (AxisType, NamedSharding, PartitionSpec,
                          make_mesh, use_mesh)
P = PartitionSpec
"""


def distributed_run(code: str, devices: int = 8, timeout: int = 300) -> dict:
    """Run `code` in a subprocess with N fake devices; the snippet must
    print a single json line prefixed with RESULT:."""
    prelude = textwrap.dedent(_PRELUDE.format(devices=devices))
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        # full traceback — a truncated tail used to hide the actual import
        # error behind "assert 1 == 0"
        pytest.fail(
            f"distributed subprocess exited {proc.returncode}\n"
            f"--- STDOUT ---\n{proc.stdout}\n--- STDERR ---\n{proc.stderr}",
            pytrace=False)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT line in output: {proc.stdout[-2000:]}")
