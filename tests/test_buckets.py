"""core/buckets.py assignment logic — pure units, no devices needed."""
from types import SimpleNamespace

import jax.numpy as jnp
import pytest

from repro.compat import PartitionSpec as P
from repro.core import buckets
from repro.core.plan import ParamPlan, Plan


def fake_mesh(**axes):
    return SimpleNamespace(shape=dict(axes), axis_names=tuple(axes))


def fake_rt(mesh, *, bucket_bytes=4 * 2**20, kind="train", batch=("data",),
            replicas=8, experts=0, tied=False, opsw=True,
            param_dtype="float32", wire_dtype="float32"):
    return SimpleNamespace(
        mesh=mesh,
        batch_axes=batch,
        replicas=replicas,
        param_dtype=jnp.dtype(param_dtype),
        wire_dtype=jnp.dtype(wire_dtype),
        model_cfg=SimpleNamespace(n_experts=experts, tie_embeddings=tied),
        shape_cfg=SimpleNamespace(kind=kind),
        run_cfg=SimpleNamespace(bucket_bytes=bucket_bytes, opsw=opsw),
    )


def leaf(name, shape, method="allreduce", sparse=False, pspec=P(None, None),
         dtype_bytes=4):
    n = 1
    for d in shape:
        n *= d
    return ParamPlan(name=name, method=method, pspec=pspec, opt_pspec=pspec,
                     wire_dtype=jnp.float32, sparse=sparse,
                     bytes=n * dtype_bytes)


def fake_plan(leaves, mesh, embed_method="allreduce"):
    return Plan(model_cfg=None, run_cfg=None, shape_cfg=None, mesh=mesh,
                rules=None, params={p.name: p for p in leaves},
                embed_method=embed_method)


MESH = fake_mesh(data=8, model=1)


def test_effective_pspec_drops_size1_axes():
    assert buckets._effective_pspec(P("model", None), MESH) == ()
    assert buckets._effective_pspec(P(None, "model"), MESH) == ()
    assert buckets._effective_pspec(P(("model",), None), MESH) == ()
    big = fake_mesh(data=2, model=4)
    assert buckets._effective_pspec(P("model", None), big) == ("model",)


def test_assign_groups_and_fills_by_bucket_bytes():
    leaves = [leaf(f"w{i}", (64, 64)) for i in range(10)]   # 16 KiB each
    plan = fake_plan(leaves, MESH)
    rt = fake_rt(MESH, bucket_bytes=4 * 16384)              # 4 params/bucket
    bp = buckets.assign_buckets(plan, rt)
    assert bp is not None
    assert [len(b.idx) for b in bp.buckets] == [4, 4, 2]
    assert bp.n_params == 10
    assert bp.wire_bytes == 10 * 16384
    # reverse-topological fill: bucket 0 holds the LAST-forward parameters,
    # whose gradients the backward produces first (overlap issue order)
    assert bp.buckets[0].idx == (9, 8, 7, 6)
    assert bp.buckets[-1].idx == (1, 0)
    # one flat buffer each, element counts preserved
    assert all(b.nbytes == sum(b.sizes) * 4 for b in bp.buckets)


def test_assign_single_bucket_when_under_cap():
    leaves = [leaf(f"w{i}", (8, 8), pspec=P("model", None) if i % 2 else
              P(None, "model")) for i in range(6)]
    plan = fake_plan(leaves, MESH)
    bp = buckets.assign_buckets(plan, fake_rt(MESH))
    # size-1 'model' shardings are physically identical -> one fused buffer,
    # members in reverse flatten order
    assert len(bp.buckets) == 1
    assert bp.buckets[0].idx == tuple(reversed(range(6)))


def test_sparse_methods_keep_their_own_exchange():
    leaves = [leaf("w0", (32, 32)),
              leaf("emb", (128, 32), method="mpi_gatherv", sparse=True)]
    plan = fake_plan(leaves, MESH, embed_method="mpi_gatherv")
    bp = buckets.assign_buckets(plan, fake_rt(MESH))
    assert bp.n_params == 1                      # the gatherv table stays out
    assert plan.embed_method == "mpi_gatherv"


def test_tied_gatherv_table_folds_into_the_bucket():
    leaves = [leaf("w0", (32, 32)),
              leaf("emb", (128, 32), method="mpi_gatherv", sparse=True)]
    plan = fake_plan(leaves, MESH, embed_method="mpi_gatherv")
    bp = buckets.assign_buckets(plan, fake_rt(MESH, tied=True))
    assert plan.embed_method == "allreduce"      # coherence flip
    assert bp.n_params == 2


@pytest.mark.parametrize("veto", [
    dict(bucket_bytes=0),
    dict(kind="decode"),
    dict(batch=(), replicas=1),
    dict(experts=8),                             # MoE opens its own shard_map
])
def test_gate_vetos(veto):
    plan = fake_plan([leaf("w0", (32, 32))], MESH)
    assert buckets.assign_buckets(plan, fake_rt(MESH, **veto)) is None


def test_gate_vetos_live_tp_axis_and_fsdp():
    tp = fake_mesh(data=2, model=4)
    plan = fake_plan([leaf("w0", (32, 32))], tp)
    assert buckets.assign_buckets(plan, fake_rt(tp, batch=("data",),
                                                replicas=2)) is None
    plan2 = fake_plan([leaf("w0", (32, 32), method="fsdp")], MESH)
    assert buckets.assign_buckets(plan2, fake_rt(MESH)) is None


def test_stats_charge_the_latency_model():
    leaves = [leaf(f"w{i}", (64, 64)) for i in range(10)]
    plan = fake_plan(leaves, MESH)
    bp = buckets.assign_buckets(plan, fake_rt(MESH))
    s = bp.stats()
    assert s["n_collectives_dense"] == 1
    assert s["n_collectives_unbucketed"] == 10
    saved = s["est_seconds_unbucketed"] - s["est_seconds"]
    assert saved == pytest.approx(9 * buckets.HW.link_latency)


def test_two_level_schedule_on_multi_host_mesh(tmp_path):
    prof = tmp_path / "hw.json"
    prof.write_text('{"inter_bw": 12.5e9, "inter_latency": 10e-6}')
    mesh = fake_mesh(pod=2, data=4, model=1)
    # 1 MiB bucket: bandwidth-dominated, two-level wins (only b/L crosses
    # the slow tier); 256 B bucket: latency-dominated, the extra 2α₁ of the
    # two-level schedule loses to the flat ring
    leaves = [leaf("big", (512, 512)), leaf("small", (8, 8))]
    plan = fake_plan(leaves, mesh)
    rt = fake_rt(mesh, batch=("pod", "data"), replicas=8,
                 bucket_bytes=1 << 20)
    rt.run_cfg.hw_profile = str(prof)
    bp = buckets.assign_buckets(plan, rt)
    assert bp.hosts == 2
    by_name = {b.idx: b.schedule for b in bp.buckets}
    assert by_name[(0,)] == "two_level"      # the 1 MiB buffer
    assert by_name[(1,)] == "ring"           # the 256 B buffer
    s = bp.stats()
    assert s["n_two_level"] == 1 and s["hosts"] == 2 and s["overlap"]


def test_single_host_mesh_keeps_flat_ring_even_with_profile(tmp_path):
    prof = tmp_path / "hw.json"
    prof.write_text('{"inter_bw": 12.5e9, "inter_latency": 10e-6}')
    leaves = [leaf("w0", (512, 512))]
    plan = fake_plan(leaves, MESH)
    rt = fake_rt(MESH)
    rt.run_cfg.hw_profile = str(prof)
    bp = buckets.assign_buckets(plan, rt)
    assert bp.hosts == 1
    assert all(b.schedule == "ring" for b in bp.buckets)
