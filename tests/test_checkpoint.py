"""Checkpointing: atomic roundtrip, async, GC, elastic mesh restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import distributed_run
from repro.checkpoint.ckpt import (AsyncCheckpointer, gc_checkpoints,
                                   latest_step, restore_checkpoint,
                                   save_checkpoint)
from repro.optim.optimizer import TrainState


def _state(seed=0):
    k = jax.random.key(seed)
    params = {"w": jax.random.normal(k, (8, 4), jnp.float32),
              "emb": jax.random.normal(jax.random.fold_in(k, 1), (16, 4),
                                       jnp.bfloat16)}
    return TrainState(step=jnp.asarray(3, jnp.int32), params=params,
                      m=jax.tree.map(lambda p: jnp.zeros(p.shape), params),
                      v=None, ema=None)


def test_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 3, s, extra={"hello": 1})
    got, step, extra = restore_checkpoint(str(tmp_path), s)
    assert step == 3 and extra == {"hello": 1}
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_atomicity_tmp_never_visible(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 1, s)
    # a stale .tmp from a crashed writer must not be listed or restored
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1
    _, step, _ = restore_checkpoint(str(tmp_path), s)
    assert step == 1


def test_gc_keeps_latest(tmp_path):
    s = _state()
    for i in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), i, s)
    gc_checkpoints(str(tmp_path), keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    s = _state()
    ck.save(5, s)
    ck.wait()
    assert ck.last_committed == 5
    got, step, _ = restore_checkpoint(str(tmp_path), s)
    assert step == 5


@pytest.mark.distributed
def test_elastic_restore_across_meshes(tmp_path):
    """Save on a 2x4 mesh, restore onto 8x1 and onto a single device —
    the node-failure / re-mesh path."""
    code = f"""
import jax.numpy as jnp
from repro.checkpoint.ckpt import save_checkpoint, restore_checkpoint

mesh = make_mesh((2, 4), ("data", "model"))
w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                   NamedSharding(mesh, P("data", "model")))
state = {{"w": w}}
save_checkpoint(r"{tmp_path}", 7, state)

mesh2 = make_mesh((8,), ("data",))
sh2 = {{"w": NamedSharding(mesh2, P("data", None))}}
got, step, _ = restore_checkpoint(r"{tmp_path}", state, shardings=sh2)
ok_mesh = bool((np.asarray(got["w"]) ==
                np.arange(64, dtype=np.float32).reshape(8, 8)).all())
got1, _, _ = restore_checkpoint(r"{tmp_path}", state)
ok_single = bool((np.asarray(got1["w"]) ==
                  np.arange(64, dtype=np.float32).reshape(8, 8)).all())
print("RESULT:" + json.dumps({{"mesh": ok_mesh, "single": ok_single,
                              "step": step}}))
"""
    res = distributed_run(code, devices=8)
    assert res == {"mesh": True, "single": True, "step": 7}


@pytest.mark.distributed
def test_trainer_remesh_preserves_state(tmp_path):
    """Elastic re-mesh: live state survives a mesh change (8 -> 4 devices),
    training continues."""
    code = """
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.data import SyntheticLM
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), layers=1)
shape = ShapeConfig("t", 16, 4, "train")
rc = RunConfig(attention_impl="naive", remat="none")
ds = SyntheticLM(cfg.vocab_size, 16, 4)
mesh8 = make_mesh((2, 4), ("data", "model"))
t = Trainer(cfg, shape, rc, TrainerConfig(total_steps=2), ds, mesh=mesh8)
with use_mesh(mesh8):
    t.run()
w_before = np.asarray(jax.device_get(jax.tree.leaves(t.state.params)[0]),
                      np.float32)
mesh4 = make_mesh((2, 2), ("data", "model"))
t.remesh(mesh4)
w_after = np.asarray(jax.device_get(jax.tree.leaves(t.state.params)[0]),
                     np.float32)
same = bool(np.allclose(w_before, w_after))
t.tcfg = TrainerConfig(total_steps=4)
with use_mesh(mesh4):
    t.run()
print("RESULT:" + json.dumps({"same": same, "step": t.step}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    assert res["same"] is True
    assert res["step"] == 4
