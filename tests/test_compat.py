"""repro.compat: mesh construction on the current JAX, capability probes,
and the fallback paths exercised by monkeypatching the probes — so both API
generations are covered no matter which JAX is installed."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.compat import shardmesh, version


# ---------------------------------------------------------------------------
# capability probes
# ---------------------------------------------------------------------------

def test_version_tuple_parses_current_jax():
    vt = compat.jax_version_tuple()
    assert len(vt) == 3 and all(isinstance(x, int) for x in vt)
    assert vt >= compat.MIN_SUPPORTED
    assert compat.supported()


def test_version_tuple_strips_dev_suffixes(monkeypatch):
    monkeypatch.setattr(jax, "__version__", "0.7.2.dev20250101")
    assert version.jax_version_tuple() == (0, 7, 2)
    monkeypatch.setattr(jax, "__version__", "0.4.37rc1")
    assert version.jax_version_tuple() == (0, 4, 37)
    monkeypatch.setattr(jax, "__version__", "1.0")
    assert version.jax_version_tuple() == (1, 0, 0)


def test_probes_match_installed_jax():
    assert version.has_axis_types() == hasattr(jax.sharding, "AxisType")
    assert version.has_set_mesh() == hasattr(jax, "set_mesh")
    assert version.has_top_level_shard_map() == hasattr(jax, "shard_map")
    caps = compat.capabilities()
    assert caps["jax_version"] == jax.__version__
    assert caps["explicit_sharding"] == compat.has_explicit_sharding()


# ---------------------------------------------------------------------------
# mesh construction on the current JAX (single device -> (1,) meshes only;
# multi-device construction is covered by every `distributed` test)
# ---------------------------------------------------------------------------

def test_make_mesh_current_jax():
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == 1


def test_make_mesh_accepts_axis_types_kwarg():
    mesh = compat.make_mesh((1,), ("data",),
                            axis_types=(compat.AxisType.Auto,))
    assert mesh.axis_names == ("data",)


def test_use_mesh_is_reentrant_context():
    mesh = compat.make_mesh((1,), ("data",))
    with compat.use_mesh(mesh) as m:
        assert m is mesh
        with compat.use_mesh(mesh):
            pass


def test_shard_map_runs_on_current_jax():
    mesh = compat.make_mesh((1,), ("data",))
    fn = compat.shard_map(lambda x: x * 2, mesh=mesh,
                          in_specs=compat.P("data"),
                          out_specs=compat.P("data"), check_vma=False)
    np.testing.assert_array_equal(np.asarray(fn(jnp.arange(4.0))),
                                  np.arange(4.0) * 2)


# ---------------------------------------------------------------------------
# fallback paths, forced via the probes
# ---------------------------------------------------------------------------

def test_make_mesh_fallback_without_axis_types(monkeypatch):
    monkeypatch.setattr(version, "has_axis_types", lambda: False)
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.shape["data"] == 1
    # Auto axis_types are accepted and dropped...
    mesh = compat.make_mesh((1,), ("data",),
                            axis_types=(shardmesh.AxisType.Auto,))
    assert mesh.axis_names == ("data",)
    # ...but Explicit must fail loudly, never silently downgrade
    with pytest.raises(NotImplementedError):
        compat.make_mesh((1,), ("data",),
                         axis_types=(shardmesh.AxisType.Explicit,))


def test_make_mesh_fallback_without_jax_make_mesh(monkeypatch):
    monkeypatch.setattr(version, "has_axis_types", lambda: False)
    monkeypatch.delattr(jax, "make_mesh")
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.axis_names == ("data",) and mesh.shape["data"] == 1


def test_use_mesh_fallback_is_noop(monkeypatch):
    monkeypatch.setattr(version, "has_set_mesh", lambda: False)
    monkeypatch.setattr(version, "has_use_mesh", lambda: False)
    mesh = compat.make_mesh((1,), ("data",))
    with compat.use_mesh(mesh) as m:
        assert m is mesh


def test_shard_map_fallback_via_experimental(monkeypatch):
    monkeypatch.setattr(version, "has_top_level_shard_map", lambda: False)
    mesh = compat.make_mesh((1,), ("data",))
    fn = compat.shard_map(lambda x: x + 1, mesh=mesh,
                          in_specs=compat.P("data"),
                          out_specs=compat.P("data"), check_vma=False)
    np.testing.assert_array_equal(np.asarray(fn(jnp.zeros(2))), np.ones(2))


def test_explicit_sharding_probe_composition(monkeypatch):
    monkeypatch.setattr(version, "has_axis_types", lambda: False)
    assert not version.has_explicit_sharding()
    monkeypatch.setattr(version, "has_axis_types", lambda: True)
    monkeypatch.setattr(version, "has_set_mesh", lambda: True)
    assert version.has_explicit_sharding()


# ---------------------------------------------------------------------------
# cost_analysis normalization (list-of-dicts on 0.4.x, dict on newer)
# ---------------------------------------------------------------------------

def test_cost_analysis_normalized_shapes():
    class _C:
        def __init__(self, ret):
            self._ret = ret

        def cost_analysis(self):
            return self._ret

    assert compat.cost_analysis(_C([{"flops": 2.0}])) == {"flops": 2.0}
    assert compat.cost_analysis(_C({"flops": 3.0})) == {"flops": 3.0}
    assert compat.cost_analysis(_C([])) == {}
    assert compat.cost_analysis(_C(None)) == {}
    compiled = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    assert compat.cost_analysis(compiled).get("flops", 0) > 0


# ---------------------------------------------------------------------------
# the import-hygiene gate from the issue: no direct AxisType imports outside
# the compat package
# ---------------------------------------------------------------------------

def test_no_direct_version_dependent_jax_api_outside_compat():
    """Every spelling that differs across the supported JAX range must stay
    inside repro/compat — in code AND comments, so stale guidance can't
    creep back either."""
    import os
    import re
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    forbidden = [
        re.compile(r"from\s+jax\.sharding\s+import\s[^\n]*\bAxisType\b"),
        re.compile(r"jax\.sharding\.AxisType"),
        re.compile(r"jax\.set_mesh"),
        re.compile(r"jax\.shard_map"),
        re.compile(r"from\s+jax\.experimental\.shard_map\s+import"),
        re.compile(r"pltpu\.(?:TPU)?CompilerParams"),
        re.compile(r"\w+\.cost_analysis\(\)"),   # use compat.cost_analysis
    ]
    this_file = os.path.abspath(__file__)
    compat_dir = os.path.join(root, "src", "repro", "compat") + os.sep
    offenders = []
    for top in ("src", "tests", "benchmarks", "tools"):
        for dirpath, _, names in os.walk(os.path.join(root, top)):
            for name in names:
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                if path == this_file or path.startswith(compat_dir):
                    continue
                text = open(path).read()
                for pat in forbidden:
                    for m in pat.finditer(text):
                        line = text[:m.start()].count("\n") + 1
                        offenders.append(f"{path}:{line}: {m.group(0)}")
    assert not offenders, \
        "version-dependent JAX API outside repro/compat:\n" \
        + "\n".join(offenders)


def test_check_env_smoke():
    """tools/check_env.py prints one json line and exits 0 — the one-line
    environment-drift diagnosis."""
    import json
    import os
    tool = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "check_env.py")
    proc = subprocess.run([sys.executable, tool, "--json"],
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    report = json.loads(proc.stdout)
    assert report["jax"]["jax_version"] == jax.__version__
    assert "hypothesis" in report["optional_deps"]
    assert report["ok"] is True
