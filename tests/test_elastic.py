"""Elastic straggler response: monitor escalation, shrink_mesh, the
trainer's auto-remesh loop, and the hardened checkpoint/restore fault path.

Covers: the StepMonitor escalation policy (sustained outliers ->
remesh_suggested, post-remesh cooldown, recovery-aware timing attribution,
true medians on even windows); launch/mesh.shrink_mesh eligibility;
restore-across-a-grown-plan (the checkpoint manifest carries the plan
record, and maybe_restore re-analyzes/rebuilds against it); the retry
path's no-checkpoint rebuild (donated buffers must never be silently
retried); and the end-to-end distributed chaos scenario — an injected
sustained slowdown escalates to an automatic checkpoint + remesh onto a
smaller data axis with a bit-equal f32 loss prefix vs a never-straggled
run.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from conftest import distributed_run
from repro.checkpoint.ckpt import (gc_checkpoints, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.runtime import monitor as monitor_mod
from repro.runtime.monitor import StepMonitor
from repro.runtime.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# monitor escalation policy
# ---------------------------------------------------------------------------

class _Clock:
    """Deterministic stand-in for time.perf_counter (starts off 0 so the
    first start() timestamp is unambiguous)."""

    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def _tick(mon: StepMonitor, clock: _Clock, dt: float) -> dict:
    mon.start()
    clock.t += dt
    return mon.stop(tokens=10)


@pytest.fixture()
def clock(monkeypatch):
    c = _Clock()
    monkeypatch.setattr(monitor_mod.time, "perf_counter", c)
    return c


def test_median_true_median_on_even_windows():
    mon = StepMonitor()
    assert mon.median() == 0.0
    mon.times.extend([1.0, 5.0, 3.0])
    assert mon.median() == 3.0                 # odd: middle element
    mon.times.append(9.0)
    assert mon.median() == 4.0                 # even: mean of middle two,
    #                                            not the upper middle (5.0)


def test_sustained_outliers_escalate_to_remesh_suggested(clock):
    mon = StepMonitor(sustained=3, min_samples=4, cooldown=10)
    for _ in range(6):
        stats = _tick(mon, clock, 1.0)
    assert not mon.straggler_suspected
    for i in range(3):
        stats = _tick(mon, clock, 5.0)        # 5x the 1.0 median
        assert mon._outlier_run == i + 1
    assert mon.straggler_suspected
    assert mon.remesh_suggested
    assert stats["straggler_suspected"] and stats["remesh_suggested"]


def test_outlier_detection_waits_for_min_samples(clock):
    mon = StepMonitor(sustained=1, min_samples=4)
    _tick(mon, clock, 1.0)
    _tick(mon, clock, 1.0)
    _tick(mon, clock, 50.0)                   # only 3 samples: no verdict
    assert not mon.straggler_suspected
    _tick(mon, clock, 1.0)
    _tick(mon, clock, 50.0)                   # 5th sample: detection armed
    assert mon.straggler_suspected


def test_remesh_cooldown_blocks_resuggestion(clock):
    mon = StepMonitor(sustained=3, min_samples=4, cooldown=14)
    for _ in range(4):
        _tick(mon, clock, 1.0)
    for _ in range(3):
        _tick(mon, clock, 5.0)
    assert mon.remesh_suggested
    mon.note_remesh()                         # at total_steps = 7
    assert mon.remeshes == 1
    assert not mon.times and mon._outlier_run == 0   # fresh timing regime
    assert not mon.remesh_suggested
    # a new sustained run inside the cooldown is suspected but NOT escalated
    for _ in range(8):
        _tick(mon, clock, 1.0)                # steps 8..15
    for _ in range(3):
        _tick(mon, clock, 5.0)                # steps 16..18: 11 < 14 since
    assert mon.straggler_suspected and not mon.remesh_suggested
    for _ in range(3):
        _tick(mon, clock, 5.0)                # steps 19..21: cooldown elapsed
    assert mon.straggler_suspected and mon.remesh_suggested


def test_note_recovery_drops_sample_and_outlier_run(clock):
    mon = StepMonitor(sustained=2, min_samples=2)
    for _ in range(4):
        _tick(mon, clock, 1.0)
    _tick(mon, clock, 9.0)
    assert mon._outlier_run == 1
    # a restore pause happens mid-step: the in-flight sample must not enter
    # the window (it would read as a 50s straggler step) and the run resets
    mon.start()
    clock.t += 50.0
    mon.note_recovery()
    n = len(mon.times)
    stats = mon.stop(tokens=10)
    assert len(mon.times) == n                # sample dropped
    assert stats["step_time_s"] == 0.0
    assert mon._outlier_run == 0
    assert mon.total_steps == 6               # throughput accounting kept


def test_ckpt_error_surfaces_in_stats(clock):
    mon = StepMonitor()
    mon.note_ckpt_error(OSError("disk full"))
    stats = _tick(mon, clock, 1.0)
    assert stats["ckpt_error"] == "OSError: disk full"
    mon.note_ckpt_error(None)
    assert "ckpt_error" not in _tick(mon, clock, 1.0)


def test_ckpt_retries_surface_in_stats(clock):
    mon = StepMonitor()
    assert "ckpt_retries" not in _tick(mon, clock, 1.0)
    mon.note_ckpt_retries(3)
    assert _tick(mon, clock, 1.0)["ckpt_retries"] == 3


# ---------------------------------------------------------------------------
# heartbeat attribution + probation (the re-admission protocol)
# ---------------------------------------------------------------------------

def test_heartbeats_attribute_the_slow_slice(clock):
    """Per-slice heartbeat EMAs name the straggler: a slice whose EMA runs
    past straggler_factor x the median of the others for ``sustained``
    beats is attributed — and the attribution alone escalates, even when
    the local wall clock (which the collective hides) looks healthy."""
    mon = StepMonitor(sustained=3, min_samples=4)
    for i in range(3):
        _tick(mon, clock, 1.0)
        mon.note_heartbeats({0: 0.01, 1: 0.01, 2: 0.01, 3: 0.01})
        assert mon.straggler_slice() is None
    stats = None
    for i in range(3):
        stats = _tick(mon, clock, 1.0)         # wall clock: nothing to see
        mon.note_heartbeats({0: 0.01, 1: 0.01, 2: 0.2, 3: 0.01})
    assert not mon.straggler_suspected         # no wall-clock outliers...
    assert mon.straggler_slice() == 2          # ...but slice 2 is named
    assert mon.remesh_suggested                # attribution escalates
    assert mon.heartbeats[2] > mon.heartbeats[0]
    stats = _tick(mon, clock, 1.0)
    assert stats["straggler_slice"] == 2


def test_heartbeat_recovery_clears_the_slot_run(clock):
    mon = StepMonitor(sustained=3)
    for _ in range(2):
        mon.note_heartbeats({0: 0.01, 1: 0.2})
    assert mon._slot_runs[1] == 2
    # the contention drains; the EMA needs a few clean beats to decay back
    # under straggler_factor x the median of the others
    for _ in range(5):
        mon.note_heartbeats({0: 0.01, 1: 0.01})
    assert mon._slot_runs[1] == 0
    assert mon.straggler_slice() is None


def test_note_regrow_resets_window_and_cooldown_origin(clock):
    """A landed re-growth is a new step-time regime: the timing window,
    outlier runs, and the cooldown origin all reset — without this, a grow
    immediately followed by jitter re-escalates off pre-grow medians."""
    mon = StepMonitor(sustained=3, min_samples=4, cooldown=14)
    for _ in range(4):
        _tick(mon, clock, 1.0)
    for _ in range(3):
        _tick(mon, clock, 5.0)
    assert mon.remesh_suggested
    mon.note_regrow()                          # at total_steps = 7
    assert mon.regrows == 1
    assert not mon.times and mon._outlier_run == 0
    assert not mon._outlier_flags and not mon.heartbeats
    assert not mon.remesh_suggested
    # a fresh sustained run inside the re-armed cooldown: suspected, held
    for _ in range(4):
        _tick(mon, clock, 1.0)
    for _ in range(3):
        _tick(mon, clock, 5.0)                 # steps 12..14: 7 < 14
    assert mon.straggler_suspected and not mon.remesh_suggested


def test_probation_fast_reevict_bypasses_escalation_and_cooldown(clock):
    """The re-admitted slice re-straggling inside its probation window
    escalates after probation_sustained beats — no full sustained run, no
    cooldown wait (the first escalation already vetted this host)."""
    mon = StepMonitor(sustained=5, min_samples=4, cooldown=100)
    mon.note_remesh()                          # cooldown armed at step 0
    mon.note_regrow(slot=1, probation_steps=20, probation_sustained=2)
    _tick(mon, clock, 1.0)
    mon.note_heartbeats({0: 0.01, 1: 0.2, 2: 0.01})
    assert not mon.remesh_suggested            # 1 beat < probation_sustained
    _tick(mon, clock, 1.0)
    mon.note_heartbeats({0: 0.01, 1: 0.2, 2: 0.01})
    assert mon._probation_trip == 1
    assert mon.remesh_suggested                # inside cooldown, run of 2 < 5
    assert mon.straggler_slice() == 1          # the eviction names it
    mon.note_remesh()                          # the re-evict lands
    assert mon._probation is None and mon._probation_trip is None


def test_probation_expires_after_its_window(clock):
    mon = StepMonitor(sustained=5, min_samples=4)
    mon.note_regrow(slot=1, probation_steps=3, probation_sustained=2)
    for _ in range(4):
        _tick(mon, clock, 1.0)                 # the window elapses clean
        mon.note_heartbeats({0: 0.01, 1: 0.01, 2: 0.01})
    assert mon._probation is None              # back to ordinary standards
    _tick(mon, clock, 1.0)
    mon.note_heartbeats({0: 0.01, 1: 0.2, 2: 0.01})
    _tick(mon, clock, 1.0)
    mon.note_heartbeats({0: 0.01, 1: 0.2, 2: 0.01})
    assert mon._probation_trip is None         # 2 beats no longer trip
    assert not mon.remesh_suggested


# ---------------------------------------------------------------------------
# jitter hysteresis (the bounded-staleness fallback's driver)
# ---------------------------------------------------------------------------

def test_jitter_hysteresis_suggests_stale_then_recovery(clock):
    """Intermittent outliers (ratio >= jitter_enter without a sustained
    run) suggest the stale flip; after the flip the window refills, and the
    ratio draining under jitter_exit suggests flipping back."""
    mon = StepMonitor(sustained=5, min_samples=4, window=10,
                      jitter_enter=0.3, jitter_exit=0.1)
    for _ in range(6):
        _tick(mon, clock, 1.0)
    assert not mon.stale_suggested and mon.jitter_ratio == 0.0
    for _ in range(3):                         # alternating: spiky, never
        _tick(mon, clock, 5.0)                 # sustained
        _tick(mon, clock, 1.0)
    assert mon.jitter_ratio >= 0.3
    assert not mon.straggler_suspected
    assert mon.stale_suggested
    assert not mon.stale_recovered             # not stale yet: nothing to
    mon.note_stale_flip(True)                  # recover from
    assert mon.stale_flips == 1
    assert not mon._outlier_flags              # window refills under the
    assert not mon.stale_suggested             # new plan (and _stale_on
    #                                            blocks re-suggesting)
    for _ in range(3):
        _tick(mon, clock, 1.0)
    assert not mon.stale_recovered             # min_samples not met yet
    for _ in range(3):
        _tick(mon, clock, 1.0)
    assert mon.jitter_ratio == 0.0
    assert mon.stale_recovered
    mon.note_stale_flip(False)
    assert mon.stale_flips == 2 and not mon._stale_on
    stats = _tick(mon, clock, 1.0)
    assert stats["stale_mode"] is False and stats["stale_flips"] == 2


def test_straggler_escalation_preempts_the_stale_fallback(clock):
    """A sustained run is an eviction case, not a staleness case: while
    straggler_suspected holds, stale_suggested must stay quiet even with
    the jitter ratio far past the enter threshold."""
    mon = StepMonitor(sustained=3, min_samples=4, window=10)
    for _ in range(4):
        _tick(mon, clock, 1.0)
    for _ in range(3):
        _tick(mon, clock, 5.0)
    assert mon.jitter_ratio >= 0.3
    assert mon.straggler_suspected
    assert not mon.stale_suggested


# ---------------------------------------------------------------------------
# shrink_mesh eligibility (structural checks run distributed, below)
# ---------------------------------------------------------------------------

def test_shrink_mesh_eligibility_single_device():
    from repro.launch.mesh import make_mesh, shrink_mesh
    assert shrink_mesh(None, 0) is None
    mesh = make_mesh((1, 1), ("data", "model"))
    assert shrink_mesh(mesh, 0) is None               # data axis at 1
    assert shrink_mesh(mesh, 0, axis="pod") is None   # axis absent
    with pytest.raises(ValueError):
        shrink_mesh(mesh, 5)                          # no such slice


def test_grow_mesh_eligibility_single_device():
    from repro.launch.mesh import grow_mesh, make_mesh
    assert grow_mesh(None, []) is None
    mesh = make_mesh((1, 1), ("data", "model"))
    dev = np.asarray(mesh.devices).flat[0]
    assert grow_mesh(mesh, [dev], axis="pod") is None  # axis absent
    with pytest.raises(ValueError):
        grow_mesh(mesh, [dev])                   # still on the live mesh
    with pytest.raises(ValueError):
        grow_mesh(mesh, [dev, dev])              # wrong slice shape
    with pytest.raises(ValueError):
        grow_mesh(mesh, [dev], insert_axis_index=5)  # out of range


# ---------------------------------------------------------------------------
# checkpoint dir hardening
# ---------------------------------------------------------------------------

def _tiny_state():
    import jax
    import jax.numpy as jnp
    from repro.optim.optimizer import TrainState
    params = {"w": jnp.arange(8, dtype=jnp.float32)}
    return TrainState(step=jnp.asarray(1, jnp.int32), params=params,
                      m=None, v=None, ema=None)


def test_latest_step_and_gc_ignore_stray_entries(tmp_path):
    s = _tiny_state()
    for i in (1, 2, 3):
        save_checkpoint(str(tmp_path), i, s)
    # the strays that used to crash int(d.split("_")[1])
    (tmp_path / "notes.txt").write_text("hi")
    (tmp_path / "step_latest").mkdir()
    (tmp_path / "step_abc").mkdir()
    (tmp_path / "step_5_backup").mkdir()
    # digits but not this writer's step_%08d padding: counting it would
    # point restore/GC at a nonexistent padded name
    (tmp_path / "step_7").mkdir()
    os.makedirs(tmp_path / "step_00000009.tmp")       # crashed writer
    assert latest_step(str(tmp_path)) == 3
    gc_checkpoints(str(tmp_path), keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_0")
                  and not d.endswith(".tmp"))
    assert kept == ["step_00000002", "step_00000003"]
    assert (tmp_path / "step_latest").exists()        # strays untouched
    assert (tmp_path / "step_7").exists()
    _, step, _ = restore_checkpoint(str(tmp_path), s)
    assert step == 3


def test_async_checkpointer_save_sync_commits(tmp_path):
    from repro.checkpoint.ckpt import AsyncCheckpointer
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save_sync(4, _tiny_state(), extra={"plan": {}})
    assert ck.last_committed == 4                     # no wait() needed
    assert latest_step(str(tmp_path)) == 4


def test_save_sync_discards_stale_async_error(tmp_path):
    """The pre-remesh safety checkpoint must not be blocked by a *stale*
    background failure — the fresh commit is the whole point."""
    from repro.checkpoint.ckpt import AsyncCheckpointer
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    ck._error = OSError("stale background failure")
    ck.save_sync(3, _tiny_state())
    assert ck.last_committed == 3
    assert latest_step(str(tmp_path)) == 3
    ck.wait()                                 # consumed: must not re-raise


def test_async_save_retries_transient_failures(tmp_path, monkeypatch):
    """A transient background-write failure (filesystem hiccup) retries
    with backoff instead of silently waiting for the next period; the
    cumulative count surfaces as total_retries (-> stats ckpt_retries)."""
    from repro.checkpoint import ckpt as ckpt_mod
    from repro.checkpoint.ckpt import AsyncCheckpointer
    real = ckpt_mod.save_checkpoint
    calls = {"n": 0}

    def flaky(*a, **k):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError("transient")
        return real(*a, **k)

    monkeypatch.setattr(ckpt_mod, "save_checkpoint", flaky)
    ck = AsyncCheckpointer(str(tmp_path), keep=2, retries=3, backoff=0.001)
    ck.save(5, _tiny_state())
    ck.wait()                                  # must not raise: 3rd try won
    assert calls["n"] == 3
    assert ck.total_retries == 2
    assert ck.last_committed == 5
    assert latest_step(str(tmp_path)) == 5


def test_async_save_surfaces_exhausted_retries(tmp_path, monkeypatch):
    from repro.checkpoint import ckpt as ckpt_mod
    from repro.checkpoint.ckpt import AsyncCheckpointer

    def always_fail(*a, **k):
        raise OSError("disk gone")

    monkeypatch.setattr(ckpt_mod, "save_checkpoint", always_fail)
    ck = AsyncCheckpointer(str(tmp_path), keep=2, retries=2, backoff=0.001)
    ck.save(5, _tiny_state())
    with pytest.raises(OSError):
        ck.wait()                              # exhausted: failure surfaces
    assert ck.total_retries == 2
    assert ck.last_committed is None


def test_background_ckpt_failure_does_not_abort_run(tiny_shape, tmp_path):
    """A stored background-write error used to re-raise out of the next
    periodic save() and abort a healthy run; now it surfaces as stats
    ckpt_error, the save retries next period, and training completes."""
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none")
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    tcfg = TrainerConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=2)
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    t.ckpt._error = OSError("disk full")              # failed async write
    stats = []
    t.run(on_metrics=lambda s, m: stats.append(m))    # must not raise
    assert t.step == 6
    assert any(m.get("ckpt_error") == "OSError: disk full" for m in stats)
    assert "ckpt_error" not in stats[-1]              # healed after retry
    assert latest_step(str(tmp_path)) == 6            # later saves landed


# ---------------------------------------------------------------------------
# restore across a grown plan (the manifest plan record)
# ---------------------------------------------------------------------------

def _growth_setup(tiny_shape, ckpt_dir, total_steps=8, replan_every=6):
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none",
                   capacity_mode="capped", capacity_factor=2.0,
                   zipf_a=2.0, capacity_growth=1.5, overflow_tolerance=0.5)
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch, zipf_a=2.0, burst_steps=4,
                     burst_zipf_a=1.3)
    tcfg = TrainerConfig(total_steps=total_steps, ckpt_dir=ckpt_dir,
                         ckpt_every=50, replan_every=replan_every,
                         replan_warmup=2, replan_drift=50.0)
    return cfg, rc, ds, tcfg


def test_restore_adopts_grown_plan_from_manifest(tiny_shape, tmp_path):
    """A checkpoint written after a capacity-growth replan must restore with
    the *grown* plan: previously maybe_restore kept the build-time estimate
    (smaller buffers, pre-flip methods) and never rebuilt the step, so the
    resumed run silently re-overflowed the rows the growth had rescued."""
    cfg, rc, ds, tcfg = _growth_setup(tiny_shape, str(tmp_path))
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    cap0 = t.plan.table_capacity["embed"]
    t.run()
    grown_cap = t.plan.table_capacity["embed"]
    assert grown_cap > cap0 and "embed" in t.plan.grown_tables
    # the manifest records the live plan, not just the dataset cursor
    d = os.path.join(str(tmp_path), f"step_{t.step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        extra = json.load(f)["extra"]
    assert extra["plan"]["embed"]["capacity"] == grown_cap
    assert extra["plan"]["embed"]["grown"] is True

    # a fresh trainer starts from the build-time estimate...
    t2 = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    assert t2.plan.table_capacity["embed"] == cap0
    step_fn0 = t2.train_step
    t2.maybe_restore()
    # ...and restore re-analyzes + rebuilds against the saved record
    assert t2.step == t.step
    assert t2.plan.table_capacity["embed"] == grown_cap
    assert "embed" in t2.plan.grown_tables
    assert t2.train_step is not step_fn0      # the jitted step was rebuilt
    assert t2.monitor._outlier_run == 0
    # and the restored run trains on under the adopted plan
    t2.tcfg = dataclasses.replace(t2.tcfg, total_steps=t.step + 2,
                                  replan_every=0)
    stats = []
    t2.run(on_metrics=lambda s, m: stats.append(m))
    assert len(stats) == 2
    assert all(np.isfinite(m["loss"]) for m in stats)


def test_remesh_carries_observed_plan_state(tiny_shape, tmp_path):
    """An elastic rebuild must not revert to the build-time estimate: a
    capacity the overflow rule grew (and its grown stickiness) survives a
    remesh — the new plan is derived from the observed census with sticky
    growth against the pre-remesh plan, only the world-size terms
    re-price."""
    cfg, rc, ds, tcfg = _growth_setup(tiny_shape, str(tmp_path))
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    cap0 = t.plan.table_capacity["embed"]
    t.run()
    assert "embed" in t.plan.grown_tables
    grown_cap = t.plan.table_capacity["embed"]
    t.remesh(None)
    # the estimate alone would re-derive cap0; the carried census holds
    # growth-headroom sizing and the grown flag
    assert t.plan.table_capacity["embed"] > cap0, \
        (cap0, t.plan.table_capacity["embed"], grown_cap)
    assert "embed" in t.plan.grown_tables
    t.tcfg = dataclasses.replace(t.tcfg, total_steps=t.step + 2,
                                 replan_every=0)
    stats = []
    t.run(on_metrics=lambda s, m: stats.append(m))
    assert all(np.isfinite(m["loss"]) for m in stats)


def test_restore_adopts_dense_wire_pins(tiny_shape, tmp_path):
    """Profiled wire_dtype_auto pins cover *dense* parameters, which
    Plan.tables() (sparse-only) cannot record — the manifest's wire_pins
    entry must bring them back, or a restored run silently reverts an
    outlier-prone bucket's f32 pin to the bf16 default."""
    from repro.core.plan import plan_diff, plan_leaves
    from repro.core.transform import analyze, apply_replan, estimate_census
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none", opsw=True,
                   wire_dtype="bfloat16")
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    tcfg = TrainerConfig(total_steps=2, ckpt_dir=str(tmp_path), ckpt_every=50)
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    # a profiled pin lands (as wire_dtype_hints would): one dense param
    # keeps f32 on the wire
    pinned = next(p.name for p in plan_leaves(t.plan.params) if not p.sparse)
    census = estimate_census(t.model, t.rt)
    census.wire_dtypes = {pinned: "float32"}
    new_plan = analyze(t.model, t.rt, census=census)
    diff = plan_diff(t.plan, new_plan)
    assert diff["wire_flips"]
    t.plan = new_plan
    t.train_step, t.state, t.shardings = apply_replan(
        t.model, t.optimizer, t.rt, new_plan, t.state, diff)
    t.run()                                   # final save carries wire_pins
    assert t._wire_pins(t.plan) == {pinned: "float32"}

    t2 = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    assert t2._wire_pins(t2.plan) == {}       # build-time default
    t2.maybe_restore()
    assert t2._wire_pins(t2.plan) == {pinned: "float32"}
    assert t2.step == 2


def test_restore_with_matching_plan_keeps_step(tiny_shape, tmp_path):
    """No spurious rebuild: restoring a checkpoint whose plan record matches
    the live plan must not re-jit."""
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none")
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    tcfg = TrainerConfig(total_steps=2, ckpt_dir=str(tmp_path), ckpt_every=50)
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    t.run()
    t2 = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    step_fn0 = t2.train_step
    t2.maybe_restore()
    assert t2.step == 2
    assert t2.train_step is step_fn0


# ---------------------------------------------------------------------------
# retry path: no committed checkpoint => rebuild, never retry poisoned state
# ---------------------------------------------------------------------------

def _flaky_once(t: Trainer, fail_at_step: int):
    orig = t.train_step
    fired = {"n": 0}

    def step(state, batch):
        if t.step == fail_at_step and not fired["n"]:
            fired["n"] = 1
            raise RuntimeError("injected step failure")
        return orig(state, batch)

    t.train_step = step
    return fired


def test_retry_without_checkpoint_rebuilds_fresh_state(tiny_shape, tmp_path):
    """A step failure before any checkpoint has committed must NOT retry on
    self.state — the failed call may have consumed the donated buffers.
    The driver rebuilds from seed at step 0 instead."""
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none")
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    tcfg = TrainerConfig(total_steps=6, ckpt_dir=str(tmp_path),
                         ckpt_every=100)
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    fired = _flaky_once(t, fail_at_step=3)
    steps = []
    t.run(on_metrics=lambda s, m: steps.append(s))
    assert fired["n"] == 1
    # the run restarted from 0 (fresh init), then completed
    assert steps == [1, 2, 3, 1, 2, 3, 4, 5, 6]
    assert t.step == 6
    assert int(np.asarray(t.state.step)) == 6         # fresh state, 6 updates
    assert latest_step(str(tmp_path)) == 6            # final save committed


def test_retry_with_checkpoint_restores_it(tiny_shape, tmp_path):
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none")
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    tcfg = TrainerConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=2)
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    _flaky_once(t, fail_at_step=5)
    steps = []
    t.run(on_metrics=lambda s, m: steps.append(s))
    # rolled back to the step-4 checkpoint, not to 0
    assert steps == [1, 2, 3, 4, 5, 5, 6]
    assert t.step == 6 and int(np.asarray(t.state.step)) == 6


# ---------------------------------------------------------------------------
# distributed: shrink_mesh structure + the full chaos scenario
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_shrink_mesh_drops_one_slice_and_keeps_grid():
    code = """
from repro.launch.mesh import shrink_mesh

mesh = make_mesh((4, 2), ("data", "model"))
grid = np.asarray(mesh.devices)
m2 = shrink_mesh(mesh, drop_axis_index=3)
kept = np.asarray(m2.devices)
dropped_ids = [d.id for d in grid[3]]
same_grid = all(kept[i, j].id == grid[i, j].id
                for i in range(3) for j in range(2))
floor = shrink_mesh(m2, 0, min_axis_size=3)
print("RESULT:" + json.dumps({
    "shape": dict(m2.shape), "axes": list(m2.axis_names),
    "same_grid": bool(same_grid),
    "disjoint": not (set(d.id for d in kept.flat) & set(dropped_ids)),
    "floored": floor is None}))
"""
    res = distributed_run(code, devices=8)
    assert res["shape"] == {"data": 3, "model": 2}
    assert res["axes"] == ["data", "model"]
    assert res["same_grid"] and res["disjoint"]
    assert res["floored"] is True             # 3 - 1 < min_axis_size=3


@pytest.mark.distributed
def test_shrink_grow_round_trip_restores_the_grid():
    """grow_mesh is shrink_mesh's exact inverse: re-inserting the evicted
    slice at its original grid position restores the device grid
    bit-for-bit (every surviving device kept its position through both
    hops), carries the axis names and axis types, enforces the
    min_axis_size floor on a later shrink, and rejects devices already on
    the live mesh."""
    code = """
from repro.launch.mesh import grow_mesh, shrink_mesh

mesh = make_mesh((4, 2), ("data", "model"))
grid = np.asarray(mesh.devices)
m3 = shrink_mesh(mesh, drop_axis_index=1)
evicted = grid[1]
m4 = grow_mesh(m3, evicted, insert_axis_index=1)
back = np.asarray(m4.devices)
round_trip = all(back[i, j].id == grid[i, j].id
                 for i in range(4) for j in range(2))
types_kept = getattr(m4, "axis_types", None) == \
    getattr(mesh, "axis_types", None)
appended = grow_mesh(m3, evicted)       # default: after the last slice
app = np.asarray(appended.devices)
overlap_raises = False
try:
    grow_mesh(m4, evicted, insert_axis_index=1)
except ValueError:
    overlap_raises = True
floor = shrink_mesh(m4, 0, min_axis_size=4)
print("RESULT:" + json.dumps({
    "shrunk_shape": dict(m3.shape), "grown_shape": dict(m4.shape),
    "axes": list(m4.axis_names), "round_trip": bool(round_trip),
    "types_kept": bool(types_kept),
    "appended_last": [d.id for d in app[3]] == [d.id for d in evicted],
    "overlap_raises": overlap_raises, "floored": floor is None}))
"""
    res = distributed_run(code, devices=8)
    assert res["shrunk_shape"] == {"data": 3, "model": 2}
    assert res["grown_shape"] == {"data": 4, "model": 2}
    assert res["axes"] == ["data", "model"]
    assert res["round_trip"], "a surviving device moved across the round trip"
    assert res["types_kept"]
    assert res["appended_last"]
    assert res["overlap_raises"], "re-admitting live devices must raise"
    assert res["floored"] is True             # 4 - 1 < min_axis_size=4


@pytest.mark.distributed
def test_manifest_plan_restore_across_a_grow():
    """The evict -> readmit cycle commits checkpoints at both hops; the
    last one carries the *re-grown* world's plan record and mesh shape, so
    a fresh trainer on the full mesh restores the step, the plan, and the
    trajectory without re-deriving anything from the build-time estimate."""
    code = """
import tempfile
from repro.checkpoint.ckpt import latest_step
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
rc = RunConfig(attention_impl="naive", remat="none", param_dtype="float32",
               compute_dtype="float32", wire_dtype="float32",
               capacity_mode="capped", capacity_factor=2.0, link_latency=0.0)
ck = tempfile.mkdtemp()

def trainer(steps):
    ds = SyntheticLM(cfg.vocab_size, 32, 8)
    mesh = make_mesh((4, 2), ("data", "model"))
    tcfg = TrainerConfig(total_steps=steps, ckpt_dir=ck, ckpt_every=100,
                         min_data_parallel=2, probation_steps=30)
    return Trainer(cfg, shape, rc, tcfg, ds, mesh=mesh), mesh

t, mesh = trainer(4)
with use_mesh(mesh):
    t.run()                                  # steps 1..4 on (4, 2)
    assert t._auto_remesh() is not None      # by-convention evict (slice 3)
    shrunk = dict(t.mesh.shape)
    evicted = [int(d.id) for d in t._evicted[-1]["devices"].flat]
    import dataclasses
    t.tcfg = dataclasses.replace(t.tcfg, total_steps=8)
    t.run()                                  # steps 5..8 on (3, 2)
    assert t.readmit() is not None           # the slice returns, probation
    grown = dict(t.mesh.shape)
    probation = t.monitor._probation[0] if t.monitor._probation else None
    t.tcfg = dataclasses.replace(t.tcfg, total_steps=10)
    t.run()                                  # steps 9..10 + final save
saved_ckpt = latest_step(ck)

t2, mesh2 = trainer(12)
cap_estimate = t2.plan.table_capacity["embed"]
with use_mesh(mesh2):
    t2.maybe_restore()
    restored_step = t2.step
    losses = []
    t2.run(on_metrics=lambda s, m: losses.append(float(m["loss"])))

print("RESULT:" + json.dumps({
    "shrunk": shrunk, "grown": grown, "probation": probation,
    "evicted_ids": evicted,
    "remeshes": t.monitor.remeshes, "regrows": t.monitor.regrows,
    "latest_ckpt": saved_ckpt, "restored_step": restored_step,
    "cap_estimate": cap_estimate,
    "cap_saved": t.plan.table_capacity["embed"],
    "cap_restored": t2.plan.table_capacity["embed"],
    "losses": losses}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    assert res["shrunk"] == {"data": 3, "model": 2}
    assert res["grown"] == {"data": 4, "model": 2}
    assert res["probation"] == 3              # the returned slice, on watch
    assert len(res["evicted_ids"]) == 2       # one (model=2) slice
    assert res["remeshes"] == 1 and res["regrows"] == 1
    assert res["latest_ckpt"] == 10
    assert res["restored_step"] == 10
    # the re-grown world's plan record came back, not the fresh estimate
    assert res["cap_restored"] == res["cap_saved"]
    assert len(res["losses"]) == 2
    assert all(np.isfinite(l) for l in res["losses"])


@pytest.mark.distributed
def test_remesh_reprices_methods_for_the_new_world_size():
    """The cost model's exchange terms depend on N, so shrinking the mesh
    must re-run the Table-3 argmin: at a declared α=0.3 on a (D, 1) mesh
    (no row-sharding axis), mpi_gatherv costs 2(N-1)αb — dearer than the
    dense allreduce's 2(N-1)/N·b at N=4 (1.8b vs 1.5b), cheaper at N=3
    (1.2b vs 1.33b). The auto-remesh rebuild must flip the method and keep
    training."""
    code = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.launch.mesh import shrink_mesh
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
rc = RunConfig(attention_impl="naive", remat="none", param_dtype="float32",
               compute_dtype="float32", wire_dtype="float32",
               link_latency=0.0, table_alpha=(("embed", 0.3),))
ds = SyntheticLM(cfg.vocab_size, 32, 8)
mesh = make_mesh((4, 1), ("data", "model"))
t = Trainer(cfg, shape, rc, TrainerConfig(total_steps=2), ds, mesh=mesh)
method4 = t.plan.table_methods["embed"]
with use_mesh(mesh):
    t.run()
mesh3 = shrink_mesh(mesh, drop_axis_index=3)
t.remesh(mesh3)
method3 = t.plan.table_methods["embed"]
t.tcfg = TrainerConfig(total_steps=4)
losses = []
with use_mesh(mesh3):
    t.run(on_metrics=lambda s, m: losses.append(float(m["loss"])))
print("RESULT:" + json.dumps({
    "method4": method4, "method3": method3,
    "shape3": dict(mesh3.shape), "losses": losses}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    assert res["method4"] == "allreduce", res
    assert res["method3"] == "mpi_gatherv", res
    assert res["shape3"] == {"data": 3, "model": 1}
    assert len(res["losses"]) == 2
    assert all(np.isfinite(l) for l in res["losses"])


@pytest.mark.distributed
def test_auto_remesh_on_sustained_straggle_keeps_trajectory():
    """The acceptance scenario: a sustained injected slowdown escalates to
    an automatic checkpoint + remesh onto a smaller data axis (the plan
    re-priced for the new world size), training resumes on the live state,
    and the f32 loss trajectory is bit-equal to a never-straggled run over
    the shared (pre-remesh) step range."""
    code = """
import time
from repro.checkpoint.ckpt import latest_step
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=2.0, link_latency=0.0)
STEPS, SLOW_FROM, SLEEP = 14, 6, 0.3

def drive(straggle, ckpt_dir):
    ds = SyntheticLM(cfg.vocab_size, 32, 8)
    mesh = make_mesh((4, 2), ("data", "model"))
    tcfg = TrainerConfig(total_steps=STEPS, ckpt_dir=ckpt_dir,
                         ckpt_every=100, remesh_on_straggle=straggle,
                         remesh_cooldown=20, min_data_parallel=2)
    t = Trainer(cfg, shape, RunConfig(**kw), tcfg, ds, mesh=mesh)
    t.monitor.sustained = 3
    t.monitor.min_samples = 4
    if straggle:
        orig = t.train_step
        def slow(state, batch):
            if t.step >= SLOW_FROM:
                time.sleep(SLEEP)     # the 'slow host' gating the collective
            return orig(state, batch)
        t.train_step = slow
    tables0 = t.plan.tables()
    hist = []
    with use_mesh(mesh):
        t.run(on_metrics=lambda s, m: hist.append(dict(
            step=s, loss=float(m["loss"]),
            remeshes=int(m.get("remeshes", 0)), dt=m["step_time_s"])))
    return t, tables0, hist

import tempfile
ck = tempfile.mkdtemp()
base_t, base_tables, base_hist = drive(False, None)
t, tables0, hist = drive(True, ck)

remesh_steps = [h["step"] for h in hist if h["remeshes"] == 1]
remesh_at = remesh_steps[0] if remesh_steps else -1
manifest = {}
if remesh_at > 0:
    import json as _json
    with open(f"{ck}/step_{remesh_at:08d}/manifest.json") as f:
        manifest = _json.load(f)["extra"]
print("RESULT:" + json.dumps({
    "remeshes": t.monitor.remeshes,
    "remesh_at": remesh_at,
    "mesh_after": dict(t.mesh.shape),
    "tables_before": tables0, "tables_after": t.plan.tables(),
    "base_losses": [h["loss"] for h in base_hist],
    "losses": [h["loss"] for h in hist],
    "dts": [h["dt"] for h in hist],
    "manifest_mesh": manifest.get("mesh"),
    "manifest_plan_tables": sorted(manifest.get("plan", {})),
    "latest_ckpt": latest_step(ck),
    "final_step": t.step}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    # escalation fired exactly once and shrank the data axis by one slice
    assert res["remeshes"] == 1, res
    r = res["remesh_at"]
    assert r >= 6 + 3, res                    # needed >= sustained slow steps
    assert res["mesh_after"] == {"data": 3, "model": 2}
    assert res["final_step"] == 14
    # the plan was re-priced for the smaller world (per-replica tokens grew)
    cap0 = res["tables_before"]["embed"]["capacity"]
    cap1 = res["tables_after"]["embed"]["capacity"]
    assert cap1 != cap0, (cap0, cap1)
    # the pre-remesh checkpoint committed with the old-mesh plan record
    assert res["manifest_mesh"] == {"data": 4, "model": 2}
    assert "embed" in res["manifest_plan_tables"]
    assert res["latest_ckpt"] == 14           # final save after the remesh
    # trajectory continuity: bit-equal f32 losses over the shared
    # (pre-remesh) range, finite and sane after the swap
    assert res["losses"][:r] == res["base_losses"][:r], \
        (r, res["losses"], res["base_losses"])
    post = res["losses"][r:]
    assert all(np.isfinite(l) for l in post)
    assert max(abs(a - b) for a, b in
               zip(post, res["base_losses"][r:])) < 5e-2
    # throughput recovered once the slow slice was evicted: post-remesh
    # steps (minus the recompile step) beat the straggled steps
    slow = res["dts"][6:r]
    fast_again = res["dts"][r + 1:]
    assert slow and fast_again
    assert np.median(fast_again) < 0.5 * np.median(slow), res["dts"]
