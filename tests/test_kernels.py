"""Pallas kernels vs ref.py oracles — shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels import ref
from repro.kernels.embed_gather import embed_gather
from repro.kernels.embed_scatter import embed_scatter_add
from repro.kernels.flash_attention import flash_attention
from repro.kernels.wkv import wkv


@pytest.mark.parametrize("b,s,h,d", [(1, 128, 1, 64), (2, 256, 4, 64),
                                     (1, 200, 2, 128), (2, 64, 8, 32)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, d, causal, dtype):
    ks = jax.random.split(jax.random.key(s * h + causal), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, h, d), dtype)
    v = jax.random.normal(ks[2], (b, s, h, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_cross_lengths():
    """Sq != Sk (prefill appending to a prefix) without causal mask."""
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (2, 96, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 160, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 160, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(4, 64), st.integers(8, 64),
       st.integers(0, 1000))
def test_embed_gather_hypothesis(nshards_i, n_ids, vs, seed):
    e = 16
    key = jax.random.key(seed)
    table = jax.random.normal(key, (vs, e), jnp.float32)
    offset = nshards_i * vs
    ids = jax.random.randint(jax.random.fold_in(key, 1), (n_ids,), 0,
                             vs * 4)
    out = embed_gather(table, ids, offset, interpret=True)
    want = ref.embed_gather_ref(table, ids, offset)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def _deduped_ids(key, n, lo, hi):
    """Sorted-unique local-space ids like the dedupe buffer produces (may
    include unowned negatives / overflow / sentinel duplicates at the top
    clipped off by uniqueness)."""
    ids = jax.random.randint(key, (4 * n,), lo, hi)
    uniq = np.unique(np.asarray(ids))[:n]
    pad = np.full(max(n - uniq.size, 0), hi, uniq.dtype)  # unowned sentinel
    return jnp.asarray(np.concatenate([uniq, pad])[:n], jnp.int32)


@pytest.mark.parametrize("vs,e,n", [(16, 8, 8), (64, 32, 40), (33, 16, 20)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embed_scatter_add_sweep(vs, e, n, dtype):
    key = jax.random.key(vs * e + n)
    rows = jax.random.normal(key, (n, e), dtype)
    ids = _deduped_ids(jax.random.fold_in(key, 1), n, -vs, 2 * vs)
    out = embed_scatter_add(ids, rows, vs, interpret=True)
    want = ref.embed_scatter_add_ref(ids, rows, vs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 64), st.integers(4, 48), st.integers(0, 1000))
def test_embed_scatter_add_hypothesis(vs, n, seed):
    e = 8
    key = jax.random.key(seed)
    rows = jax.random.normal(key, (n, e), jnp.float32)
    ids = _deduped_ids(jax.random.fold_in(key, 1), n, -3, vs + 3)
    out = embed_scatter_add(ids, rows, vs, interpret=True)
    want = ref.embed_scatter_add_ref(ids, rows, vs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lookup_pallas_matches_jnp_bitwise(dtype):
    """The kernelized sparse hot path is a drop-in: lookup forward AND the
    scatter-add backward match the jnp implementation bit-for-bit in
    interpret mode (the acceptance bar for embed_impl=pallas)."""
    from repro.core.embedding import EmbedCtx, lookup

    vocab, e, b, s = 40, 16, 2, 12
    key = jax.random.key(3)
    table = jax.random.normal(key, (vocab, e), dtype)
    ids = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, vocab)

    def run(impl):
        ctx = EmbedCtx(mesh=None, method="dense", batch_axes=(),
                       model_axis="", vocab_padded=vocab,
                       wire_dtype=jnp.float32, local_agg=True, impl=impl)

        def loss(t):
            out, _ = lookup(t, ids, ctx=ctx, capacity=b * s)
            return jnp.sum(out.astype(jnp.float32) ** 2)

        val, grad = jax.value_and_grad(loss)(table)
        fwd, _ = lookup(table, ids, ctx=ctx, capacity=b * s)
        return fwd, val, grad

    fwd_j, val_j, grad_j = run("jnp")
    fwd_p, val_p, grad_p = run("pallas")
    np.testing.assert_array_equal(np.asarray(fwd_j), np.asarray(fwd_p))
    np.testing.assert_array_equal(np.asarray(val_j), np.asarray(val_p))
    np.testing.assert_array_equal(np.asarray(grad_j), np.asarray(grad_p))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,e,chunk", [(1, 64, 2, 16, 16),
                                           (2, 100, 3, 32, 32),
                                           (1, 31, 1, 64, 32)])
def test_wkv_sweep(b, s, h, e, chunk, dtype):
    ks = jax.random.split(jax.random.key(s + e), 5)
    r = jax.random.normal(ks[0], (b, s, h, e), dtype) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, e), dtype) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, e), dtype) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, e), jnp.float32)
                  * 0.5 - 1.0)
    u = jax.random.normal(ks[4], (h, e), jnp.float32) * 0.1
    st0 = jax.random.normal(jax.random.fold_in(ks[4], 1), (b, h, e, e),
                            jnp.float32) * 0.1
    out, s_t = wkv(r, k, v, lw.astype(dtype), u, st0, chunk=chunk,
                   interpret=True)
    want_o, want_s = ref.wkv_ref(r, k, v, lw.astype(dtype), u, st0)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want_o, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(want_s),
                               rtol=tol, atol=tol)


def test_wkv_chunk_invariance():
    """Chunk size is an implementation detail — outputs must agree."""
    ks = jax.random.split(jax.random.key(3), 5)
    b, s, h, e = 1, 96, 2, 16
    r = jax.random.normal(ks[0], (b, s, h, e), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, e), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, e), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, e), jnp.float32) - 1.5)
    u = jnp.zeros((h, e), jnp.float32)
    st0 = jnp.zeros((b, h, e, e), jnp.float32)
    o16, s16 = wkv(r, k, v, lw, u, st0, chunk=16, interpret=True)
    o48, s48 = wkv(r, k, v, lw, u, st0, chunk=48, interpret=True)
    np.testing.assert_allclose(np.asarray(o16), np.asarray(o48),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s48),
                               rtol=1e-4, atol=1e-4)


def test_model_chunked_wkv_matches_oracle():
    """The model's pure-jnp chunked path (models/rwkv.py) vs the sequential
    oracle — the model and the kernel share semantics."""
    from repro.models.rwkv import _chunk_wkv
    ks = jax.random.split(jax.random.key(11), 5)
    b, s, h, e = 2, 70, 2, 16
    r = jax.random.normal(ks[0], (b, s, h, e), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, e), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, e), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, e), jnp.float32) - 1.0)
    u = jax.random.normal(ks[4], (h, e), jnp.float32) * 0.2
    st0 = jnp.zeros((b, h, e, e), jnp.float32)
    out, s_t = _chunk_wkv(r, k, v, lw, u, st0, 32)
    want_o, want_s = ref.wkv_ref(r, k, v, lw, u, st0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_o),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(want_s),
                               rtol=1e-4, atol=1e-4)
