"""The serving engine: batched prefill, slot-paged decode, sparse-planned
serve path.

Covers: prefill/decode parity against the teacher-forced reference loop
(one jitted prefill dispatch reproduces prompt_len decode dispatches);
the per-power-of-two-bucket executable cache (same-bucket prompts share one
trace, counted by a trace-time side effect); the slot-reuse regression for
the shared-cache_len cross-slot hazard (a freed slot's stale rows must be
invisible to the next tenant — engine-vs-engine bit-exact); device-side
sampling (the once-dead ``ServerConfig.greedy`` flag); and serve-time
per-table planning (one analyze() at decode shapes gives the skewed table a
row-sharded pull with nonzero per-token exchange cost while the near-dense
table rides the free replicated gather).
"""
import math

import numpy as np
import pytest

from conftest import distributed_run
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core import cost_model as cm
from repro.runtime.server import (Request, Server, ServerConfig, ToyServer,
                                  bucket_len, prefill_buckets)


def _cfg(layers=2):
    return reduced(get_config("phi3-medium-14b"), layers=layers)


def _rc():
    return RunConfig(attention_impl="naive")


def _prompts(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 100, size=n).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# parity: one batched prefill dispatch == prompt_len teacher-forced ones
# ---------------------------------------------------------------------------

def test_prefill_matches_teacher_forced_loop():
    """The collected-KV prefill forward reproduces the token-at-a-time
    decode loop: same per-position logits (allclose — XLA CPU reassociates
    GEMM reductions differently at Lq=8 vs Lq=1, so bitwise equality ends
    at the last few float bits) and the same greedy continuation."""
    import jax
    import jax.numpy as jnp
    from repro.core.runtime import Runtime
    from repro.core.transform import (analyze, make_serve_decode_step,
                                      make_serve_prefill_step)
    from repro.models.model import build_model

    cfg = _cfg()
    shape = ShapeConfig("serve", 32, 1, "decode")
    rt = Runtime(cfg, _rc(), shape)
    model = build_model(cfg, rt)
    rt.plan = plan = analyze(model, rt)
    params = model.init(jax.random.key(0))
    (prompt,) = _prompts([7])
    new_toks = 5

    # reference: teacher-forced loop through decode_fn, scalar cache_len
    cache = model.init_cache(1, shape.seq_len)
    ref_logits, tok = [], None
    for i, t in enumerate(prompt):
        logits, cache = model.decode_fn(
            params, cache, jnp.asarray([[t]], jnp.int32), jnp.int32(i))
        ref_logits.append(logits[0, -1])
    ref_toks = []
    for k in range(new_toks):
        tok = int(jnp.argmax(ref_logits[-1]))
        ref_toks.append(tok)
        logits, cache = model.decode_fn(
            params, cache, jnp.asarray([[tok]], jnp.int32),
            jnp.int32(len(prompt) + k))
        ref_logits.append(logits[0, -1])

    # batched path: per-position logits from the collect-KV forward...
    full_logits, _ = model.prefill_cache_fn(params, prompt[None, :])
    np.testing.assert_allclose(
        np.asarray(full_logits[0]), np.asarray(jnp.stack(ref_logits[:7])),
        atol=1e-5, rtol=1e-5)

    # ...and the same greedy trajectory through one prefill + N decodes
    prefill = make_serve_prefill_step(model, rt, plan, greedy=True)
    decode = make_serve_decode_step(model, rt, plan,
                                    max_seq=shape.seq_len, greedy=True)
    lb = bucket_len(len(prompt), shape.seq_len)
    padded = np.zeros((1, lb), np.int32)
    padded[0, :len(prompt)] = prompt
    key = jax.random.key(0)
    cache2 = model.init_cache(1, shape.seq_len)
    lens = jnp.zeros((1,), jnp.int32)
    pend = jnp.zeros((1, 1), jnp.int32)
    cache2, lens, pend, first = prefill(
        params, cache2, lens, pend, jnp.asarray(padded),
        np.int32(len(prompt)), np.int32(0), key)
    toks = [int(first[0])]
    active = jnp.ones((1,), bool)
    for _ in range(new_toks - 1):
        cache2, lens, pend, out = decode(params, cache2, lens, pend,
                                         active, key)
        toks.append(int(out[0]))
    assert toks == ref_toks, (toks, ref_toks)
    assert int(lens[0]) == len(prompt) + new_toks - 1


def test_engine_matches_toy_server_tokens():
    """Concurrent mixed-length decoding on the engine reproduces the toy
    loop's *sequential* answers. The toy is only a valid reference drained
    one request at a time — decoding mixed-length prompts concurrently its
    shared cache_len attends slots over slot_pos.max() rows (the cross-slot
    hazard this PR removes), and its tokens genuinely differ."""
    cfg = _cfg(layers=1)
    scfg = ServerConfig(max_batch=2, max_seq=64)
    eng = Server(cfg, _rc(), scfg, seed=0)
    toy = ToyServer(cfg, _rc(), scfg, params=eng.params, seed=0)
    prompts = _prompts([4, 9, 6])
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=6))
    eng.run_until_drained()
    eng.close()
    for i, p in enumerate(prompts):
        toy.submit(Request(i, p, max_new_tokens=6))
        toy.run_until_drained()           # drain each alone: exact reference
    a = {r.uid: tuple(r.out_tokens) for r in eng.completed}
    b = {r.uid: tuple(r.out_tokens) for r in toy.completed}
    assert set(a) == set(b) == {0, 1, 2}
    # argmax near-ties can flip under XLA CPU reduction reassociation
    agree = sum(x == y for k in a for x, y in zip(a[k], b[k]))
    assert agree >= 16, (a, b)


# ---------------------------------------------------------------------------
# length buckets: one executable per power-of-two bucket
# ---------------------------------------------------------------------------

def test_bucket_helpers():
    assert [bucket_len(n, 64) for n in (1, 8, 9, 16, 17, 40, 63)] == \
        [8, 8, 16, 16, 32, 64, 64]
    assert prefill_buckets(64) == [8, 16, 32, 64]
    assert prefill_buckets(8) == [8]


def test_same_bucket_prompts_share_one_trace():
    """Admission is jit-cached per bucket: two same-bucket prompts cost two
    prefill *calls* but exactly one *trace* (the compile counter is a
    trace-time side effect inside the jitted function)."""
    cfg = _cfg(layers=1)
    sv = Server(cfg, _rc(), ServerConfig(max_batch=2, max_seq=64), seed=0)
    for i, p in enumerate(_prompts([5, 7, 20])):   # buckets 8, 8, 32
        sv.submit(Request(i, p, max_new_tokens=3))
    sv.run_until_drained()
    sv.close()
    assert sv.stats["prefill_calls"] == 3
    assert sv.stats["buckets"] == {8, 32}
    assert sv.stats["prefill_traces"] == 2, sv.stats
    assert sv.stats["decode_traces"] == 1, sv.stats
    assert all(len(r.out_tokens) == 3 for r in sv.completed)


# ---------------------------------------------------------------------------
# slot reuse: per-slot lengths end the shared-cache_len cross-slot hazard
# ---------------------------------------------------------------------------

def test_slot_reuse_is_bit_exact():
    """Regression for the shared-cache_len hazard: a request admitted into
    a freed slot whose cache still holds a *longer* previous tenant's rows
    must decode exactly as on a fresh server (per-slot lengths mask the
    stale tail; the old engine attended over slot_pos.max() rows)."""
    cfg = _cfg(layers=1)
    scfg = ServerConfig(max_batch=2, max_seq=64)
    sv = Server(cfg, _rc(), scfg, seed=0)
    long_a, long_b, short = _prompts([20, 12, 5])

    # occupy both slots with long prompts, drain, then reuse with a short
    # one -> rows [5, 20) of the reused slot hold stale K/V
    sv.submit(Request(0, long_a, max_new_tokens=4))
    sv.submit(Request(1, long_b, max_new_tokens=4))
    sv.run_until_drained()
    r = Request(2, short, max_new_tokens=8)
    sv.submit(r)
    sv.run_until_drained()
    sv.close()

    fresh = Server(cfg, _rc(), scfg, params=sv.params, seed=0)
    ref = Request(2, short, max_new_tokens=8)
    fresh.submit(ref)
    fresh.run_until_drained()
    fresh.close()

    assert r.out_tokens == ref.out_tokens, (r.out_tokens, ref.out_tokens)
    assert sv.stats["cross_slot_mismatches"] == 0
    assert sv.stats["prefill_calls"] == 3     # one dispatch per admission


# ---------------------------------------------------------------------------
# sampling: the greedy flag is wired through the device-side sampler
# ---------------------------------------------------------------------------

def test_greedy_flag_selects_device_sampler():
    cfg = _cfg(layers=1)
    scfg = ServerConfig(max_batch=2, max_seq=64, greedy=False,
                        temperature=0.7)
    sv = Server(cfg, _rc(), scfg, seed=0)
    (p,) = _prompts([6])
    sv.submit(Request(0, p, max_new_tokens=8))
    sv.run_until_drained()
    sv.close()
    (r,) = sv.completed
    assert len(r.out_tokens) == 8
    assert all(0 <= t < sv.rt.padded_vocab for t in r.out_tokens)

    # same seed, greedy server: trajectories may differ (sampled vs argmax)
    g = Server(cfg, _rc(), ServerConfig(max_batch=2, max_seq=64,
                                        greedy=True), params=sv.params,
               seed=0)
    g.submit(Request(0, p, max_new_tokens=8))
    g.run_until_drained()
    g.close()
    assert len(g.completed[0].out_tokens) == 8


def test_recurrent_family_refuses_paged_engine():
    cfg = reduced(get_config("rwkv6-7b"), layers=1)
    with pytest.raises(ValueError, match="ToyServer"):
        Server(cfg, _rc(), ServerConfig(max_batch=2, max_seq=64))


# ---------------------------------------------------------------------------
# serve-mesh pricing units (cost_model)
# ---------------------------------------------------------------------------

def test_serve_pull_pricing_units():
    dims = cm.MeshDims(model=4, data=2, pod=1, hosts=1)
    b = 1024.0
    # row-sharded pulls pay the psum ring: 2*alpha*b*(m-1)/m per step
    want = 2.0 * 0.1 * b * 3 / 4
    assert cm.serve_pull_bytes(b, 0.1, "ps_gather", dims) == want
    assert cm.serve_pull_bytes(b, 0.1, "ps", dims) == want
    assert cm.serve_pull_messages("ps_gather", dims) == 1
    # replicated tables answer the gather locally: zero wire
    for m in ("allreduce", "mpi_gatherv", "dense", "fsdp"):
        assert cm.serve_pull_bytes(b, 0.1, m, dims) == 0.0
        assert cm.serve_pull_messages(m, dims) == 0
    # single model shard: nothing to pull across
    one = cm.MeshDims(model=1, data=8, pod=1, hosts=1)
    assert cm.serve_pull_bytes(b, 0.1, "ps_gather", one) == 0.0

    pr = cm.serve_table_pricing(b=b, alpha=0.1, method="ps_gather",
                                dims=dims, batch_tokens=8)
    assert pr["pull_bytes"] == want
    assert pr["pull_s"] > 0.0
    assert pr["s_per_token"] == pytest.approx(pr["pull_s"] / 8)
    free = cm.serve_table_pricing(b=b, alpha=0.99, method="allreduce",
                                  dims=dims, batch_tokens=8)
    assert free["pull_s"] == free["s_per_token"] == 0.0


def test_decode_runtime_disables_census():
    """The serve path drops the observed-census reduction: nothing consumes
    the profile at inference and the scalar psum would ride every decode
    step; training runtimes keep it."""
    from repro.core.runtime import Runtime
    cfg = _cfg(layers=1)
    serve = Runtime(cfg, _rc(), ShapeConfig("s", 64, 4, "decode"))
    train = Runtime(cfg, _rc(), ShapeConfig("t", 64, 4, "train"))
    assert serve.embed_ctx().census is False
    assert train.embed_ctx().census is True


# ---------------------------------------------------------------------------
# serve-time per-table planning on a real mesh
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_serve_plan_flips_method_per_table():
    """One analyze() at decode shapes on a (4 data x 2 model) mesh: the
    Zipf-skewed vocab table serves its pulls row-sharded (ps_gather, paying
    a nonzero per-token exchange price) while the declared near-dense table
    is replicated and pulls for free — and the serve pricing rides
    Plan.tables() only for decode-kind plans."""
    code = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.runtime import Runtime
from repro.core.transform import analyze
from repro.models.model import build_model

cfg = reduced(get_config("parallax-nmt"), vocab=256)
rc = RunConfig(attention_impl="naive", remat="none", param_dtype="float32",
               compute_dtype="float32", wire_dtype="float32",
               capacity_mode="capped", capacity_factor=1.5, link_latency=0.0,
               table_zipf=(("embed", 1.3),), table_alpha=(("enc_embed", 0.99),))
mesh = make_mesh((4, 2), ("data", "model"))
out = {}
with use_mesh(mesh):
    for kind in ("decode", "train"):
        shape = ShapeConfig("probe", seq_len=64, global_batch=8, kind=kind)
        rt = Runtime(cfg, rc, shape, mesh=mesh)
        model = build_model(cfg, rt)
        out[kind] = analyze(model, rt).tables()
print("RESULT:" + json.dumps(out))
"""
    res = distributed_run(code, devices=8, timeout=600)
    serve, train = res["decode"], res["train"]
    assert serve["embed"]["method"] == "ps_gather", serve
    assert serve["enc_embed"]["method"] == "allreduce", serve
    # the flip carries real serve-mesh prices: row-sharded pays the ring,
    # replicated pulls locally
    assert serve["embed"]["serve"]["s_per_token"] > 0.0, serve
    assert serve["embed"]["serve"]["pull_bytes"] > 0.0
    assert serve["enc_embed"]["serve"]["s_per_token"] == 0.0
    assert math.isfinite(serve["embed"]["serve"]["pull_s"])
    # train-kind plans carry no serve pricing
    assert train["embed"]["serve"] is None
    assert train["enc_embed"]["serve"] is None
