"""analysis/: the plan-contract checker and the SPMD hygiene lint.

Fast half: lint rules on seeded fixture violations (exactly one finding
each), repo-wide lint cleanliness, and the contract matcher on
hand-built plans vs canned scheduled HLO. Distributed half: the checker
passes clean on the config zoo across {bucketed, overlap on/off,
fused-apply on/off, ps_gather sparse, two-level pod} and flags every
seeded mutation (extra per-param AR, wrong wire dtype, overlap
regression).
"""
import os
import subprocess
import sys

import pytest

from conftest import distributed_run

from repro.analysis.contract import check_contract
from repro.analysis.lint import lint_file, lint_repo
from repro.core.buckets import Bucket, BucketPlan
from repro.core.plan import ParamPlan, Plan

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "lint")
ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# lint: each seeded violation -> exactly one finding of its rule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture,kind", [
    ("bad_mesh_import.py", "jax-mesh-api"),
    ("bad_runconfig.py", "unhashable-config-field"),
    ("bad_psum.py", "raw-collective"),
    ("bad_tap.py", "tap-fwd-not-identity"),
])
def test_lint_fixture_single_finding(fixture, kind):
    findings = lint_file(os.path.join(FIXTURES, fixture), ROOT)
    assert len(findings) == 1, [str(f) for f in findings]
    assert findings[0].kind == kind
    assert fixture in findings[0].where


def test_lint_repo_clean():
    """The CI gate: src/, benchmarks/, tools/ carry zero violations."""
    findings = lint_repo(ROOT)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_spmd_lint_cli():
    tool = os.path.join(ROOT, "tools", "spmd_lint.py")
    ok = subprocess.run([sys.executable, tool], capture_output=True,
                        text=True, timeout=300)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run([sys.executable, tool, "--json", FIXTURES],
                         capture_output=True, text=True, timeout=300)
    assert bad.returncode == 1
    import json
    findings = json.loads(bad.stdout)
    assert {f["kind"] for f in findings} == {
        "jax-mesh-api", "unhashable-config-field", "raw-collective",
        "tap-fwd-not-identity"}


# ---------------------------------------------------------------------------
# contract matcher: hand-built plans vs canned scheduled HLO
# ---------------------------------------------------------------------------

def _plan(buckets, *, overlap=True, replicas=2, hosts=1, n_leaves=2):
    bp = BucketPlan(buckets=buckets, batch_axes=("data",),
                    replicas=replicas,
                    n_params=sum(len(b.sizes) for b in buckets),
                    wire_bytes=sum(b.nbytes for b in buckets),
                    bucket_bytes=1 << 20, hosts=hosts, overlap=overlap)
    params = [ParamPlan(f"p{i}", "allreduce", None, None, "float32",
                        False, 4) for i in range(n_leaves)]
    return Plan(model_cfg=None, run_cfg=None, shape_cfg=None, mesh=None,
                rules=None, params=params, bucket_plan=bp)


def _bucket(elems, *, dtype="float32", schedule="ring"):
    return Bucket(key=("allreduce", dtype, ()), idx=(0,), sizes=(elems,),
                  nbytes=elems * 4, schedule=schedule)


_PRE = """HloModule m, is_scheduled=true

%body (c: f32[8,8]) -> f32[8,8] {
  %c = f32[8,8]{1,0} parameter(0)
  ROOT %d = f32[8,8]{1,0} dot(%c, %c), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%cond (c: f32[8,8]) -> pred[] {
  %c = f32[8,8]{1,0} parameter(0)
  ROOT %q = pred[] constant(false)
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
"""
_POST = """  %scal = f32[5]{0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  ROOT %out = f32[8,8]{1,0} copy(%w)
}
"""
_LOOP = "  %w = f32[8,8]{1,0} while(%p0), condition=%cond, body=%body\n"


def _ar(name, elems, dtype="f32"):
    return (f"  %{name} = {dtype}[{elems}]{{0}} all-reduce(%p0), "
            "replica_groups={{0,1}}, to_apply=%add\n")


def test_contract_clean_ring_bucket():
    plan = _plan([_bucket(8192)])
    text = _PRE + _ar("ar0", 8192) + _LOOP + _POST
    assert check_contract(plan, text) == []


def test_contract_missing_bucket_collective():
    plan = _plan([_bucket(8192)])
    text = _PRE + _LOOP + _POST          # bucket all-reduce absent
    kinds = {f.kind for f in check_contract(plan, text)}
    assert kinds == {"missing-collective"}


def test_contract_flags_extra_per_param_all_reduce():
    plan = _plan([_bucket(8192)])
    text = _PRE + _ar("ar0", 8192) + _ar("extra", 9000) + _LOOP + _POST
    kinds = {f.kind for f in check_contract(plan, text)}
    assert "unexpected-collective" in kinds
    assert "collective-count" in kinds


def test_contract_flags_overlap_pin_mismatch():
    # plan says overlap=False (pin: +n_leaves elems); HLO shows the
    # unpinned overlap shape -> a schedule finding, not a failed match
    plan = _plan([_bucket(8192)], overlap=False, n_leaves=2)
    text = _PRE + _ar("ar0", 8192) + _LOOP + _POST
    findings = check_contract(plan, text)
    assert {f.kind for f in findings} == {"schedule"}, \
        [str(f) for f in findings]


def test_contract_overlap_scheduling_positions():
    # two buckets, overlap=True: both all-reduces land AFTER the last
    # dot-bearing loop -> the exchange does not overlap the backward
    plan = _plan([_bucket(4096), _bucket(6144)])
    late = _PRE + _LOOP + _ar("ar0", 4096) + _ar("ar1", 6144) + _POST
    kinds = {f.kind for f in check_contract(plan, late)}
    assert kinds == {"schedule"}
    early = _PRE + _ar("ar0", 4096) + _LOOP + _ar("ar1", 6144) + _POST
    assert check_contract(plan, early) == []


def test_contract_two_level_triple():
    plan = _plan([_bucket(8192, schedule="two_level")],
                 replicas=4, hosts=2)
    local = plan.bucket_plan.dims.local_replicas
    piece = 8192 // local
    text = (_PRE
            + f"  %rs = f32[{piece}]{{0}} reduce-scatter(%p0), "
              "replica_groups={{0,1}}, to_apply=%add\n"
            + _ar("ar0", piece)
            + f"  %ag = f32[8192]{{0}} all-gather(%rs), "
              "replica_groups={{0,1}}, dimensions={0}\n"
            + _LOOP + _POST)
    assert check_contract(plan, text) == []
    # dropping the inter-host hop breaks the triple
    text2 = (_PRE + _ar("ar0", piece) + _LOOP + _POST)
    kinds = {f.kind for f in check_contract(plan, text2)}
    assert "missing-collective" in kinds


def test_contract_strict_wire_dtype():
    plan = _plan([_bucket(8192, dtype="bfloat16")])
    text = _PRE + _ar("ar0", 8192) + _LOOP + _POST   # rides f32 in HLO
    # default: the CPU dry-run upcast is accepted (match by element count)
    assert check_contract(plan, text) == []
    kinds = {f.kind for f in check_contract(plan, text, strict_dtype=True)}
    assert kinds == {"wire-dtype"}


def test_contract_unfused_scalars():
    plan = _plan([_bucket(8192)])
    text = (_PRE + _ar("ar0", 8192) + _LOOP
            + _ar("extra_scalar", 3) + _POST)
    findings = check_contract(plan, text)
    assert {f.kind for f in findings} == {"unfused-scalars",
                                          "collective-count"}


# ---------------------------------------------------------------------------
# distributed: the zoo sweep, the verify gate, and the seeded mutations
# ---------------------------------------------------------------------------

SWEEP_PRELUDE = """
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import get_runner
from repro.data import SyntheticLM
from repro.analysis.contract import check_contract

shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
BASE = dict(attention_impl="naive", remat="none", param_dtype="float32",
            compute_dtype="float32", wire_dtype="float32")

def probe(arch, mesh_shape=(8, 1), axes=("data", "model"), **flags):
    cfg = reduced(get_config(arch))
    ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=cfg.is_encdec,
                     frames_dim=cfg.d_model, frames_len=8)
    mesh = make_mesh(mesh_shape, axes)
    with use_mesh(mesh):
        run = get_runner(cfg, shape, RunConfig(**BASE, **flags), mesh=mesh)
        txt = run.train_step.lower(
            run.state, ds.batch(0)).compile().as_text()
        bp = run.plan.bucket_plan
        return {"buckets": len(bp.buckets) if bp else 0,
                "methods": run.plan.table_methods,
                "findings": [str(x) for x in check_contract(run.plan, txt)]}
"""

SWEEP_ENCDEC_CODE = SWEEP_PRELUDE + """
out = {}
out["default"] = probe("seamless-m4t-medium")
out["no_overlap"] = probe("seamless-m4t-medium", overlap=False)
out["no_fused"] = probe("seamless-m4t-medium", fused_apply=False,
                        bucket_bytes=256 * 1024)
out["gatherv"] = probe("seamless-m4t-medium", comm_mode="mpi",
                       bucket_bytes=256 * 1024)
print("RESULT:" + json.dumps(out))
"""

SWEEP_ZOO_CODE = SWEEP_PRELUDE + """
out = {}
for arch in ("phi3-medium-14b", "hymba-1.5b", "rwkv6-7b",
             "command-r-35b", "stablelm-12b"):
    out[arch] = probe(arch)
out["unbucketed"] = probe("phi3-medium-14b", bucket_bytes=0)
print("RESULT:" + json.dumps(out))
"""

SWEEP_SPARSE_POD_CODE = SWEEP_PRELUDE + """
import tempfile
with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as fp:
    json.dump({"link_latency": 1e-9, "link_bw": 1e9}, fp)
    hw_fast = fp.name
with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as fp:
    json.dump({"inter_bw": 12.5e9, "inter_latency": 10e-6}, fp)
    hw_pod = fp.name
out = {}
# the Table-3 argmin flips the table to ps_gather under tiny alpha on a
# latency-free link
out["ps_gather"] = probe("phi3-medium-14b", mesh_shape=(2, 4),
                         comm_mode="ps", hw_profile=hw_fast,
                         table_alpha=(("embed", 0.01),))
# pod mesh + slow inter tier: the bucket rides the two-level triple
out["two_level"] = probe("seamless-m4t-medium", mesh_shape=(2, 4, 1),
                         axes=("pod", "data", "model"), hw_profile=hw_pod,
                         bucket_bytes=1024 * 1024)
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.distributed
def test_contract_clean_on_encdec_variants():
    res = distributed_run(SWEEP_ENCDEC_CODE, devices=8, timeout=900)
    for name, r in res.items():
        assert r["findings"] == [], (name, r)
    assert res["no_fused"]["buckets"] >= 2
    assert res["gatherv"]["methods"].get("embed") == "mpi_gatherv"


@pytest.mark.distributed
def test_contract_clean_on_config_zoo():
    res = distributed_run(SWEEP_ZOO_CODE, devices=8, timeout=1200)
    for name, r in res.items():
        assert r["findings"] == [], (name, r)
    assert res["unbucketed"]["buckets"] == 0
    assert sum(r["buckets"] for r in res.values()) >= 5


@pytest.mark.distributed
def test_contract_clean_on_ps_gather_and_two_level():
    res = distributed_run(SWEEP_SPARSE_POD_CODE, devices=8, timeout=900)
    for name, r in res.items():
        assert r["findings"] == [], (name, r)
    assert res["ps_gather"]["methods"].get("embed") == "ps_gather"


MUTATION_CODE = """
import dataclasses
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import get_runner
from repro.data import SyntheticLM
from repro.analysis.contract import check_contract

cfg = reduced(get_config("seamless-m4t-medium"))
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          bucket_bytes=256 * 1024)
ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True,
                 frames_dim=cfg.d_model, frames_len=8)

def hlo(run):
    return run.train_step.lower(run.state, ds.batch(0)).compile().as_text()

mesh = make_mesh((8, 1), ("data", "model"))
with use_mesh(mesh):
    ov = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
    base = get_runner(cfg, shape, RunConfig(**kw, overlap=False), mesh=mesh)
    flat = get_runner(cfg, shape, RunConfig(**dict(kw, bucket_bytes=0)),
                      mesh=mesh)
    t_ov, t_base, t_flat = hlo(ov), hlo(base), hlo(flat)
    bp = ov.plan.bucket_plan
    wrong_wire = dataclasses.replace(ov.plan, bucket_plan=dataclasses.replace(
        bp, buckets=[dataclasses.replace(b, key=(b.key[0], "bfloat16",
                                                 b.key[2]))
                     for b in bp.buckets]))
    res = {
        "clean_ov": [str(x) for x in check_contract(ov.plan, t_ov)],
        "clean_base": [str(x) for x in check_contract(base.plan, t_base)],
        # overlap regression: overlap=False HLO against the overlap=True plan
        "overlap_mut": sorted({x.kind
                               for x in check_contract(ov.plan, t_base)}),
        # extra per-param all-reduces: flat HLO against the bucketed plan
        "extra_ar_mut": sorted({x.kind
                                for x in check_contract(ov.plan, t_flat)}),
        # wrong wire dtype, strict mode
        "wire_mut": sorted({x.kind
                            for x in check_contract(wrong_wire, t_ov,
                                                    strict_dtype=True)}),
    }
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.distributed
def test_contract_flags_seeded_mutations():
    res = distributed_run(MUTATION_CODE, devices=8, timeout=900)
    assert res["clean_ov"] == [], res
    assert res["clean_base"] == [], res
    assert res["overlap_mut"] == ["schedule"], res
    assert "unexpected-collective" in res["extra_ar_mut"], res
    assert "collective-count" in res["extra_ar_mut"], res
    assert res["wire_mut"] == ["wire-dtype"], res


VERIFY_GATE_CODE = """
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import estimate_census, get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("seamless-m4t-medium"))
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          bucket_bytes=256 * 1024, verify_contract=True)
ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True,
                 frames_dim=cfg.d_model, frames_len=8)
mesh = make_mesh((8, 1), ("data", "model"))
with use_mesh(mesh):
    # the gate runs inside build_step: a fresh build AND a forced replan
    # both pass it without raising
    run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
    loss0 = float(run.run(ds.batch(0))["loss"])
    diff = run.replan(estimate_census(run.model, run.rt), force=True)
    loss1 = float(run.run(ds.batch(1))["loss"])
    findings = run.check_contract()
print("RESULT:" + json.dumps({
    "rebuilt": diff["rebuilt"], "findings": [str(x) for x in findings],
    "losses_finite": bool(loss0 == loss0 and loss1 == loss1)}))
"""


@pytest.mark.distributed
def test_verify_contract_gate_on_build_and_replan():
    res = distributed_run(VERIFY_GATE_CODE, devices=8, timeout=900)
    assert res["rebuilt"] is True, res
    assert res["findings"] == [], res
    assert res["losses_finite"], res
