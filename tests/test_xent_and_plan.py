"""Sharded cross-entropy vs oracle; planner invariants (escalation,
divisibility fallbacks, head padding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from conftest import distributed_run
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.runtime import Runtime
from repro.core.transform import analyze
from repro.core.xent import sharded_xent, _xent_local
from repro.models.model import build_model


def _ref_xent(logits, labels, vocab):
    logits = np.asarray(logits, np.float64)[..., :vocab]
    mx = logits.max(-1, keepdims=True)
    lse = np.log(np.exp(logits - mx).sum(-1)) + mx[..., 0]
    tgt = np.take_along_axis(logits, np.asarray(labels)[..., None],
                             -1)[..., 0]
    return lse - tgt


@settings(max_examples=20, deadline=None)
@given(st.integers(5, 40), st.integers(0, 100))
def test_xent_local_matches_reference(vocab, seed):
    k = jax.random.key(seed)
    logits = jax.random.normal(k, (2, 6, vocab + 3), jnp.float32) * 3
    labels = jax.random.randint(jax.random.fold_in(k, 1), (2, 6), 0, vocab)
    got = _xent_local(logits, labels, model_axis="", vocab=vocab, shards=1)
    want = _ref_xent(logits, labels, vocab)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@pytest.mark.distributed
def test_sharded_xent_matches_local():
    code = """
import jax.numpy as jnp
from repro.core.xent import sharded_xent, _xent_local

vocab = 61
mesh = make_mesh((2, 4), ("data", "model"))
logits = jax.random.normal(jax.random.key(0), (4, 8, 64), jnp.float32) * 4
labels = jax.random.randint(jax.random.key(1), (4, 8), 0, vocab)
local = _xent_local(logits, labels, model_axis="", vocab=vocab, shards=1)

def f(lg, lb):
    return sharded_xent(lg, lb, mesh=mesh, model_axis="model",
                        batch_axes=("data",), vocab=vocab)
with use_mesh(mesh):
    got = jax.jit(f)(logits, labels)
# also grads flow
def loss(lg):
    return sharded_xent(lg, labels, mesh=mesh, model_axis="model",
                        batch_axes=("data",), vocab=vocab).mean()
with use_mesh(mesh):
    g = jax.jit(jax.grad(loss))(logits)
probs_ok = bool(jnp.all(jnp.isfinite(g)))
print("RESULT:" + json.dumps({
    "err": float(jnp.abs(got - local).max()),
    "grad_finite": probs_ok,
    "pad_grad_zero": float(jnp.abs(g[..., vocab:]).max()),
}))
"""
    res = distributed_run(code, devices=8)
    assert res["err"] < 1e-4
    assert res["grad_finite"]
    assert res["pad_grad_zero"] == 0.0   # padded vocab rows stay frozen


@pytest.mark.distributed
def test_planner_escalates_zero_stage_for_big_models():
    cfg = get_config("mistral-large-123b")
    code = """
from repro.configs import get_config, RunConfig, SHAPES
from repro.core.runtime import Runtime
from repro.core.transform import analyze
from repro.models.model import build_model

mesh = make_mesh((2, 4), ("data", "model"))
rt = Runtime(get_config("mistral-large-123b"), RunConfig(),
             SHAPES["train_4k"], mesh=mesh)
model = build_model(rt.model_cfg, rt)
plan = analyze(model, rt)
small_rt = Runtime(get_config("hymba-1.5b"), RunConfig(),
                   SHAPES["train_4k"], mesh=mesh)
small_model = build_model(small_rt.model_cfg, small_rt)
small_plan = analyze(small_model, small_rt)
print("RESULT:" + json.dumps({"big": plan.zero_stage,
                              "small": small_plan.zero_stage,
                              "methods": small_plan.methods()}))
"""
    res = distributed_run(code, devices=8)
    assert res["big"] >= 1          # must shard optimizer state at least
    assert res["small"] == 0        # small model stays replicated


@pytest.mark.distributed
def test_pspec_divisibility_fallback():
    from repro.core.plan import MeshRules
    rules = MeshRules(None, {})
    assert rules.pspec((None, "mlp"), (4, 7)) == jax.sharding.PartitionSpec()

    code = """
from repro.core.plan import MeshRules, default_rules
mesh = make_mesh((2, 4), ("data", "model"))
rules = MeshRules(mesh, default_rules(mesh, "train", 8))
ok1 = rules.pspec(("vocab", "embed"), (64, 16)) == P("model", None)
ok2 = rules.pspec(("vocab", "embed"), (63, 16)) == P(None, None)  # 63 % 4 != 0
ok3 = rules.pspec((None, "mlp"), (16, 28)) == P(None, "model")
print("RESULT:" + json.dumps({"ok": bool(ok1 and ok2 and ok3)}))
"""
    res = distributed_run(code, devices=8)
    assert res["ok"]


def test_head_padding_counts():
    cfg = get_config("phi3-medium-14b")
    assert cfg.padded_heads(16) == 48       # 40 -> 48
    assert cfg.padded_heads(8) == 40
    assert get_config("hymba-1.5b").padded_heads(16) == 32   # 25 -> 32
    assert get_config("command-r-35b").padded_heads(16) == 64  # already fine
    assert get_config("hymba-1.5b").padded_vocab(16) == 32016
