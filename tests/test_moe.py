"""MoE: routing/capacity invariants and identity-expert equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import distributed_run
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.runtime import Runtime
from repro.models import moe as moe_mod
from repro.models.layers import init_tree


def _setup(e=4, k=1, cf=8.0, d=16, f=32):
    cfg = reduced(get_config("grok-1-314b"), d_model=d, d_ff=f, experts=e)
    cfg = type(cfg)(**{**cfg.__dict__, "experts_per_token": k,
                       "moe_capacity_factor": cf})
    rt = Runtime(cfg, RunConfig(attention_impl="naive", remat="none",
                                compute_dtype="float32",
                                param_dtype="float32",
                                wire_dtype="float32"),
                 ShapeConfig("t", 8, 2, "train"))
    params = init_tree(jax.random.key(0), moe_mod.moe_specs(cfg, "tp"),
                       jnp.float32)
    return cfg, rt, params


def test_identical_experts_equal_plain_ffn():
    """With every expert's weights identical, routing must not matter:
    MoE(x) == FFN(x) for any router decisions (capacity permitting)."""
    cfg, rt, params = _setup(e=4, k=2, cf=8.0)
    w0g = params["w_gate"][0]
    for key in ("w_gate", "w_up", "w_down"):
        params[key] = jnp.broadcast_to(params[key][0:1], params[key].shape)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model), jnp.float32)
    out, metrics = moe_mod.moe_ffn(params, x, cfg=cfg, rt=rt, exec_mode="tp")
    want = jax.nn.silu(x @ params["w_gate"][0]) * (x @ params["w_up"][0])
    want = want @ params["w_down"][0]
    assert int(metrics["moe_dropped"]) == 0
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_no_drops_with_ample_capacity():
    cfg, rt, params = _setup(e=4, k=2, cf=16.0)
    x = jax.random.normal(jax.random.key(2), (2, 8, cfg.d_model), jnp.float32)
    _, metrics = moe_mod.moe_ffn(params, x, cfg=cfg, rt=rt, exec_mode="tp")
    assert int(metrics["moe_dropped"]) == 0


def test_tiny_capacity_drops_and_reports():
    cfg, rt, params = _setup(e=4, k=1, cf=0.3)
    x = jax.random.normal(jax.random.key(3), (2, 8, cfg.d_model), jnp.float32)
    out, metrics = moe_mod.moe_ffn(params, x, cfg=cfg, rt=rt, exec_mode="tp")
    assert int(metrics["moe_dropped"]) > 0
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.distributed
def test_ep_equals_tp_distributed():
    """Expert-parallel a2a execution == tensor-parallel execution == local."""
    code = """
import jax.numpy as jnp
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.runtime import Runtime
from repro.models import moe as moe_mod
from repro.models.layers import init_tree

cfg0 = reduced(get_config("grok-1-314b"), d_model=16, d_ff=32, experts=8)
cfg = type(cfg0)(**{**cfg0.__dict__, "experts_per_token": 2,
                    "moe_capacity_factor": 8.0})
rc = RunConfig(attention_impl="naive", remat="none", compute_dtype="float32",
               param_dtype="float32", wire_dtype="float32")
shape = ShapeConfig("t", 8, 4, "train")

rt0 = Runtime(cfg, rc, shape)
params = init_tree(jax.random.key(0), moe_mod.moe_specs(cfg, "tp"),
                   jnp.float32)
x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.float32)
ref, _ = moe_mod.moe_ffn(params, x, cfg=cfg, rt=rt0, exec_mode="tp")

mesh = make_mesh((2, 4), ("data", "model"))
out = {}
with use_mesh(mesh):
    for mode in ("tp", "ep"):
        rt = Runtime(cfg, rc, shape, mesh=mesh)
        got, m = jax.jit(lambda p, xx: moe_mod.moe_ffn(
            p, xx, cfg=cfg, rt=rt, exec_mode=mode))(params, x)
        out[mode] = float(jnp.abs(got - ref).max())
print("RESULT:" + json.dumps(out))
"""
    res = distributed_run(code, devices=8, timeout=600)
    assert res["tp"] < 1e-4, res
    assert res["ep"] < 1e-4, res
