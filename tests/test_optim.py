"""Optimizers vs hand-rolled references; OPAU clip semantics; EMA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizer import adamw, momentum, sgd, global_norm, \
    clip_by_global_norm


def _params():
    k = jax.random.key(0)
    return {"a": jax.random.normal(k, (4, 8), jnp.float32),
            "b": {"w": jax.random.normal(jax.random.fold_in(k, 1), (8,),
                                         jnp.float32)}}


def _grads(scale=1.0):
    k = jax.random.key(9)
    return {"a": scale * jax.random.normal(k, (4, 8), jnp.float32),
            "b": {"w": scale * jax.random.normal(jax.random.fold_in(k, 2),
                                                 (8,), jnp.float32)}}


def test_adamw_matches_reference():
    lr, b1, b2, eps = 1e-2, 0.9, 0.95, 1e-8
    opt = adamw(lr, b1, b2, eps, clip_norm=None)
    state = opt.init(_params())
    g = _grads()
    state2, _ = opt.update(state, g)

    # manual reference, step 1
    for name, p0, gl in [("a", _params()["a"], g["a"]),
                         ("bw", _params()["b"]["w"], g["b"]["w"])]:
        m = (1 - b1) * gl
        v = (1 - b2) * jnp.square(gl)
        mhat = m / (1 - b1)
        vhat = v / (1 - b2)
        want = p0 - lr * mhat / (jnp.sqrt(vhat) + eps)
        got = state2.params["a"] if name == "a" else state2.params["b"]["w"]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5)


def test_clip_by_global_norm_matches_formula():
    g = _grads(scale=10.0)
    norm = float(global_norm(g))
    want = np.sqrt(sum(float(jnp.sum(x * x)) for x in jax.tree.leaves(g)))
    assert abs(norm - want) / want < 1e-6
    clipped, _ = clip_by_global_norm(g, 1.0)
    post = float(global_norm(clipped))
    assert abs(post - 1.0) < 1e-4


def test_clip_noop_below_threshold():
    g = _grads(scale=1e-3)
    clipped, norm = clip_by_global_norm(g, 1.0)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(clipped)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_momentum_and_sgd_step():
    p = _params()
    g = _grads()
    s_sgd = sgd(0.1).init(p)
    s_sgd2, _ = sgd(0.1).update(s_sgd, g)
    np.testing.assert_allclose(np.asarray(s_sgd2.params["a"]),
                               np.asarray(p["a"] - 0.1 * g["a"]), rtol=1e-6)
    opt = momentum(0.1, mu=0.9, clip_norm=None)
    s2, _ = opt.update(opt.init(p), g)
    np.testing.assert_allclose(np.asarray(s2.params["a"]),
                               np.asarray(p["a"] - 0.1 * g["a"]), rtol=1e-6)
    s3, _ = opt.update(s2, g)
    want = s2.params["a"] - 0.1 * (0.9 * g["a"] + g["a"])
    np.testing.assert_allclose(np.asarray(s3.params["a"]), np.asarray(want),
                               rtol=1e-5)


def test_ema_tracks_params():
    opt = adamw(1e-2, ema_decay=0.5, clip_norm=None)
    state = opt.init(_params())
    state2, _ = opt.update(state, _grads())
    want = 0.5 * np.asarray(state.ema["a"]) + 0.5 * np.asarray(
        state2.params["a"], np.float32)
    np.testing.assert_allclose(np.asarray(state2.ema["a"]), want, rtol=1e-5)
