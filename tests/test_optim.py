"""Optimizers vs hand-rolled references; OPAU clip semantics; EMA;
fused bucket-apply layout + bit-exactness vs the per-param path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.buckets import Bucket, BucketPlan
from repro.optim.optimizer import adamw, momentum, sgd, global_norm, \
    clip_by_global_norm, bucket_segments, fuse_state, is_fused, unfuse_state


def _params():
    k = jax.random.key(0)
    return {"a": jax.random.normal(k, (4, 8), jnp.float32),
            "b": {"w": jax.random.normal(jax.random.fold_in(k, 1), (8,),
                                         jnp.float32)}}


def _grads(scale=1.0):
    k = jax.random.key(9)
    return {"a": scale * jax.random.normal(k, (4, 8), jnp.float32),
            "b": {"w": scale * jax.random.normal(jax.random.fold_in(k, 2),
                                                 (8,), jnp.float32)}}


def test_adamw_matches_reference():
    lr, b1, b2, eps = 1e-2, 0.9, 0.95, 1e-8
    opt = adamw(lr, b1, b2, eps, clip_norm=None)
    state = opt.init(_params())
    g = _grads()
    state2, _ = opt.update(state, g)

    # manual reference, step 1
    for name, p0, gl in [("a", _params()["a"], g["a"]),
                         ("bw", _params()["b"]["w"], g["b"]["w"])]:
        m = (1 - b1) * gl
        v = (1 - b2) * jnp.square(gl)
        mhat = m / (1 - b1)
        vhat = v / (1 - b2)
        want = p0 - lr * mhat / (jnp.sqrt(vhat) + eps)
        got = state2.params["a"] if name == "a" else state2.params["b"]["w"]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5)


def test_clip_by_global_norm_matches_formula():
    g = _grads(scale=10.0)
    norm = float(global_norm(g))
    want = np.sqrt(sum(float(jnp.sum(x * x)) for x in jax.tree.leaves(g)))
    assert abs(norm - want) / want < 1e-6
    clipped, _ = clip_by_global_norm(g, 1.0)
    post = float(global_norm(clipped))
    assert abs(post - 1.0) < 1e-4


def test_clip_noop_below_threshold():
    g = _grads(scale=1e-3)
    clipped, norm = clip_by_global_norm(g, 1.0)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(clipped)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_momentum_and_sgd_step():
    p = _params()
    g = _grads()
    s_sgd = sgd(0.1).init(p)
    s_sgd2, _ = sgd(0.1).update(s_sgd, g)
    np.testing.assert_allclose(np.asarray(s_sgd2.params["a"]),
                               np.asarray(p["a"] - 0.1 * g["a"]), rtol=1e-6)
    opt = momentum(0.1, mu=0.9, clip_norm=None)
    s2, _ = opt.update(opt.init(p), g)
    np.testing.assert_allclose(np.asarray(s2.params["a"]),
                               np.asarray(p["a"] - 0.1 * g["a"]), rtol=1e-6)
    s3, _ = opt.update(s2, g)
    want = s2.params["a"] - 0.1 * (0.9 * g["a"] + g["a"])
    np.testing.assert_allclose(np.asarray(s3.params["a"]), np.asarray(want),
                               rtol=1e-5)


def test_ema_tracks_params():
    opt = adamw(1e-2, ema_decay=0.5, clip_norm=None)
    state = opt.init(_params())
    state2, _ = opt.update(state, _grads())
    want = 0.5 * np.asarray(state.ema["a"]) + 0.5 * np.asarray(
        state2.params["a"], np.float32)
    np.testing.assert_allclose(np.asarray(state2.ema["a"]), want, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused bucket-apply: state layout + bit-exactness vs the per-param path
# ---------------------------------------------------------------------------

def _bucket_plan():
    """One bucket holding leaf 0 ('a', 32 elements); leaf 1 ('b/w') stays
    unbucketed — both the bucket-native and the surviving per-leaf path of
    update_fused are exercised."""
    b = Bucket(key=("allreduce", "float32", ()), idx=(0,), sizes=(32,),
               nbytes=32 * 4)
    return BucketPlan(buckets=[b], batch_axes=("data",), replicas=1,
                      n_params=1, wire_bytes=b.nbytes, bucket_bytes=1 << 20)


def _assert_states_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_bucket_segments_layout():
    bp = _bucket_plan()
    assert bucket_segments(bp) == {0: (0, 0, 32)}


def test_fuse_unfuse_roundtrip_exact():
    opt = adamw(1e-2, ema_decay=0.5, clip_norm=None)
    state = opt.init(_params())
    bp = _bucket_plan()
    fused = fuse_state(state, bp)
    assert is_fused(fused) and not is_fused(state)
    # bucketed leaf positions hold no buffer in the fused layout
    assert fused.m["leaf"]["a"] is None
    assert fused.m["leaf"]["b"]["w"] is not None
    _assert_states_equal(state, unfuse_state(fused, bp))


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(1e-2, b1=0.9, b2=0.95, weight_decay=0.1, clip_norm=1.0,
                  ema_decay=0.9),
    lambda: adamw(1e-2, weight_decay=0.0, clip_norm=None, ema_decay=0.0),
    lambda: momentum(1e-1, mu=0.9, clip_norm=1.0, ema_decay=0.5),
])
def test_fused_update_bit_identical_f32(make_opt):
    """update_fused replays update's cast/reduce chain op for op: at f32 the
    two trajectories (params, moments, EMA, grad_norm) are bitwise equal
    over multiple steps, including clipping and weight decay."""
    opt = make_opt()
    bp = _bucket_plan()
    ref = opt.init(_params())
    fused = fuse_state(opt.init(_params()), bp)
    # jit both, as the train step does: XLA canonicalizes the reshape
    # between a leaf and its flat bucket segment, so the clip-norm
    # reduction associates identically (eager dispatch would differ at ULP)
    upd = jax.jit(opt.update)
    upd_fused = jax.jit(lambda s, g, bufs: opt.update_fused(s, g, bufs, bp))
    for step in range(3):
        g = _grads(scale=0.5 + step)            # crosses the clip threshold
        bufs = [jnp.reshape(g["a"], (-1,)).astype(jnp.float32)]
        ref, m_ref = upd(ref, g)
        fused, m_fused = upd_fused(fused, g, bufs)
        _assert_states_equal(ref, unfuse_state(fused, bp))
        if "grad_norm" in m_ref:
            assert float(m_ref["grad_norm"]) == float(m_fused["grad_norm"])


def test_fused_wd_mask_segments():
    """A param-wise weight-decay mask becomes a per-bucket segment vector;
    fused and per-param agree bitwise under a non-uniform mask."""
    mask = {"a": 0.0, "b": {"w": 1.0}}
    opt = adamw(1e-2, weight_decay=0.2, clip_norm=None, wd_mask=mask)
    bp = _bucket_plan()
    ref, _ = opt.update(opt.init(_params()), _grads())
    fused = fuse_state(opt.init(_params()), bp)
    bufs = [jnp.reshape(_grads()["a"], (-1,)).astype(jnp.float32)]
    fused, _ = opt.update_fused(fused, _grads(), bufs, bp)
    _assert_states_equal(ref, unfuse_state(fused, bp))


def test_sgd_has_no_fused_path():
    assert sgd(0.1).update_fused is None
    # and a stateless sgd state never reads as fused
    assert not is_fused(sgd(0.1).init(_params()))
