"""End-to-end behaviour: training converges, serving drains, resume works."""
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.transform import get_runner
from repro.data import SyntheticLM


def test_train_loss_decreases(tiny_shape):
    cfg = reduced(get_config("phi3-medium-14b"))
    runner = get_runner(cfg, tiny_shape,
                        RunConfig(attention_impl="naive", remat="none",
                                  learning_rate=3e-3))
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    losses = [float(runner.run(ds.batch(i))["loss"]) for i in range(20)]
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_trainer_checkpoint_resume(tmp_path, tiny_shape):
    from repro.runtime.trainer import Trainer, TrainerConfig
    cfg = reduced(get_config("stablelm-12b"))
    rc = RunConfig(attention_impl="naive", remat="none")
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    tcfg = TrainerConfig(total_steps=6, ckpt_dir=str(tmp_path / "ckpt"),
                         ckpt_every=3)
    seen = {}
    t1 = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    t1.run(on_metrics=lambda s, m: seen.setdefault(s, m["loss"]))
    t1.ckpt.wait()
    assert t1.ckpt.last_committed == 6

    # resume from step 6 and train 3 more — deterministic data continuation
    tcfg2 = TrainerConfig(total_steps=9, ckpt_dir=str(tmp_path / "ckpt"),
                          ckpt_every=100)
    t2 = Trainer(cfg, tiny_shape, rc, tcfg2, ds)
    t2.maybe_restore()
    assert t2.step == 6
    t2.run()
    assert t2.step == 9


def test_trainer_retries_after_failure(tmp_path, tiny_shape):
    from repro.runtime.trainer import Trainer, TrainerConfig
    cfg = reduced(get_config("phi3-medium-14b"), layers=1)
    rc = RunConfig(attention_impl="naive", remat="none")
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    tcfg = TrainerConfig(total_steps=4, ckpt_dir=str(tmp_path / "c"),
                         ckpt_every=1, max_retries=2)
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    real_step = t.train_step
    boom = {"armed": False}

    def flaky(state, batch):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")
        return real_step(state, batch)

    t.train_step = flaky
    t.run()           # warms checkpoints
    boom["armed"] = True
    t.tcfg = TrainerConfig(total_steps=8, ckpt_dir=str(tmp_path / "c"),
                           ckpt_every=1, max_retries=2)
    t.run()           # hits the failure, restores, finishes
    assert t.step == 8


def test_server_drains_and_is_deterministic():
    from repro.runtime.server import Request, Server, ServerConfig
    cfg = reduced(get_config("phi3-medium-14b"), layers=1)

    def run_once():
        rng = np.random.default_rng(0)
        server = Server(cfg, RunConfig(attention_impl="naive"),
                        ServerConfig(max_batch=2, max_seq=64))
        for i in range(5):
            server.submit(Request(uid=i,
                                  prompt=rng.integers(0, cfg.vocab_size, 4,
                                                      dtype=np.int32),
                                  max_new_tokens=4))
        done = server.run_until_drained()
        return {r.uid: tuple(r.out_tokens) for r in done}

    a = run_once()
    b = run_once()
    assert len(a) == len(b) == 5
    assert all(len(v) == 4 for v in a.values())
    # token-level equality can flip on argmax near-ties under XLA CPU's
    # reduction reassociation; require >= 90% agreement across runs
    agree = sum(x == y for k in a for x, y in zip(a[k], b[k]))
    assert agree >= 18, (a, b)
