"""HLO analyzer: trip-count multipliers, dot FLOPs, collective accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.compat import cost_analysis
from repro.utils.hlo import analyze_hlo, _shape_bytes, _ring_factor


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]{1,0}") == 128
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[2,2]{1,0}, s32[4])") == 32
    assert _shape_bytes("pred[]") == 1


def test_ring_factors():
    assert _ring_factor("all-reduce", 4) == pytest.approx(1.5)
    assert _ring_factor("all-gather", 4) == pytest.approx(0.75)
    assert _ring_factor("all-reduce", 1) == 0.0


def test_scan_trip_count_correction():
    """cost_analysis counts a scan body once; the parser must multiply."""
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    xs = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    s = analyze_hlo(compiled.as_text())
    want = 5 * 2 * 64 * 32 * 32
    assert abs(s.dot_flops - want) / want < 1e-6
    # XLA's own count misses the 5x
    xla = cost_analysis(compiled)["flops"]
    assert xla < s.dot_flops


def test_nested_scan_multipliers():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), None
            c, _ = jax.lax.scan(inner, c, jnp.arange(3))
            return c, None
        y, _ = jax.lax.scan(outer, x, w)
        return y.sum()

    xs = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 16, 16), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    s = analyze_hlo(compiled.as_text())
    want = 4 * 3 * 2 * 16 * 16 * 16
    assert abs(s.dot_flops - want) / want < 1e-6


def test_canned_collective_parse():
    text = """
HloModule test

ENTRY %main (p0: f32[16,8]) -> f32[16,8] {
  %p0 = f32[16,8]{1,0} parameter(0)
  %ar = f32[16,8]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %out = f32[16,8]{1,0} copy(%ar)
}
"""
    s = analyze_hlo(text)
    assert s.collective_count.get("all-reduce") == 1
    assert s.collective_raw_bytes == 16 * 8 * 4
    assert s.collective_bytes == pytest.approx(16 * 8 * 4 * 1.5)
    # f32 wire-correction halves it
    s2 = analyze_hlo(text, f32_collective_scale=0.5)
    assert s2.collective_bytes == pytest.approx(16 * 8 * 4 * 0.75)
