"""HLO analyzer: trip-count multipliers, dot FLOPs, collective accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.compat import cost_analysis
from repro.utils.hlo import (analyze_hlo, dot_bearing_events, _group_size,
                             _replica_groups, _result_type, _ring_factor,
                             _shape_bytes)


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]{1,0}") == 128
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[2,2]{1,0}, s32[4])") == 32
    assert _shape_bytes("pred[]") == 1


def test_start_collective_counts_result_half_only():
    """Async ``-start`` collectives are typed (operands, results); summing
    the whole tuple double-counts the wire bytes."""
    t = "(f32[4,8]{1,0}, f32[32,8]{1,0})"
    assert _shape_bytes(_result_type("all-gather-start", t)) == 32 * 8 * 4
    # sync op with a genuine tuple result is untouched
    assert _shape_bytes(_result_type("all-gather", t)) == (4 + 32) * 8 * 4
    # all-reduce-start aliases equal shapes; result half = one of them
    t2 = "(f32[16]{0}, f32[16]{0})"
    assert _shape_bytes(_result_type("all-reduce-start", t2)) == 64
    # odd tuples (no operand/result split) pass through
    t3 = "(f32[4], f32[4], s32[2])"
    assert _shape_bytes(_result_type("all-reduce-start", t3)) == 40


def test_replica_groups_multi_group():
    assert _replica_groups("all-reduce(...), replica_groups={{0,1},{2,3}}"
                           ) == [[0, 1], [2, 3]]
    assert _replica_groups("..., replica_groups={0,1,2}") == [[0, 1, 2]]
    # unequal groups: ring cost follows the LARGEST group
    line = "..., replica_groups={{0},{1,2,3}}"
    assert _replica_groups(line) == [[0], [1, 2, 3]]
    assert _group_size(line) == 3
    assert _group_size("..., replica_groups={{4,5},{6,7}}") == 2
    # iota tile-assignment form survives
    assert _group_size("..., replica_groups=[4,2]<=[8]") == 2


def test_ring_factors():
    assert _ring_factor("all-reduce", 4) == pytest.approx(1.5)
    assert _ring_factor("all-gather", 4) == pytest.approx(0.75)
    assert _ring_factor("all-reduce", 1) == 0.0


def test_scan_trip_count_correction():
    """cost_analysis counts a scan body once; the parser must multiply."""
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    xs = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    s = analyze_hlo(compiled.as_text())
    want = 5 * 2 * 64 * 32 * 32
    assert abs(s.dot_flops - want) / want < 1e-6
    # XLA's own count misses the 5x
    xla = cost_analysis(compiled)["flops"]
    assert xla < s.dot_flops


def test_nested_scan_multipliers():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), None
            c, _ = jax.lax.scan(inner, c, jnp.arange(3))
            return c, None
        y, _ = jax.lax.scan(outer, x, w)
        return y.sum()

    xs = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 16, 16), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    s = analyze_hlo(compiled.as_text())
    want = 4 * 3 * 2 * 16 * 16 * 16
    assert abs(s.dot_flops - want) / want < 1e-6


def test_canned_collective_parse():
    text = """
HloModule test

ENTRY %main (p0: f32[16,8]) -> f32[16,8] {
  %p0 = f32[16,8]{1,0} parameter(0)
  %ar = f32[16,8]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %out = f32[16,8]{1,0} copy(%ar)
}
"""
    s = analyze_hlo(text)
    assert s.collective_count.get("all-reduce") == 1
    assert s.collective_raw_bytes == 16 * 8 * 4
    assert s.collective_bytes == pytest.approx(16 * 8 * 4 * 1.5)
    # f32 wire-correction halves it
    s2 = analyze_hlo(text, f32_collective_scale=0.5)
    assert s2.collective_bytes == pytest.approx(16 * 8 * 4 * 0.75)


def test_dot_bearing_events_on_canned_scheduled_module():
    """The shared scheduling API: collective/loop positions and the
    first-vs-last comparison both tests and the contract checker use."""
    text = """
HloModule test, is_scheduled=true

%body (c: f32[8,8]) -> f32[8,8] {
  %c = f32[8,8]{1,0} parameter(0)
  ROOT %d = f32[8,8]{1,0} dot(%c, %c), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%cond (c: f32[8,8]) -> pred[] {
  %c = f32[8,8]{1,0} parameter(0)
  ROOT %p = pred[] constant(false)
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  %ar0 = bf16[8192]{0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  %w = f32[8,8]{1,0} while(%p0), condition=%cond, body=%body
  %ar1 = f32[5]{0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  ROOT %out = f32[8,8]{1,0} copy(%w)
}
"""
    sched = dot_bearing_events(text, min_bytes=1024)
    assert sched["scheduled"]
    assert len(sched["loops"]) == 1
    assert len(sched["collectives"]) == 1      # the scalar psum is filtered
    assert sched["first_collective"] < sched["last_loop"]
    ev = [e for e in sched["events"] if e["collective"]]
    assert [e["elems"] for e in ev] == [8192, 5]
    assert [e["dtype"] for e in ev] == ["bf16", "f32"]
    # no byte filter: both collectives appear
    assert len(dot_bearing_events(text)["collectives"]) == 2
    # empty sides stay None instead of raising
    empty = dot_bearing_events(text, collective="all-gather")
    assert empty["first_collective"] is None
