"""Lint fixture: a raw lax.psum outside the manual-region machinery —
gradient traffic the planner cannot account for. Must produce exactly
ONE raw-collective finding."""
import jax


def aggregate(grad, axes):
    return jax.lax.psum(grad, axes)  # the violation
