"""Lint fixture: a custom_vjp identity tap whose fwd casts its residuals
— the tapped step is no longer bit-identical to the untapped one. Must
produce exactly ONE tap-fwd-not-identity finding."""
import jax
import jax.numpy as jnp


@jax.custom_vjp
def tap(leaves, token):
    return leaves


def fwd(leaves, token):
    return tuple(x.astype(jnp.float32) for x in leaves), None  # violation


def bwd(_, cts):
    return cts, None


tap.defvjp(fwd, bwd)
