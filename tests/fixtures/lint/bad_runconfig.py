"""Lint fixture: a RunConfig with a list-typed field — unhashable, so it
breaks plan/compile cache keys. Must produce exactly ONE
unhashable-config-field finding."""
from dataclasses import dataclass, field


@dataclass(frozen=True)
class RunConfig:
    comm_mode: str = "hybrid"
    table_alpha: tuple = ()
    bucket_order: list = field(default_factory=list)  # the violation
