"""Lint fixture: a direct jax.sharding import outside compat — the
version-dependent API the compat layer exists to wrap. Must produce
exactly ONE jax-mesh-api finding."""
from jax.sharding import Mesh  # noqa: F401


def make(devices):
    return Mesh(devices, ("data",))
