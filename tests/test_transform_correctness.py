"""The paper's correctness property (§3.1/§7.2), asserted exactly:

    synchronous data-parallel training ≡ single-device training
    at equal global batch,

for every communication mode (hybrid / ps / mpi) and for every optimization
flag (LA / OPAU / OPSW) — the optimizations must change bytes-on-wire, never
math. Runs on 8 fake devices in a subprocess (main session keeps 1 device).
"""
import pytest

from conftest import distributed_run

_CODE = """
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import get_runner
from repro.data import SyntheticLM

import dataclasses
cfg = reduced(get_config("{arch}"))
if cfg.n_experts:
    # ample capacity: token dropping is partition-dependent (as in every
    # capacity-bounded MoE system) and would break exact equality
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32")
if cfg.n_experts:
    # adam's sign(g)-like update amplifies f32 reduction-order noise on
    # near-zero grads; sgd keeps the comparison a direct gradient check
    kw["optimizer"] = "sgd"; kw["learning_rate"] = 0.3
ds = SyntheticLM(cfg.vocab_size, 32, 4, is_encdec=cfg.is_encdec,
                 frames_dim=cfg.d_model if cfg.family == "audio" else 0,
                 frames_len=8)

ref = get_runner(cfg, shape, RunConfig(**kw))
ref_losses = [float(ref.run(ds.batch(i))["loss"]) for i in range(3)]

mesh = make_mesh((2, 4), ("data", "model"))
out = {{"ref": ref_losses}}
for name, flags in {flag_sets}.items():
    with use_mesh(mesh):
        run = get_runner(cfg, shape, RunConfig(**kw, **flags), mesh=mesh)
        out[name] = [float(run.run(ds.batch(i))["loss"]) for i in range(3)]
print("RESULT:" + json.dumps(out))
"""

FLAG_SETS = {
    "hybrid": {"comm_mode": "hybrid"},
    "ps": {"comm_mode": "ps"},
    "mpi": {"comm_mode": "mpi"},
    "no_la": {"comm_mode": "hybrid", "local_agg": False},
    "no_opau": {"comm_mode": "hybrid", "opau": False},
    "no_opsw": {"comm_mode": "hybrid", "opsw": False},
}


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "command-r-35b",
                                  "rwkv6-7b", "grok-1-314b"])
@pytest.mark.distributed
def test_distributed_equals_single_device(arch):
    sets = FLAG_SETS if arch == "phi3-medium-14b" else \
        {k: FLAG_SETS[k] for k in ("hybrid", "mpi")}
    res = distributed_run(_CODE.format(arch=arch, flag_sets=repr(sets)),
                          devices=8, timeout=600)
    ref = res.pop("ref")
    for name, losses in res.items():
        for i, (a, b) in enumerate(zip(ref, losses)):
            # f32 end-to-end: only reduction-order drift is allowed
            assert abs(a - b) < 5e-4 + 1e-4 * i, \
                (arch, name, i, ref, losses)


@pytest.mark.distributed
def test_clip_after_aggregation_semantics():
    """Gradient clipping must act on the *aggregated* gradient (paper §3.1):
    per-replica clipping gives a mathematically different (wrong) update.
    We assert our transform matches the aggregate-then-clip oracle even when
    per-replica norms would exceed the bound."""
    code = """
import jax.numpy as jnp
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("phi3-medium-14b"), layers=1)
shape = ShapeConfig("tiny", seq_len=16, global_batch=4, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32", clip_norm=0.05,
          learning_rate=0.05)
ds = SyntheticLM(cfg.vocab_size, 16, 4)
ref = get_runner(cfg, shape, RunConfig(**kw))
ref_out = [float(ref.run(ds.batch(i))["grad_norm"]) for i in range(2)]
mesh = make_mesh((4, 2), ("data", "model"))
with use_mesh(mesh):
    run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
    dist_out = [float(run.run(ds.batch(i))["grad_norm"]) for i in range(2)]
print("RESULT:" + json.dumps({"ref": ref_out, "dist": dist_out}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    for a, b in zip(res["ref"], res["dist"]):
        assert abs(a - b) / max(abs(a), 1e-9) < 1e-3, res
