"""Property-test shim: real hypothesis when installed, otherwise a tiny
deterministic fallback so the suite collects and runs offline.

Usage (replaces ``from hypothesis import given, settings, strategies as st``):

    from _prop import HAVE_HYPOTHESIS, given, settings, st

The fallback implements only what this repo's tests use — ``integers``,
``floats``, ``lists``, ``sampled_from`` — and draws a fixed number of
pseudo-random examples from a seed derived from the test name, so failures
reproduce across runs. It does NOT shrink; with hypothesis installed you get
the real engine.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import inspect
    import random
    import zlib

    _FALLBACK_EXAMPLES = 25   # per test, unless @settings caps lower

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            # log-uniform when the range spans decades (matches how the
            # cost-model tests use wide float ranges), else uniform
            import math
            if min_value > 0 and max_value / min_value > 1e3:
                lo, hi = math.log(min_value), math.log(max_value)
                return _Strategy(lambda rng: math.exp(rng.uniform(lo, hi)))
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

        @staticmethod
        def lists(elements: _Strategy, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            def runner(*args, **kwargs):
                n = min(getattr(runner, "_max_examples", _FALLBACK_EXAMPLES),
                        _FALLBACK_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = random.Random(seed * 1000003 + i)
                    drawn = [s.example(rng) for s in strategies]
                    try:
                        fn(*args, *drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (fallback prop engine, "
                            f"example {i}): {fn.__name__}{tuple(drawn)}"
                        ) from e
            # hand-copied metadata, NOT functools.wraps: wraps would expose
            # the original signature via __wrapped__ and pytest would demand
            # fixtures for the drawn parameters
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner.__signature__ = inspect.Signature()
            runner._max_examples = _FALLBACK_EXAMPLES
            return runner
        return deco

    def settings(max_examples=None, deadline=None, **_ignored):
        def deco(fn):
            if max_examples is not None and hasattr(fn, "_max_examples"):
                fn._max_examples = max_examples
            return fn
        return deco
