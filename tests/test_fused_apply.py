"""Fused bucket-apply integration: the bucket-native optimizer update is
bit-identical to the per-param path at f32 end to end, fused optimizer
memory migrates through a bucket-regrouping replan, and checkpoints hold
the canonical per-param layout (save/restore round-trips through a fused
trainer exactly). Unit-level layout/bitwise tests live in test_optim.py;
the sparse-push overlap HLO regression lives in test_perf_paths.py."""
import pytest

from conftest import distributed_run

REGROUP_CODE = """
import dataclasses
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import estimate_census, get_runner
from repro.data import SyntheticLM
from repro.optim.optimizer import is_fused

cfg = reduced(get_config("seamless-m4t-medium"))
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
# mpi: the decoder vocab table keeps its gatherv row-buffer exchange, so
# the fused apply coexists with an unbucketed sparse leaf in the same step
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32", comm_mode="mpi",
          bucket_bytes=256 * 1024)
ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True,
                 frames_dim=cfg.d_model, frames_len=8)

def sig(plan):
    return [[list(b.idx), b.key[1]] for b in plan.bucket_plan.buckets]

mesh = make_mesh((8, 1), ("data", "model"))
with use_mesh(mesh):
    out = {}
    for fused in (True, False):
        run = get_runner(cfg, shape, RunConfig(**kw, fused_apply=fused),
                         mesh=mesh)
        losses = [float(run.run(ds.batch(i))["loss"]) for i in range(2)]
        rec = {"pre_sig": sig(run.plan),
               "pre_fused": bool(is_fused(run.state)),
               "pre_flag": bool(run.plan.fused_apply)}
        # regroup the buckets: a quarter of the budget makes more, smaller
        # buckets; force the hot-swap so the optimizer memory must migrate
        run.rt.run_cfg = dataclasses.replace(run.rt.run_cfg,
                                             bucket_bytes=64 * 1024)
        diff = run.replan(estimate_census(run.model, run.rt), force=True)
        losses += [float(run.run(ds.batch(i))["loss"]) for i in range(2, 4)]
        rec.update(losses=losses, post_sig=sig(run.plan),
                   post_fused=bool(is_fused(run.state)),
                   post_flag=bool(run.plan.fused_apply),
                   rebuilt=bool(diff.get("rebuilt")))
        out[str(fused)] = rec
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.distributed
def test_fused_apply_bit_exact_across_regrouping_replan():
    """The fused-apply tentpole regression: fused vs per-param trajectories
    are bitwise equal at f32 — including across a forced replan that
    regroups the bucket layout, which must migrate the fused m/v/EMA
    buffers through the canonical per-param layout (old layout unfuses,
    new layout re-fuses)."""
    res = distributed_run(REGROUP_CODE, devices=8, timeout=900)
    f, p = res["True"], res["False"]
    assert f["pre_flag"] and f["pre_fused"], res
    assert not p["pre_flag"] and not p["pre_fused"], res
    # the replan genuinely regrouped the layout (same on both runners)
    assert f["pre_sig"] != f["post_sig"], res
    assert f["post_sig"] == p["post_sig"], res
    # ...and the fused state survived the migration
    assert f["rebuilt"] and f["post_fused"] and f["post_flag"], res
    # trajectory continuity: bitwise equal before AND after the regroup
    assert f["losses"] == p["losses"], res


CKPT_CODE = """
import tempfile
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.data import SyntheticLM
from repro.optim.optimizer import is_fused
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
rc = RunConfig(attention_impl="naive", remat="none", param_dtype="float32",
               compute_dtype="float32", wire_dtype="float32")
mesh = make_mesh((8, 1), ("data", "model"))

def drive(total, ckpt_dir, resume=False):
    ds = SyntheticLM(cfg.vocab_size, 32, 8)
    tcfg = TrainerConfig(total_steps=total, ckpt_dir=ckpt_dir, ckpt_every=4)
    with use_mesh(mesh):
        t = Trainer(cfg, shape, rc, tcfg, ds, mesh=mesh)
        if resume:
            t.maybe_restore()
        stats = []
        t.run(on_metrics=lambda s, m: stats.append((s, m)))
    return t, stats

t_ref, ref = drive(8, None)
d = tempfile.mkdtemp()
t_a, first = drive(4, d)
t_b, second = drive(8, d, resume=True)
res = {
    "fused": [bool(is_fused(t.state)) for t in (t_ref, t_a, t_b)],
    "resumed_from": second[0][0],
    "ref_losses": [float(m["loss"]) for _, m in ref],
    "split_losses": [float(m["loss"]) for _, m in first + second],
    "apply_seconds": float(ref[-1][1].get("apply_seconds", -1.0)),
    "exchange": "exchange" in ref[-1][1],
}
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.distributed
def test_fused_trainer_checkpoint_trajectory_continuity():
    """Checkpoints written by a fused trainer hold the canonical per-param
    layout: a run interrupted at step 4 and resumed by a fresh trainer
    reproduces the uninterrupted 8-step f32 trajectory exactly (restore
    lands in a canonical template, then re-fuses onto the live plan). The
    analytic apply cost is surfaced in the step stats."""
    res = distributed_run(CKPT_CODE, devices=8, timeout=900)
    assert all(res["fused"]), res                # fused layout was live
    assert res["resumed_from"] == 5, res         # restore picked up step 4
    assert res["split_losses"] == res["ref_losses"], res
    assert res["apply_seconds"] > 0, res
    assert res["exchange"], res
