"""§Perf optimizations must not change math: explicit-SP and the dp dense
strategy reproduce the single-device result exactly (f32)."""
import pytest

from conftest import distributed_run

CODE = """
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("__ARCH__"))
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32")
ds = SyntheticLM(cfg.vocab_size, 32, 8)
ref = get_runner(cfg, shape, RunConfig(**kw))
ref_losses = [float(ref.run(ds.batch(i))["loss"]) for i in range(3)]
mesh = make_mesh((2, 4), ("data", "model"))
with use_mesh(mesh):
    run = get_runner(cfg, shape, RunConfig(**kw, __FLAGS__), mesh=mesh)
    losses = [float(run.run(ds.batch(i))["loss"]) for i in range(3)]
print("RESULT:" + json.dumps({
    "diff": max(abs(a - b) for a, b in zip(ref_losses, losses)),
    "methods": run.plan.methods()}))
"""


@pytest.mark.parametrize("arch,flags", [
    ("phi3-medium-14b", "explicit_sp=True"),
    ("command-r-35b", "explicit_sp=True"),      # tied embeddings + SP
    ("phi3-medium-14b", 'dense_strategy="dp"'),
    ("hymba-1.5b", 'dense_strategy="dp"'),
    ("rwkv6-7b", 'dense_strategy="dp"'),
    ("phi3-medium-14b", 'explicit_sp=True, dense_strategy="auto"'),
])
@pytest.mark.distributed
def test_perf_paths_exact(arch, flags):
    res = distributed_run(
        CODE.replace("__ARCH__", arch).replace("__FLAGS__", flags),
        devices=8, timeout=600)
    assert res["diff"] < 2e-5, res


@pytest.mark.distributed
def test_auto_strategy_picks_sensibly():
    code = """
from repro.configs import get_config, SHAPES
from repro.core.cost_model import MeshDims, pick_dense_strategy
dims = MeshDims(model=16, data=16)
out = {a: pick_dense_strategy(get_config(a), SHAPES["train_4k"], dims)
       for a in ("hymba-1.5b", "phi3-medium-14b", "grok-1-314b",
                 "llama4-maverick-400b-a17b")}
out["decode"] = pick_dense_strategy(get_config("hymba-1.5b"),
                                    SHAPES["decode_32k"], dims)
print("RESULT:" + json.dumps(out))
"""
    res = distributed_run(code, devices=8)
    assert res["hymba-1.5b"] == "dp"
    assert res["grok-1-314b"] == "tp"            # MoE needs the model axis
    assert res["llama4-maverick-400b-a17b"] == "tp"
    assert res["decode"] == "tp"                 # decode keeps cache sharding
