"""§Perf optimizations must not change math: explicit-SP, the dp dense
strategy, and the bucketed gradient exchange reproduce the single-device /
per-tensor result exactly (f32) — and the bucketing win is HLO-verified
(collapsed all-reduce count)."""
import pytest

from conftest import distributed_run

CODE = """
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("__ARCH__"))
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32")
ds = SyntheticLM(cfg.vocab_size, 32, 8)
ref = get_runner(cfg, shape, RunConfig(**kw))
ref_losses = [float(ref.run(ds.batch(i))["loss"]) for i in range(3)]
mesh = make_mesh((2, 4), ("data", "model"))
with use_mesh(mesh):
    run = get_runner(cfg, shape, RunConfig(**kw, __FLAGS__), mesh=mesh)
    losses = [float(run.run(ds.batch(i))["loss"]) for i in range(3)]
print("RESULT:" + json.dumps({
    "diff": max(abs(a - b) for a, b in zip(ref_losses, losses)),
    "methods": run.plan.methods()}))
"""


@pytest.mark.parametrize("arch,flags", [
    ("phi3-medium-14b", "explicit_sp=True"),
    ("command-r-35b", "explicit_sp=True"),      # tied embeddings + SP
    ("phi3-medium-14b", 'dense_strategy="dp"'),
    ("hymba-1.5b", 'dense_strategy="dp"'),
    ("rwkv6-7b", 'dense_strategy="dp"'),
    ("phi3-medium-14b", 'explicit_sp=True, dense_strategy="auto"'),
])
@pytest.mark.distributed
def test_perf_paths_exact(arch, flags):
    res = distributed_run(
        CODE.replace("__ARCH__", arch).replace("__FLAGS__", flags),
        devices=8, timeout=600)
    assert res["diff"] < 2e-5, res


BUCKET_CODE = """
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.plan import ParamPlan
from repro.core.transform import get_runner
from repro.data import SyntheticLM
from repro.utils.hlo import analyze_hlo

cfg = reduced(get_config("seamless-m4t-medium"))   # 26 dense param tensors
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32")
ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True,
                 frames_dim=cfg.d_model, frames_len=8)

def ar_count(run):
    txt = run.train_step.lower(run.state, ds.batch(0)).compile().as_text()
    return analyze_hlo(txt).collective_count.get("all-reduce", 0)

mesh = make_mesh((8, 1), ("data", "model"))
with use_mesh(mesh):
    flat = get_runner(cfg, shape, RunConfig(**kw, bucket_bytes=0), mesh=mesh)
    fused = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
    n_dense = sum(1 for p in jax.tree.leaves(
        fused.plan.params, is_leaf=lambda x: isinstance(x, ParamPlan))
        if p.method == "allreduce")
    res = {
        "n_dense": n_dense,
        "ar_flat": ar_count(flat),
        "ar_fused": ar_count(fused),
        "stats": fused.plan.bucket_plan.stats(),
        "flat_losses": [float(flat.run(ds.batch(i))["loss"]) for i in range(3)],
        "fused_losses": [float(fused.run(ds.batch(i))["loss"]) for i in range(3)],
    }
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.distributed
def test_bucketed_exchange_collapses_all_reduces():
    """The tentpole regression: with bucketing the distributed train step's
    dense exchange rides O(buckets) all-reduces (bucket + fused scalar psum)
    instead of one per dense parameter — at identical math."""
    res = distributed_run(BUCKET_CODE, devices=8, timeout=900)
    assert res["n_dense"] >= 20
    assert res["ar_flat"] >= res["n_dense"], res        # one per tensor (min)
    assert res["ar_fused"] <= 4, res                    # collapsed
    assert res["stats"]["n_collectives_dense"] < res["stats"][
        "n_collectives_unbucketed"]
    diff = max(abs(a - b) for a, b in
               zip(res["flat_losses"], res["fused_losses"]))
    assert diff < 2e-5, res


OVERLAP_CODE = """
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import get_runner
from repro.data import SyntheticLM
from repro.utils.hlo import dot_bearing_events

cfg = reduced(get_config("seamless-m4t-medium"))
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          bucket_bytes=256 * 1024)               # ~4 buckets on this model
ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True,
                 frames_dim=cfg.d_model, frames_len=8)

def probe(run):
    txt = run.train_step.lower(run.state, ds.batch(0)).compile().as_text()
    # bucket all-reduces are >= tens of KB; the fused scalar psum is ~100 B.
    # the model scans over layers, so its matmul work (forward AND
    # backward) runs inside dot-bearing while loops; top-level dots are
    # the grad-norm clip, which legitimately follows the exchange
    sched = dot_bearing_events(txt, min_bytes=16384)
    return {"scheduled": sched["scheduled"],
            "first_ar": sched["first_collective"],
            "n_ars": len(sched["collectives"]),
            "last_loop": sched["last_loop"],
            "n_loops": len(sched["loops"])}

mesh = make_mesh((8, 1), ("data", "model"))
with use_mesh(mesh):
    ov = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
    base = get_runner(cfg, shape, RunConfig(**kw, overlap=False), mesh=mesh)
    res = {
        "overlap": probe(ov), "baseline": probe(base),
        "n_buckets": len(ov.plan.bucket_plan.buckets),
        "ov_losses": [float(ov.run(ds.batch(i))["loss"]) for i in range(3)],
        "base_losses": [float(base.run(ds.batch(i))["loss"])
                        for i in range(3)],
    }
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.distributed
def test_overlap_schedules_first_bucket_before_backward_ends():
    """The overlap tentpole, HLO-verified on the scheduled module: with
    overlap on, the first bucket's all-reduce is scheduled BEFORE the last
    backward matmul loop (the exchange runs concurrently with the rest of
    the backward); with overlap off the data-dependence pin holds every
    bucket collective until all gradient math has drained. Same buckets,
    same math: the two 3-step f32 loss trajectories must be
    bit-identical."""
    res = distributed_run(OVERLAP_CODE, devices=8, timeout=900)
    assert res["n_buckets"] >= 2, res
    ov, base = res["overlap"], res["baseline"]
    assert ov["scheduled"] and base["scheduled"], res
    assert ov["n_ars"] >= res["n_buckets"], res
    assert ov["n_loops"] > 0 and base["n_loops"] > 0, res
    # ready-order: overlap issues its first fused psum mid-backward ...
    assert ov["first_ar"] < ov["last_loop"], res
    # ... while the pinned baseline cannot start exchanging until the
    # backward has fully drained
    assert base["first_ar"] > base["last_loop"], res
    # bit-identical math: issue order never changes the values
    diff = max(abs(a - b) for a, b in
               zip(res["ov_losses"], res["base_losses"]))
    assert diff == 0.0, res


SPARSE_OVERLAP_CODE = """
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import get_runner
from repro.data import SyntheticLM
from repro.utils.hlo import dot_bearing_events

cfg = reduced(get_config("seamless-m4t-medium"))
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
# mpi pins the decoder vocab table to the gatherv row-buffer exchange; the
# audio encoder consumes dense frames, so the table's grad becomes ready
# when the *decoder* backward finishes — before the encoder backward loops
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32", comm_mode="mpi",
          bucket_bytes=256 * 1024)
ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True,
                 frames_dim=cfg.d_model, frames_len=8)

def probe(run):
    txt = run.train_step.lower(run.state, ds.batch(0)).compile().as_text()
    # row buffers are (capacity, d_model) f32 all-gathers — tens of KB; the
    # uid gathers are (capacity,) int32 and fall under the byte filter
    sched = dot_bearing_events(txt, collective="all-gather",
                               min_bytes=16384)
    ags, last = sched["collectives"], sched["last_loop"]
    return {"scheduled": sched["scheduled"], "n_ags": len(ags),
            "ags_before": sum(1 for p in ags if p < last),
            "ags_after": sum(1 for p in ags if p > last),
            "n_loops": len(sched["loops"])}

mesh = make_mesh((8, 1), ("data", "model"))
with use_mesh(mesh):
    ov = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
    base = get_runner(cfg, shape, RunConfig(**kw, overlap=False), mesh=mesh)
    res = {
        "method": ov.plan.table_methods["embed"],
        "stats_ov": ov.plan.bucket_plan.stats(),
        "stats_base": base.plan.bucket_plan.stats(),
        "overlap": probe(ov), "baseline": probe(base),
        "ov_losses": [float(ov.run(ds.batch(i))["loss"]) for i in range(3)],
        "base_losses": [float(base.run(ds.batch(i))["loss"])
                        for i in range(3)],
    }
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.distributed
def test_sparse_push_overlaps_with_backward():
    """The sparse leg of the overlap tentpole, HLO-verified: with overlap on
    the gatherv table's row-buffer all-gather is issued at that table's
    gradient readiness inside the backward — scheduled BEFORE the last
    dot-bearing backward loop; with overlap off the deferred push drains
    post-backward, so every push collective lands after it. The forward
    row pulls appear identically in both modules, so the before/after
    deltas are attributable to the push alone — and issue order never
    changes the values (bit-identical f32 trajectories)."""
    res = distributed_run(SPARSE_OVERLAP_CODE, devices=8, timeout=900)
    assert res["method"] == "mpi_gatherv", res
    ov, base = res["overlap"], res["baseline"]
    assert ov["scheduled"] and base["scheduled"], res
    assert ov["n_loops"] > 0 and base["n_loops"] > 0, res
    # the exchange accounting sees the in-backward push (and the monitor
    # surfaces it as n_overlapped_sparse)
    assert res["stats_ov"]["n_overlapped_sparse"] >= 1, res
    assert res["stats_base"]["n_overlapped_sparse"] == 0, res
    # overlap: at least one row-buffer collective rides inside the backward
    assert ov["ags_before"] > base["ags_before"], res
    # baseline: the deferred push pins every row-buffer push post-backward
    assert base["ags_after"] > ov["ags_after"], res
    assert base["ags_after"] >= 1, res
    # bit-identical math across the schedule flip
    diff = max(abs(a - b) for a, b in
               zip(res["ov_losses"], res["base_losses"]))
    assert diff == 0.0, res


PALLAS_PS_CODE = """
from repro.configs import get_config, reduced, RunConfig, ShapeConfig
from repro.core.transform import get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("phi3-medium-14b"))
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32")
ds = SyntheticLM(cfg.vocab_size, 32, 8)
mesh = make_mesh((2, 4), ("data", "model"))
with use_mesh(mesh):
    runs = {}
    for impl in ("jnp", "pallas"):
        # comm_mode="ps" pins the row-sharded PS exchange: the hybrid argmin
        # is free to prefer gatherv for a table this small
        r = get_runner(cfg, shape, RunConfig(**kw, comm_mode="ps",
                                             embed_impl=impl), mesh=mesh)
        runs[impl] = [float(r.run(ds.batch(i))["loss"]) for i in range(3)]
    method = r.plan.embed_method
print("RESULT:" + json.dumps({
    "diff": max(abs(a - b) for a, b in zip(runs["jnp"], runs["pallas"])),
    "method": method}))
"""


@pytest.mark.distributed
def test_pallas_embed_impl_exact_on_ps_path():
    """Kernelized pull/push under the real row-sharded PS exchange (model
    axis > 1) is a drop-in for the jnp path."""
    res = distributed_run(PALLAS_PS_CODE, devices=8, timeout=900)
    assert res["method"] in ("ps", "ps_gather"), res
    assert res["diff"] == 0.0, res


@pytest.mark.distributed
def test_auto_strategy_picks_sensibly():
    code = """
from repro.configs import get_config, SHAPES
from repro.core.cost_model import MeshDims, pick_dense_strategy
dims = MeshDims(model=16, data=16)
out = {a: pick_dense_strategy(get_config(a), SHAPES["train_4k"], dims)
       for a in ("hymba-1.5b", "phi3-medium-14b", "grok-1-314b",
                 "llama4-maverick-400b-a17b")}
out["decode"] = pick_dense_strategy(get_config("hymba-1.5b"),
                                    SHAPES["decode_32k"], dims)
print("RESULT:" + json.dumps(out))
"""
    res = distributed_run(code, devices=8)
    assert res["hymba-1.5b"] == "dp"
    assert res["grok-1-314b"] == "tp"            # MoE needs the model axis
    assert res["llama4-maverick-400b-a17b"] == "tp"
    assert res["decode"] == "tp"                 # decode keeps cache sharding
