"""The profile -> replan -> hot-swap loop (paper §5's runtime profiling).

Covers: the Zipf-aware census estimator pinned against the empirical data
pipeline; planning as pure stages (a plan recomputed from a census equals a
from-scratch plan given the same census); state round-trips across no-op and
method-flipping replans; the trainer's replan hook; and the abstract-init
remesh path.
"""
import dataclasses

import numpy as np
import pytest

from conftest import distributed_run
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core import sparsity
from repro.core.plan import plan_diff
from repro.core.sparsity import (SparsityProfile, expected_unique,
                                 expected_unique_zipf, observed_census)
from repro.core.transform import (analyze, choose_methods, estimate_census,
                                  get_runner)
from repro.data import SyntheticLM


# ---------------------------------------------------------------------------
# census estimators vs the actual data pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("vocab,tokens", [(256, 64), (1024, 128),
                                          (8192, 2048)])
def test_zipf_estimator_matches_pipeline_uniform_does_not(vocab, tokens):
    """expected_unique_zipf must track the empirical unique counts of the
    Zipf(1.3) pipeline; the uniform bound must systematically over-estimate
    (the planned-α error that motivates runtime replanning)."""
    seq = 16
    batch = max(tokens // seq, 1)
    ds = SyntheticLM(vocab, seq, batch, seed=0)
    emp = float(np.mean(ds.unique_counts(steps=16)))
    zipf_est = expected_unique_zipf(tokens, vocab, ds.zipf_a)
    uniform_est = expected_unique(tokens, vocab)
    assert abs(zipf_est - emp) / emp < 0.15, (emp, zipf_est)
    assert uniform_est > 1.5 * emp, (emp, uniform_est)
    assert uniform_est > zipf_est


def test_expected_unique_zipf_edges():
    assert expected_unique_zipf(0, 100) == 0.0
    assert expected_unique_zipf(100, 0) == 0.0
    # more tokens never reduce expected unique; bounded by vocab
    prev = 0.0
    for t in (1, 10, 100, 1000):
        cur = expected_unique_zipf(t, 64)
        assert prev <= cur <= 64.0
        prev = cur
    with pytest.raises(ValueError):
        sparsity.zipf_row_probs(16, 1.0)


def test_declared_zipf_skew_informs_the_planner(tiny_shape):
    """RunConfig.zipf_a switches the census to the skew-aware estimate."""
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc_uniform = RunConfig(capacity_mode="capped")
    rc_zipf = dataclasses.replace(rc_uniform, zipf_a=1.3)
    runner = get_runner(cfg, tiny_shape, rc_uniform)
    plan_u = runner.plan
    plan_z = get_runner(cfg, tiny_shape, rc_zipf).plan
    local_tokens = tiny_shape.tokens
    assert plan_u.alpha == pytest.approx(
        expected_unique(local_tokens, 256) / 256)
    assert plan_z.alpha == pytest.approx(
        expected_unique_zipf(local_tokens, 256, 1.3) / 256)
    assert plan_z.alpha < plan_u.alpha
    assert plan_z.capacity < plan_u.capacity


def test_zipf_row_probs_is_a_distribution():
    p = sparsity.zipf_row_probs(512, 1.3)
    assert p.shape == (512,)
    assert np.all(p > 0)
    assert abs(p.sum() - 1.0) < 1e-6
    assert p[0] > p[-1]          # skewed toward low ids


# ---------------------------------------------------------------------------
# the sparsity profile EMA
# ---------------------------------------------------------------------------

def test_profile_ema_and_observed_census():
    rc = RunConfig(capacity_mode="capped", capacity_factor=2.0)
    prof = SparsityProfile(decay=0.5)
    assert not prof.ready()
    prof.update({"loss": 3.0})                   # no census keys: ignored
    assert not prof.ready()
    prof.update({"embed_unique": 40.0, "loss": 3.0})
    prof.update({"embed_unique": 20.0})
    assert prof.ready(2)
    assert prof.ema["embed_unique"] == pytest.approx(30.0)
    base = sparsity.Census(dense_params=10, sparse_params=100, alpha=0.5,
                           local_tokens=64, capacity=64)
    obs = observed_census(prof, base, vocab=200, run_cfg=rc)
    assert obs.alpha == pytest.approx(30.0 / 200)
    assert obs.capacity == 60                    # ceil(30 * 2.0)
    assert obs.local_tokens == base.local_tokens
    # exact capacity mode never resizes buffers from the profile
    obs_exact = observed_census(prof, base, vocab=200, run_cfg=RunConfig())
    assert obs_exact.capacity == base.capacity
    # empty profile is a no-op
    assert observed_census(SparsityProfile(), base, 200, rc) is base


def test_step_metrics_carry_observed_unique(tiny_shape):
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    runner = get_runner(cfg, tiny_shape,
                        RunConfig(attention_impl="naive", remat="none"))
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    m = runner.run(ds.batch(0))
    got = float(m["embed_unique"])
    want = float(np.unique(ds.batch(0)["tokens"]).size)
    assert got == pytest.approx(want)


# ---------------------------------------------------------------------------
# staged planning purity + replan round-trips
# ---------------------------------------------------------------------------

def _methods(plan):
    import jax
    from repro.core.plan import ParamPlan
    return {p.name: p.method for p in jax.tree.leaves(
        plan.params, is_leaf=lambda x: isinstance(x, ParamPlan))}


def test_plan_from_census_equals_from_scratch(tiny_shape):
    """analyze(census=c) must equal a from-scratch analyze whose estimate
    is c — planning is a pure function of (model, rt, census)."""
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(capacity_mode="capped")
    runner = get_runner(cfg, tiny_shape, rc)
    census = estimate_census(runner.model, runner.rt)
    replanned = analyze(runner.model, runner.rt, census=census)
    staged = choose_methods(runner.model, runner.rt, census)
    for other in (replanned, staged):
        assert _methods(other) == _methods(runner.plan)
        assert other.capacity == runner.plan.capacity
        assert other.alpha == runner.plan.alpha
        d = plan_diff(runner.plan, other)
        assert not d["changed"] and not d["flips"]


def test_noop_replan_keeps_params_bit_identical(tiny_shape):
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    runner = get_runner(cfg, tiny_shape,
                        RunConfig(attention_impl="naive", remat="none"))
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    runner.run(ds.batch(0))
    before = {"embed": np.asarray(runner.state.params["embed"]).copy(),
              "m": np.asarray(runner.state.m["embed"]).copy()}
    census = estimate_census(runner.model, runner.rt)
    d = runner.replan(census)
    assert not d["changed"] and not d["rebuilt"]     # same census: no-op
    d = runner.replan(census, force=True)            # force the rebuild path
    assert d["rebuilt"]
    np.testing.assert_array_equal(before["embed"],
                                  np.asarray(runner.state.params["embed"]))
    np.testing.assert_array_equal(before["m"],
                                  np.asarray(runner.state.m["embed"]))
    # the swapped-in step still trains
    m = runner.run(ds.batch(1))
    assert np.isfinite(float(m["loss"]))


def test_capacity_drift_triggers_replan(tiny_shape):
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none",
                   capacity_mode="capped", capacity_factor=1.0)
    runner = get_runner(cfg, tiny_shape, rc)
    cap0 = runner.plan.capacity
    prof = SparsityProfile()
    prof.update({"embed_unique": cap0 / 4})
    census = observed_census(prof, estimate_census(runner.model, runner.rt),
                             cfg.vocab_size, rc)
    d = runner.replan(census)
    assert d["capacity_drifted"] and d["rebuilt"]
    assert runner.plan.capacity < cap0


def test_trainer_replan_hook_and_monitor(tiny_shape, tmp_path):
    from repro.runtime.trainer import Trainer, TrainerConfig
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none",
                   capacity_mode="capped", capacity_factor=1.5)
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    tcfg = TrainerConfig(total_steps=8, replan_every=4, replan_warmup=2,
                         replan_drift=1.3)
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    cap0 = t.plan.capacity
    stats = []
    t.run(on_metrics=lambda s, m: stats.append(m))
    # Zipf data vs uniform estimate: the capacity must have shrunk
    assert t.monitor.replans >= 1
    assert t.plan.capacity < cap0
    assert t.plan.alpha < cap0 / cfg.vocab_size
    assert "observed_alpha" in stats[-1]
    assert stats[-1]["replans"] == t.monitor.replans
    assert all(np.isfinite(m["loss"]) for m in stats)


def test_remesh_uses_existing_state_without_init(tiny_shape, monkeypatch):
    """The elastic rebuild must not materialize a throwaway model.init."""
    from repro.runtime.trainer import Trainer, TrainerConfig
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none")
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    t = Trainer(cfg, tiny_shape, rc, TrainerConfig(total_steps=2), ds)
    t.run()
    before = np.asarray(t.state.params["embed"]).copy()
    step_before = int(t.state.step)

    def boom(*a, **k):
        raise AssertionError("remesh materialized a fresh model.init")

    monkeypatch.setattr(type(t.model), "init", boom)
    t.remesh(None)
    np.testing.assert_array_equal(before,
                                  np.asarray(t.state.params["embed"]))
    assert int(t.state.step) == step_before
    # and the rebuilt step still runs on the restored state
    t.tcfg = dataclasses.replace(t.tcfg, total_steps=3)
    t.run()
    assert t.step == 3


# ---------------------------------------------------------------------------
# distributed: method-flipping replan preserves the loss trajectory
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_method_flipping_replan_preserves_trajectory():
    """On a (4 data x 2 model) mesh with Zipf ids, the uniform estimate
    plans `ps` but the observed α is below the ps/ps_gather crossover: the
    replan must flip the embedding method, keep pspecs (no host round-trip),
    and reproduce the static run's losses exactly (correctness contract
    across a hot-swap)."""
    code = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.sparsity import SparsityProfile, observed_census
from repro.core.transform import estimate_census, get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
# this scenario tunes the ps/ps_gather *byte* crossover on a toy 64KB
# table; at that size the per-message latency term swamps bytes and
# legitimately argmins to dense allreduce — link_latency=0 pins the paper's
# pure Table-3 byte model (latency behavior is covered by
# test_cost_model.py and test_buckets.py)
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=2.0, link_latency=0.0)
ds = SyntheticLM(cfg.vocab_size, 32, 8)
mesh = make_mesh((4, 2), ("data", "model"))

def drive(adaptive):
    with use_mesh(mesh):
        run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
        first = run.plan.embed_method
        prof = SparsityProfile()
        losses, flips, pspecs_changed = [], [], False
        for i in range(8):
            m = run.run(ds.batch(i))
            losses.append(float(m["loss"]))
            prof.update({k: float(v) for k, v in m.items()
                         if getattr(v, "ndim", 0) == 0})
            if adaptive and i == 3:
                census = observed_census(
                    prof, estimate_census(run.model, run.rt),
                    cfg.vocab_size, run.rt.run_cfg)
                d = run.replan(census)
                flips = d["flips"]
                pspecs_changed = d["pspecs_changed"]
        return dict(first=first, last=run.plan.embed_method, losses=losses,
                    flips=flips, pspecs_changed=pspecs_changed,
                    alpha=run.plan.alpha)

static = drive(False)
adaptive = drive(True)
print("RESULT:" + json.dumps({"static": static, "adaptive": adaptive}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    st, ad = res["static"], res["adaptive"]
    assert st["first"] == st["last"] == "ps"
    assert ad["first"] == "ps" and ad["last"] == "ps_gather", ad
    assert ad["flips"], "replan did not flip any method"
    assert not ad["pspecs_changed"]      # row-sharded either way: state stays
    assert ad["alpha"] < st["alpha"]     # observed < uniform estimate
    for i, (a, b) in enumerate(zip(st["losses"], ad["losses"])):
        assert abs(a - b) < 5e-4 + 1e-4 * i, (i, st["losses"], ad["losses"])
