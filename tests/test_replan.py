"""The profile -> replan -> hot-swap loop (paper §5's runtime profiling).

Covers: the Zipf-aware census estimator pinned against the empirical data
pipeline; planning as pure stages (a plan recomputed from a census equals a
from-scratch plan given the same census); state round-trips across no-op and
method-flipping replans; the trainer's replan hook; and the abstract-init
remesh path.
"""
import dataclasses

import numpy as np
import pytest

from conftest import distributed_run
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core import sparsity
from repro.core.plan import plan_diff
from repro.core.sparsity import (SparsityProfile, expected_unique,
                                 expected_unique_zipf, observed_census)
from repro.core.transform import (analyze, choose_methods, estimate_census,
                                  get_runner)
from repro.data import SyntheticLM


# ---------------------------------------------------------------------------
# census estimators vs the actual data pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("vocab,tokens", [(256, 64), (1024, 128),
                                          (8192, 2048)])
def test_zipf_estimator_matches_pipeline_uniform_does_not(vocab, tokens):
    """expected_unique_zipf must track the empirical unique counts of the
    Zipf(1.3) pipeline; the uniform bound must systematically over-estimate
    (the planned-α error that motivates runtime replanning)."""
    seq = 16
    batch = max(tokens // seq, 1)
    ds = SyntheticLM(vocab, seq, batch, seed=0)
    emp = float(np.mean(ds.unique_counts(steps=16)))
    zipf_est = expected_unique_zipf(tokens, vocab, ds.zipf_a)
    uniform_est = expected_unique(tokens, vocab)
    assert abs(zipf_est - emp) / emp < 0.15, (emp, zipf_est)
    assert uniform_est > 1.5 * emp, (emp, uniform_est)
    assert uniform_est > zipf_est


def test_expected_unique_zipf_edges():
    assert expected_unique_zipf(0, 100) == 0.0
    assert expected_unique_zipf(100, 0) == 0.0
    # more tokens never reduce expected unique; bounded by vocab
    prev = 0.0
    for t in (1, 10, 100, 1000):
        cur = expected_unique_zipf(t, 64)
        assert prev <= cur <= 64.0
        prev = cur
    with pytest.raises(ValueError):
        sparsity.zipf_row_probs(16, 1.0)


def test_declared_zipf_skew_informs_the_planner(tiny_shape):
    """RunConfig.zipf_a switches the census to the skew-aware estimate."""
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc_uniform = RunConfig(capacity_mode="capped")
    rc_zipf = dataclasses.replace(rc_uniform, zipf_a=1.3)
    runner = get_runner(cfg, tiny_shape, rc_uniform)
    plan_u = runner.plan
    plan_z = get_runner(cfg, tiny_shape, rc_zipf).plan
    local_tokens = tiny_shape.tokens
    assert plan_u.alpha == pytest.approx(
        expected_unique(local_tokens, 256) / 256)
    assert plan_z.alpha == pytest.approx(
        expected_unique_zipf(local_tokens, 256, 1.3) / 256)
    assert plan_z.alpha < plan_u.alpha
    assert plan_z.capacity < plan_u.capacity


def test_zipf_row_probs_is_a_distribution():
    p = sparsity.zipf_row_probs(512, 1.3)
    assert p.shape == (512,)
    assert np.all(p > 0)
    assert abs(p.sum() - 1.0) < 1e-6
    assert p[0] > p[-1]          # skewed toward low ids


@pytest.mark.parametrize("a", [1.0, 0.5, 0.0, -2.0])
def test_zipf_exponent_at_or_below_one_raises(a):
    """a <= 1 has no proper Zipf normalization — both entry points raise."""
    with pytest.raises(ValueError):
        sparsity.zipf_row_probs(64, a)
    with pytest.raises(ValueError):
        expected_unique_zipf(32, 64, a)


@pytest.mark.parametrize("folds", [1, 2, 4, 8, 16])
@pytest.mark.parametrize("vocab,a", [(64, 1.3), (512, 1.1), (128, 2.5)])
def test_zipf_row_probs_sums_to_one_across_fold_counts(vocab, a, folds):
    p = sparsity.zipf_row_probs(vocab, a, folds=folds)
    assert p.shape == (vocab,)
    assert np.all(p > 0)
    assert abs(p.sum() - 1.0) < 1e-6, (folds, p.sum())


@pytest.mark.parametrize("vocab,a", [(64, 1.3), (256, 2.0), (1024, 1.05)])
def test_expected_unique_zipf_monotone_in_tokens(vocab, a):
    prev = 0.0
    for tokens in (1, 2, 5, 13, 50, 200, 1000, 10000):
        cur = expected_unique_zipf(tokens, vocab, a)
        assert prev <= cur + 1e-9 <= vocab + 1e-9, (tokens, prev, cur)
        prev = cur


# ---------------------------------------------------------------------------
# per-parameter planning: per-table census / profiles / capacities
# ---------------------------------------------------------------------------

def test_per_table_census_differs_by_declared_skew(tiny_shape):
    """One run_census call yields per-table records: a declared-Zipf vocab
    table and a declared-near-dense secondary table get different alphas
    and capacities."""
    from repro.core.runtime import Runtime
    from repro.models.model import build_model
    cfg = reduced(get_config("parallax-nmt"), vocab=256)
    rc = RunConfig(capacity_mode="capped", capacity_factor=1.5,
                   table_zipf=(("embed", 1.3),),
                   table_alpha=(("enc_embed", 0.99),))
    rt = Runtime(cfg, rc, tiny_shape)
    model = build_model(cfg, rt)
    census = estimate_census(model, rt)
    assert set(census.tables) == {"embed", "enc_embed"}
    emb, enc = census.tables["embed"], census.tables["enc_embed"]
    assert emb.alpha == pytest.approx(
        expected_unique_zipf(tiny_shape.tokens, 256, 1.3) / 256)
    assert enc.alpha == pytest.approx(0.99)
    assert emb.alpha < enc.alpha
    assert emb.capacity < enc.capacity
    assert census.alpha_for("embed") == emb.alpha
    assert census.capacity_for("enc_embed") == enc.capacity
    # unknown table name falls back to the binding aggregates
    assert census.alpha_for("nope") == census.alpha


def test_profile_folds_dropped_metrics_with_decay():
    """Satellite: *_dropped metrics get their own EMA (the overflow signal)
    with the same decay law as *_unique, and decay back toward zero once
    overflow stops."""
    prof = SparsityProfile(decay=0.5)
    prof.update({"embed_unique": 40.0, "embed_dropped": 16.0})
    assert prof.ema["embed_dropped"] == pytest.approx(16.0)
    prof.update({"embed_unique": 40.0, "embed_dropped": 0.0})
    assert prof.ema["embed_dropped"] == pytest.approx(8.0)
    prof.update({"embed_unique": 40.0, "embed_dropped": 0.0})
    assert prof.ema["embed_dropped"] == pytest.approx(4.0)
    assert prof.dropped() == {"embed": pytest.approx(4.0)}
    assert prof.dropped_for("embed") == pytest.approx(4.0)
    assert prof.dropped_for("enc_embed") == 0.0
    # dropped-only updates do not count as census steps (ready() gates on
    # the unique census, which every profiled step emits)
    steps = prof.steps
    prof.update({"embed_dropped": 2.0})
    assert prof.steps == steps
    # and the binding observed_unique ignores the dropped EMAs
    assert prof.observed_unique == pytest.approx(40.0)


def test_observed_census_grows_capacity_under_sustained_overflow():
    rc = RunConfig(capacity_mode="capped", capacity_factor=1.0,
                   capacity_growth=2.0, overflow_tolerance=0.5)
    base = sparsity.Census(
        dense_params=10, sparse_params=100, alpha=0.2, local_tokens=64,
        capacity=24, tables={
            "embed": sparsity.TableCensus(
                name="embed", rows=256, tokens=64, unique=24.0, alpha=24 / 256,
                capacity=24),
            "enc_embed": sparsity.TableCensus(
                name="enc_embed", rows=256, tokens=64, unique=20.0,
                alpha=20 / 256, capacity=20),
        })
    prof = SparsityProfile(decay=0.5)
    # embed overflows (uniq 40 against live capacity ~24); enc_embed is fine
    for _ in range(3):
        prof.update({"embed_unique": 40.0, "embed_dropped": 16.0,
                     "enc_embed_unique": 20.0, "enc_embed_dropped": 0.0})
    obs = observed_census(prof, base, vocab=256, run_cfg=rc)
    grown = obs.tables["embed"]
    assert grown.grown and grown.dropped > rc.overflow_tolerance
    assert grown.capacity == 80            # ceil(40 * 1.0 * 2.0)
    assert not obs.tables["enc_embed"].grown
    assert obs.tables["enc_embed"].capacity == 20
    assert obs.capacity >= 80              # binding aggregate tracks growth
    # below tolerance: no growth, plain re-fit only
    calm = SparsityProfile()
    calm.update({"embed_unique": 40.0, "embed_dropped": 0.0})
    obs2 = observed_census(calm, base, vocab=256, run_cfg=rc)
    assert not obs2.tables["embed"].grown
    assert obs2.tables["embed"].capacity == 40


def test_observed_census_growth_is_sticky_against_oscillation():
    """Once the overflow stops and the dropped EMA decays, a bare re-fit
    would shrink the buffer by exactly capacity_growth — tripping the drift
    rule and re-introducing the overflow. With the live plan passed in, a
    previously-grown table holds headroom sizing (and still tracks demand
    downward)."""
    rc = RunConfig(capacity_mode="capped", capacity_factor=1.0,
                   capacity_growth=2.0, overflow_tolerance=0.5)
    base = sparsity.Census(
        dense_params=1, sparse_params=1, alpha=0.2, local_tokens=64,
        capacity=40, tables={"embed": sparsity.TableCensus(
            name="embed", rows=256, tokens=64, unique=40.0, alpha=40 / 256,
            capacity=40)})
    calm = SparsityProfile()
    calm.update({"embed_unique": 40.0, "embed_dropped": 0.0})
    live = {"embed": (80, True)}     # the plan a growth replan installed
    obs = observed_census(calm, base, 256, rc, live=live)
    assert obs.tables["embed"].capacity == 80       # held, not re-fit to 40
    assert obs.tables["embed"].grown                # stickiness propagates
    # demand falls: capacity tracks the headroom of the *new* demand
    low = SparsityProfile()
    low.update({"embed_unique": 20.0, "embed_dropped": 0.0})
    obs2 = observed_census(low, base, 256, rc, live=live)
    assert obs2.tables["embed"].capacity == 40      # ceil(20 * 1.0 * 2.0)
    # without live info (manual loops), behavior is the plain re-fit
    obs3 = observed_census(calm, base, 256, rc)
    assert obs3.tables["embed"].capacity == 40
    assert not obs3.tables["embed"].grown


def test_profile_dropped_filters_non_table_metrics():
    """The MoE router's moe_dropped (token drops, not buffer overflow) must
    not surface as embedding overflow when the caller names its tables."""
    prof = SparsityProfile()
    prof.update({"embed_unique": 10.0, "embed_dropped": 1.0,
                 "moe_dropped": 123.0})
    assert prof.dropped() == {"embed": 1.0, "moe": 123.0}
    assert prof.dropped(tables={"embed": "ps"}) == {"embed": 1.0}


def test_plan_diff_flags_overflow_growth_and_wire_flips(tiny_shape):
    """A grown table marks the diff changed even inside the capacity-drift
    deadband, and a per-parameter wire-dtype move is a step-rebuild signal
    (wire_flips) without any pspec change."""
    import dataclasses as _dc
    from repro.core.plan import plan_leaves
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(capacity_mode="capped", capacity_factor=1.0)
    runner = get_runner(cfg, tiny_shape, rc)
    census = estimate_census(runner.model, runner.rt)
    # growth: +30% capacity (inside the 1.5x deadband) + grown flag
    grown_tables = {
        n: _dc.replace(t, capacity=int(t.capacity * 1.3), grown=True)
        for n, t in census.tables.items()}
    grown = _dc.replace(census, tables=grown_tables)
    d = runner.replan(grown, capacity_drift=1.5)
    assert d["capacity_grown"] and d["changed"] and d["rebuilt"]
    assert not d["capacity_drifted"]
    assert runner.plan.table_capacity["embed"] == grown_tables["embed"].capacity
    # wire flip: pin every dense parameter to f32 on the wire
    dense = [p.name for p in plan_leaves(runner.plan.params) if not p.sparse]
    hinted = _dc.replace(grown, wire_dtypes={n: "float32" for n in dense})
    d2 = runner.replan(hinted)
    assert d2["wire_flips"] and d2["changed"] and d2["rebuilt"]
    assert not d2["pspecs_changed"]
    wires = {p.name: str(p.wire_dtype) for p in
             plan_leaves(runner.plan.params)}
    assert all(wires[n] == "float32" for n in dense)


def test_per_table_declarations_beat_global_sparsity_alpha():
    """A table named in table_zipf/table_alpha keeps its declared workload
    even when the global sparsity_alpha knob is set (per-table overrides
    global, as configs/base.py documents)."""
    rc = RunConfig(sparsity_alpha=0.9, table_zipf=(("embed", 2.0),),
                   table_alpha=(("enc_embed", 0.05),))
    uniq, alpha = sparsity._per_table(rc, "embed", rows=256, tokens=64)
    assert alpha == pytest.approx(expected_unique_zipf(64, 256, 2.0) / 256)
    _, alpha2 = sparsity._per_table(rc, "enc_embed", rows=256, tokens=64)
    assert alpha2 == pytest.approx(0.05)
    # an undeclared table still follows the global knob
    _, alpha3 = sparsity._per_table(rc, "other", rows=256, tokens=64)
    assert alpha3 == pytest.approx(0.9)


def test_profile_reset_grad_census_drops_only_bucket_keys():
    prof = SparsityProfile()
    prof.update({"embed_unique": 40.0, "embed_dropped": 2.0,
                 "gbucket0_gmax": 1.0, "gbucket0_grms": 0.1,
                 "gbucket1_gmax": 9.0, "gbucket1_grms": 0.2})
    prof.reset_grad_census()
    assert not any(k.startswith("gbucket") for k in prof.ema)
    assert not any(k.startswith("gbucket") for k in prof.last)
    assert prof.ema["embed_unique"] == 40.0     # sparse census untouched
    assert prof.ema["embed_dropped"] == 2.0


def test_wire_dtype_hints_from_magnitude_census():
    from types import SimpleNamespace
    bp = SimpleNamespace(buckets=[SimpleNamespace(idx=(0, 1)),
                                  SimpleNamespace(idx=(2,))])
    names = ["w0", "w1", "w2"]
    prof = SparsityProfile()
    prof.update({"gbucket0_gmax": 1.0, "gbucket0_grms": 0.5,   # tame
                 "gbucket1_gmax": 10.0, "gbucket1_grms": 0.01})  # outliers
    hints = sparsity.wire_dtype_hints(prof, bp, names, outlier_ratio=64.0)
    assert hints == {"w0": "bfloat16", "w1": "bfloat16", "w2": "float32"}
    # missing EMAs (e.g. right after a bucket-count change) yield no hint
    assert sparsity.wire_dtype_hints(
        SparsityProfile(), bp, names, outlier_ratio=64.0) == {}
    assert sparsity.wire_dtype_hints(prof, None, names,
                                     outlier_ratio=64.0) == {}


def test_wire_dtype_hints_cover_sparse_row_buffers():
    """A sparse table that kept its own exchange emits a name-keyed
    row-buffer census and can earn an f32 pin like any bucket."""
    prof = SparsityProfile()
    prof.update({"embed_gmax": 10.0, "embed_grms": 0.01,      # outliers
                 "enc_embed_gmax": 1.0, "enc_embed_grms": 0.5})  # tame
    hints = sparsity.wire_dtype_hints(
        prof, None, [], outlier_ratio=64.0,
        sparse_tables=["embed", "enc_embed", "unseen"])
    assert hints == {"embed": "float32", "enc_embed": "bfloat16"}


def test_trainer_overflow_growth_and_monitor_surfacing(tiny_shape):
    """A workload burst overflows the capped dedupe buffer: the per-table
    dropped EMA shows up in the monitor stats, and the replan loop grows
    the table's capacity (the overflow was previously counted in-graph but
    silently discarded by the planner)."""
    from repro.runtime.trainer import Trainer, TrainerConfig
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none",
                   capacity_mode="capped", capacity_factor=2.0,
                   zipf_a=2.0, capacity_growth=1.5, overflow_tolerance=0.5)
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch, zipf_a=2.0, burst_steps=4,
                     burst_zipf_a=1.3)
    tcfg = TrainerConfig(total_steps=8, replan_every=6, replan_warmup=2,
                         replan_drift=50.0)   # only growth can trigger
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    cap0 = t.plan.table_capacity["embed"]
    stats = []
    t.run(on_metrics=lambda s, m: stats.append(m))
    # overflow surfaced host-side before (and after) the growth replan
    assert any(m.get("overflow", {}).get("embed", 0) > 0 for m in stats)
    assert "overflow_rows" in stats[-1]
    assert t.monitor.replans >= 1
    assert t.plan.table_capacity["embed"] > cap0
    assert "embed" in t.plan.grown_tables
    assert all(np.isfinite(m["loss"]) for m in stats)


# ---------------------------------------------------------------------------
# the sparsity profile EMA
# ---------------------------------------------------------------------------

def test_profile_ema_and_observed_census():
    rc = RunConfig(capacity_mode="capped", capacity_factor=2.0)
    prof = SparsityProfile(decay=0.5)
    assert not prof.ready()
    prof.update({"loss": 3.0})                   # no census keys: ignored
    assert not prof.ready()
    prof.update({"embed_unique": 40.0, "loss": 3.0})
    prof.update({"embed_unique": 20.0})
    assert prof.ready(2)
    assert prof.ema["embed_unique"] == pytest.approx(30.0)
    base = sparsity.Census(dense_params=10, sparse_params=100, alpha=0.5,
                           local_tokens=64, capacity=64)
    obs = observed_census(prof, base, vocab=200, run_cfg=rc)
    assert obs.alpha == pytest.approx(30.0 / 200)
    assert obs.capacity == 60                    # ceil(30 * 2.0)
    assert obs.local_tokens == base.local_tokens
    # exact capacity mode never resizes buffers from the profile
    obs_exact = observed_census(prof, base, vocab=200, run_cfg=RunConfig())
    assert obs_exact.capacity == base.capacity
    # empty profile is a no-op
    assert observed_census(SparsityProfile(), base, 200, rc) is base


def test_step_metrics_carry_observed_unique(tiny_shape):
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    runner = get_runner(cfg, tiny_shape,
                        RunConfig(attention_impl="naive", remat="none"))
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    m = runner.run(ds.batch(0))
    got = float(m["embed_unique"])
    want = float(np.unique(ds.batch(0)["tokens"]).size)
    assert got == pytest.approx(want)


# ---------------------------------------------------------------------------
# staged planning purity + replan round-trips
# ---------------------------------------------------------------------------

def _methods(plan):
    import jax
    from repro.core.plan import ParamPlan
    return {p.name: p.method for p in jax.tree.leaves(
        plan.params, is_leaf=lambda x: isinstance(x, ParamPlan))}


def test_plan_from_census_equals_from_scratch(tiny_shape):
    """analyze(census=c) must equal a from-scratch analyze whose estimate
    is c — planning is a pure function of (model, rt, census)."""
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(capacity_mode="capped")
    runner = get_runner(cfg, tiny_shape, rc)
    census = estimate_census(runner.model, runner.rt)
    replanned = analyze(runner.model, runner.rt, census=census)
    staged = choose_methods(runner.model, runner.rt, census)
    for other in (replanned, staged):
        assert _methods(other) == _methods(runner.plan)
        assert other.capacity == runner.plan.capacity
        assert other.alpha == runner.plan.alpha
        d = plan_diff(runner.plan, other)
        assert not d["changed"] and not d["flips"]


def test_noop_replan_keeps_params_bit_identical(tiny_shape):
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    runner = get_runner(cfg, tiny_shape,
                        RunConfig(attention_impl="naive", remat="none"))
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    runner.run(ds.batch(0))
    before = {"embed": np.asarray(runner.state.params["embed"]).copy(),
              "m": np.asarray(runner.state.m["embed"]).copy()}
    census = estimate_census(runner.model, runner.rt)
    d = runner.replan(census)
    assert not d["changed"] and not d["rebuilt"]     # same census: no-op
    d = runner.replan(census, force=True)            # force the rebuild path
    assert d["rebuilt"]
    np.testing.assert_array_equal(before["embed"],
                                  np.asarray(runner.state.params["embed"]))
    np.testing.assert_array_equal(before["m"],
                                  np.asarray(runner.state.m["embed"]))
    # the swapped-in step still trains
    m = runner.run(ds.batch(1))
    assert np.isfinite(float(m["loss"]))


def test_capacity_drift_triggers_replan(tiny_shape):
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none",
                   capacity_mode="capped", capacity_factor=1.0)
    runner = get_runner(cfg, tiny_shape, rc)
    cap0 = runner.plan.capacity
    prof = SparsityProfile()
    prof.update({"embed_unique": cap0 / 4})
    census = observed_census(prof, estimate_census(runner.model, runner.rt),
                             cfg.vocab_size, rc)
    d = runner.replan(census)
    assert d["capacity_drifted"] and d["rebuilt"]
    assert runner.plan.capacity < cap0


def test_trainer_replan_hook_and_monitor(tiny_shape, tmp_path):
    from repro.runtime.trainer import Trainer, TrainerConfig
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none",
                   capacity_mode="capped", capacity_factor=1.5)
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    tcfg = TrainerConfig(total_steps=8, replan_every=4, replan_warmup=2,
                         replan_drift=1.3)
    t = Trainer(cfg, tiny_shape, rc, tcfg, ds)
    cap0 = t.plan.capacity
    stats = []
    t.run(on_metrics=lambda s, m: stats.append(m))
    # Zipf data vs uniform estimate: the capacity must have shrunk
    assert t.monitor.replans >= 1
    assert t.plan.capacity < cap0
    assert t.plan.alpha < cap0 / cfg.vocab_size
    assert "observed_alpha" in stats[-1]
    assert stats[-1]["replans"] == t.monitor.replans
    assert all(np.isfinite(m["loss"]) for m in stats)


def test_remesh_uses_existing_state_without_init(tiny_shape, monkeypatch):
    """The elastic rebuild must not materialize a throwaway model.init."""
    from repro.runtime.trainer import Trainer, TrainerConfig
    cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
    rc = RunConfig(attention_impl="naive", remat="none")
    ds = SyntheticLM(cfg.vocab_size, tiny_shape.seq_len,
                     tiny_shape.global_batch)
    t = Trainer(cfg, tiny_shape, rc, TrainerConfig(total_steps=2), ds)
    t.run()
    before = np.asarray(t.state.params["embed"]).copy()
    step_before = int(t.state.step)

    def boom(*a, **k):
        raise AssertionError("remesh materialized a fresh model.init")

    monkeypatch.setattr(type(t.model), "init", boom)
    t.remesh(None)
    np.testing.assert_array_equal(before,
                                  np.asarray(t.state.params["embed"]))
    assert int(t.state.step) == step_before
    # and the rebuilt step still runs on the restored state
    t.tcfg = dataclasses.replace(t.tcfg, total_steps=3)
    t.run()
    assert t.step == 3


# ---------------------------------------------------------------------------
# distributed: method-flipping replan preserves the loss trajectory
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_method_flipping_replan_preserves_trajectory():
    """On a (4 data x 2 model) mesh with Zipf ids, the uniform estimate
    plans `ps` but the observed α is below the ps/ps_gather crossover: the
    replan must flip the embedding method, keep pspecs (no host round-trip),
    and reproduce the static run's losses exactly (correctness contract
    across a hot-swap)."""
    code = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.sparsity import SparsityProfile, observed_census
from repro.core.transform import estimate_census, get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
# this scenario tunes the ps/ps_gather *byte* crossover on a toy 64KB
# table; at that size the per-message latency term swamps bytes and
# legitimately argmins to dense allreduce — link_latency=0 pins the paper's
# pure Table-3 byte model (latency behavior is covered by
# test_cost_model.py and test_buckets.py)
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=2.0, link_latency=0.0)
ds = SyntheticLM(cfg.vocab_size, 32, 8)
mesh = make_mesh((4, 2), ("data", "model"))

def drive(adaptive):
    with use_mesh(mesh):
        run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
        first = run.plan.embed_method
        prof = SparsityProfile()
        losses, flips, pspecs_changed = [], [], False
        for i in range(8):
            m = run.run(ds.batch(i))
            losses.append(float(m["loss"]))
            prof.update({k: float(v) for k, v in m.items()
                         if getattr(v, "ndim", 0) == 0})
            if adaptive and i == 3:
                census = observed_census(
                    prof, estimate_census(run.model, run.rt),
                    cfg.vocab_size, run.rt.run_cfg)
                d = run.replan(census)
                flips = d["flips"]
                pspecs_changed = d["pspecs_changed"]
        return dict(first=first, last=run.plan.embed_method, losses=losses,
                    flips=flips, pspecs_changed=pspecs_changed,
                    alpha=run.plan.alpha)

static = drive(False)
adaptive = drive(True)
print("RESULT:" + json.dumps({"static": static, "adaptive": adaptive}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    st, ad = res["static"], res["adaptive"]
    assert st["first"] == st["last"] == "ps"
    assert ad["first"] == "ps" and ad["last"] == "ps_gather", ad
    assert ad["flips"], "replan did not flip any method"
    assert not ad["pspecs_changed"]      # row-sharded either way: state stays
    assert ad["alpha"] < st["alpha"]     # observed < uniform estimate
    for i, (a, b) in enumerate(zip(st["losses"], ad["losses"])):
        assert abs(a - b) < 5e-4 + 1e-4 * i, (i, st["losses"], ad["losses"])


@pytest.mark.distributed
def test_two_table_model_gets_per_table_methods_and_capacities():
    """The per-parameter acceptance scenario: on a (4 data x 2 model) mesh,
    one analyze() call gives a Zipf-skewed vocab table and a declared
    near-dense secondary table *different* methods and capacities, the two
    tables report separate census metrics, and training runs."""
    code = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.transform import get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("parallax-nmt"), vocab=256)
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=1.5, link_latency=0.0,
          table_zipf=(("embed", 1.3),), table_alpha=(("enc_embed", 0.99),))
mesh = make_mesh((4, 2), ("data", "model"))
with use_mesh(mesh):
    run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
    ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True, src_zipf_a=0.0)
    losses, uniq = [], {}
    for i in range(3):
        m = run.run(ds.batch(i))
        losses.append(float(m["loss"]))
        uniq = {k: float(v) for k, v in m.items()
                if k.endswith(("_unique", "_dropped"))}
print("RESULT:" + json.dumps({
    "tables": run.plan.tables(), "losses": losses, "metrics": uniq,
    "capacity": run.plan.capacity}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    tables = res["tables"]
    assert set(tables) == {"embed", "enc_embed"}, tables
    # the skewed table lands on a sparse exchange; the near-dense one on the
    # dense all-reduce — different methods AND capacities from one analyze()
    assert tables["embed"]["method"] in ("ps", "ps_gather", "mpi_gatherv")
    assert tables["enc_embed"]["method"] == "allreduce"
    assert tables["embed"]["capacity"] < tables["enc_embed"]["capacity"]
    assert {"embed_unique", "enc_embed_unique", "embed_dropped",
            "enc_embed_dropped"} <= set(res["metrics"])
    assert all(np.isfinite(l) for l in res["losses"])


@pytest.mark.distributed
def test_dense_routed_table_capacity_sizes_for_global_dedupe():
    """The capacity sizing flip: a sparse table routed to the *dense*
    exchange (allreduce) dedupes once over the global batch in global
    semantics, so under capped mode its buffer is sized exactly to
    min(global tokens, rows) — a bound at which it can never drop — while
    a sparse-routed sibling keeps the per-replica Zipf estimate (bounded
    by local tokens)."""
    code = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.transform import get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("parallax-nmt"), vocab=256)
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=1.5, link_latency=0.0,
          table_zipf=(("embed", 1.3),), table_alpha=(("enc_embed", 0.99),))
mesh = make_mesh((4, 2), ("data", "model"))
with use_mesh(mesh):
    run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
    ds = SyntheticLM(cfg.vocab_size, 32, 8, is_encdec=True, src_zipf_a=0.0)
    losses, dropped = [], {}
    for i in range(3):
        m = run.run(ds.batch(i))
        losses.append(float(m["loss"]))
        dropped = {k: float(v) for k, v in m.items()
                   if k.endswith("_dropped")}
print("RESULT:" + json.dumps({
    "tables": run.plan.tables(), "losses": losses, "dropped": dropped,
    "tokens": shape.tokens, "rows": run.rt.padded_vocab,
    "local_tokens": shape.tokens // 4}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    tables = res["tables"]
    assert tables["enc_embed"]["method"] == "allreduce", tables
    assert tables["embed"]["method"] in ("ps", "ps_gather", "mpi_gatherv")
    # dense-routed: exact global-dedupe sizing, not the Zipf estimate
    want = min(res["tokens"], res["rows"])
    assert tables["enc_embed"]["capacity"] == want, res
    assert want > res["local_tokens"], res   # the flip genuinely mattered
    # sparse-routed sibling keeps the per-replica capped estimate
    assert tables["embed"]["capacity"] <= res["local_tokens"], res
    # and at the exact bound the dense-routed table never drops
    assert res["dropped"].get("enc_embed_dropped") == 0.0, res
    assert all(np.isfinite(l) for l in res["losses"])


@pytest.mark.distributed
def test_wire_dtype_auto_replan_from_magnitude_census():
    """End-to-end profiled wire-dtype selection: on a DP mesh the bucketed
    step emits the per-bucket |g|inf/rms magnitude census; with an
    outlier-ratio of 0 every bucket profiles as outlier-prone, so the replan
    pins all dense parameters to f32 on the wire (wire_flips), re-derives
    the buckets at the new dtype, and training continues."""
    code = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.plan import plan_leaves
from repro.core.sparsity import SparsityProfile, observed_census, \\
    wire_dtype_hints
from repro.core.transform import estimate_census, get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="bfloat16", opsw=True,
          capacity_mode="capped", capacity_factor=2.0,
          wire_dtype_auto=True, wire_outlier_ratio=0.0)
ds = SyntheticLM(cfg.vocab_size, 32, 8)
mesh = make_mesh((8, 1), ("data", "model"))
with use_mesh(mesh):
    run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
    assert run.plan.bucket_plan is not None
    keys0 = sorted({b.key[1] for b in run.plan.bucket_plan.buckets})
    prof = SparsityProfile()
    for i in range(3):
        m = run.run(ds.batch(i))
        prof.update({k: float(v) for k, v in m.items()
                     if getattr(v, "ndim", 0) == 0})
    gm = {k: v for k, v in prof.ema.items() if k.endswith(("_gmax", "_grms"))}
    census = observed_census(prof, estimate_census(run.model, run.rt),
                             cfg.vocab_size, run.rt.run_cfg)
    names = [p.name for p in plan_leaves(run.plan.params)]
    census.wire_dtypes = wire_dtype_hints(
        prof, run.plan.bucket_plan, names, outlier_ratio=0.0)
    d = run.replan(census)
    wires = sorted({str(p.wire_dtype) for p in plan_leaves(run.plan.params)
                    if not p.sparse})
    keys1 = sorted({b.key[1] for b in run.plan.bucket_plan.buckets})
    loss = float(run.run(ds.batch(3))["loss"])
print("RESULT:" + json.dumps({
    "n_gm": len(gm), "n_buckets": len(run.plan.bucket_plan.buckets),
    "wire_flips": d["wire_flips"], "rebuilt": d["rebuilt"],
    "pspecs_changed": d["pspecs_changed"], "wires": wires,
    "keys0": keys0, "keys1": keys1, "loss": loss}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    # the magnitude census reached the host: one gmax + one grms per bucket
    assert res["n_gm"] == 2 * res["n_buckets"], res
    assert res["wire_flips"] and res["rebuilt"], res
    assert not res["pspecs_changed"]                 # trace-only change
    assert res["wires"] == ["float32"], res
    # the bucket grouping follows the per-parameter wire dtype
    assert res["keys0"] == ["bfloat16"] and res["keys1"] == ["float32"], res
    assert np.isfinite(res["loss"])


@pytest.mark.distributed
def test_overflow_growth_replan_exact_trajectory():
    """Sustained overflow (a high-unique workload burst against a capped
    dedupe buffer) must trigger a capacity-*growth* replan — below the
    capacity-drift deadband, on the grown flag alone — and the hot-swap must
    not perturb the f32 trajectory: after the burst both the small and the
    grown buffer hold every unique id, so static vs adaptive losses match
    exactly (0.0 divergence)."""
    code = """
from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.core.sparsity import SparsityProfile, observed_census
from repro.core.transform import estimate_census, get_runner
from repro.data import SyntheticLM

cfg = reduced(get_config("phi3-medium-14b"), vocab=256)
shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
# declared steady skew (zipf 2.0) sizes a tight capped buffer; the first 4
# batches draw at zipf 1.3 (roughly 3x the unique rows) and overflow it
kw = dict(attention_impl="naive", remat="none", param_dtype="float32",
          compute_dtype="float32", wire_dtype="float32",
          capacity_mode="capped", capacity_factor=2.0, zipf_a=2.0,
          capacity_growth=1.5, overflow_tolerance=0.5, link_latency=0.0)
ds = SyntheticLM(cfg.vocab_size, 32, 8, zipf_a=2.0, burst_steps=4,
                 burst_zipf_a=1.3)
mesh = make_mesh((4, 1), ("data", "model"))
STEPS, REPLAN_AT = 10, 6

def drive(adaptive):
    with use_mesh(mesh):
        run = get_runner(cfg, shape, RunConfig(**kw), mesh=mesh)
        cap0 = run.plan.table_capacity["embed"]
        prof = SparsityProfile()
        losses, dropped, diff = [], [], None
        for i in range(STEPS):
            m = run.run(ds.batch(i))
            losses.append(float(m["loss"]))
            dropped.append(float(m["embed_dropped"]))
            prof.update({k: float(v) for k, v in m.items()
                         if getattr(v, "ndim", 0) == 0})
            if adaptive and i + 1 == REPLAN_AT:
                census = observed_census(
                    prof, estimate_census(run.model, run.rt),
                    cfg.vocab_size, run.rt.run_cfg)
                d = run.replan(census, capacity_drift=50.0)
                diff = dict(capacity_grown=d["capacity_grown"],
                            capacity_drifted=d["capacity_drifted"],
                            rebuilt=d["rebuilt"], flips=d["flips"],
                            pspecs_changed=d["pspecs_changed"],
                            table_capacity=list(d["table_capacity"]))
        return dict(cap0=cap0, cap=run.plan.table_capacity["embed"],
                    grown=list(run.plan.grown_tables), losses=losses,
                    dropped=dropped, diff=diff)

static = drive(False)
adaptive = drive(True)
print("RESULT:" + json.dumps({"static": static, "adaptive": adaptive,
    "max_divergence": max(abs(a - b) for a, b in
                          zip(static["losses"], adaptive["losses"]))}))
"""
    res = distributed_run(code, devices=8, timeout=600)
    ad = res["adaptive"]
    d = ad["diff"]
    # the burst overflowed the capped buffer...
    assert max(ad["dropped"][:4]) > 0, ad["dropped"]
    # ...and the growth rule (not the drift deadband) triggered the replan
    assert d is not None and d["rebuilt"] and d["capacity_grown"], d
    assert not d["capacity_drifted"] and not d["flips"] \
        and not d["pspecs_changed"], d
    assert ad["cap"] > ad["cap0"], ad
    assert ad["grown"] == ["embed"]
    assert res["static"]["cap"] == res["static"]["cap0"]
    # post-burst unique counts fit both buffers: the swap is math-inert
    assert res["max_divergence"] == 0.0, res
