"""The sparse PS exchange: dedupe invariants (hypothesis), lookup/gradient
equivalence against the dense oracle, capacity-overflow accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from conftest import distributed_run
from repro.core.embedding import EmbedCtx, dedupe, lookup

VOCAB = 64
E = 8


def _dense_ctx(exact=True):
    return EmbedCtx(mesh=None, method="dense", batch_axes=(),
                    model_axis="", vocab_padded=VOCAB,
                    wire_dtype=jnp.float32, local_agg=True, exact=exact)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, VOCAB - 1), min_size=1, max_size=64),
       st.integers(1, 64))
def test_dedupe_reconstructs_ids(ids, capacity):
    """uids[inv] == ids for every slot that was not dropped; dropped count
    is exact."""
    arr = jnp.asarray(ids, jnp.int32)
    uids, inv, dropped = dedupe(arr, capacity, VOCAB, local_agg=True)
    n_unique = len(set(ids))
    assert int(dropped) == max(0, n_unique - min(capacity, len(ids)))
    uids_np = np.asarray(uids)
    inv_np = np.asarray(inv)
    for i, tok in enumerate(ids):
        if inv_np[i] < len(uids_np):
            assert uids_np[inv_np[i]] == tok
    # all non-sentinel uids are actually present in ids
    for u in uids_np:
        if u != VOCAB:
            assert u in ids


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, VOCAB - 1), min_size=4, max_size=32))
def test_lookup_matches_dense_gather(ids):
    table = jax.random.normal(jax.random.key(0), (VOCAB, E), jnp.float32)
    arr = jnp.asarray(ids, jnp.int32).reshape(1, -1)
    out, metrics = lookup(table, arr, ctx=_dense_ctx(), capacity=len(ids))
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.asarray(table)[np.asarray(ids)], rtol=1e-6)
    assert int(metrics["embed_dropped"]) == 0


def test_lookup_grad_matches_dense_oracle():
    table = jax.random.normal(jax.random.key(1), (VOCAB, E), jnp.float32)
    ids = jnp.asarray([[3, 5, 3, 9, VOCAB - 1, 5]], jnp.int32)

    def f(t):
        out, _ = lookup(t, ids, ctx=_dense_ctx(), capacity=6)
        return jnp.sum(out * out)

    def f_ref(t):
        return jnp.sum(t[ids[0]] ** 2)

    g1 = jax.grad(f)(table)
    g2 = jax.grad(f_ref)(table)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-6)


def test_capped_capacity_drops_and_reports():
    table = jnp.ones((VOCAB, E), jnp.float32)
    ids = jnp.arange(16, dtype=jnp.int32).reshape(1, 16)  # 16 unique
    out, metrics = lookup(table, ids, ctx=_dense_ctx(exact=False), capacity=10)
    assert int(metrics["embed_dropped"]) == 6
    # dropped rows read as zeros, kept rows as ones
    got = np.asarray(out[0]).sum(axis=-1)
    assert set(np.unique(got)) <= {0.0, float(E)}
    assert (got == E).sum() == 10


@pytest.mark.distributed
@pytest.mark.parametrize("method", ["ps", "ps_gather", "mpi_gatherv"])
def test_sharded_pull_push_matches_dense(method):
    """Distributed lookup fwd+bwd == dense oracle, per exchange method."""
    code = """
import jax.numpy as jnp
from repro.core.embedding import EmbedCtx, lookup

VOCAB, E = 64, 8
mesh = make_mesh((2, 4), ("data", "model"))
table = jax.random.normal(jax.random.key(0), (VOCAB, E), jnp.float32)
ids = jax.random.randint(jax.random.key(1), (4, 16), 0, VOCAB)

ctx = EmbedCtx(mesh=mesh, method="__METHOD__", batch_axes=("data",),
               model_axis="model", vocab_padded=VOCAB,
               wire_dtype=jnp.float32, local_agg=True)

def f(t):
    out, _ = lookup(t, ids, ctx=ctx, capacity=32)
    return jnp.sum(out * out), out

with use_mesh(mesh):
    (loss, out), grad = jax.jit(jax.value_and_grad(f, has_aux=True))(table)

def f_ref(t):
    return jnp.sum(t[ids] ** 2)
g_ref = jax.grad(f_ref)(table)
out_ref = table[ids]
import numpy as np
print("RESULT:" + json.dumps({
    "out_err": float(jnp.abs(out - out_ref).max()),
    "grad_err": float(jnp.abs(grad - g_ref).max()),
}))
"""
    res = distributed_run(code.replace("__METHOD__", method), devices=8)
    assert res["out_err"] < 1e-5, res
    assert res["grad_err"] < 1e-5, res
