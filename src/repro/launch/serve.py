"""Serving launcher: batched requests against a small model.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --reduced --requests 16 --max-new 8

``--engine paged`` (default) runs the rebuilt engine: one jitted prefill
per admission, slot-paged decode, device-side sampling. ``--engine toy``
runs the teacher-forced baseline loop (also the fallback for recurrent
families, whose carry cannot be bucket-prefilled under padding).
"""
import argparse
import os


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--engine", choices=("paged", "toy"), default="paged")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--sample", action="store_true",
                    help="temperature sampling instead of greedy argmax")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args()


ARGS = _parse()
if ARGS.devices:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={ARGS.devices} "
        + os.environ.get("XLA_FLAGS", ""))

import time  # noqa: E402
import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import RunConfig, get_config, reduced  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.runtime.server import (Request, Server, ServerConfig,  # noqa: E402
                                  ToyServer)


def main():
    args = ARGS
    print(f"jax {jax.__version__}  devices={jax.device_count()}  "
          f"explicit_sharding={compat.has_explicit_sharding()}")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(dims) == 2 else \
            ("pod", "data", "model")
        mesh = make_mesh(dims, axes)
    rng = np.random.default_rng(args.seed)
    cls = Server if args.engine == "paged" else ToyServer
    server = cls(cfg, RunConfig(attention_impl="naive"),
                 ServerConfig(max_batch=args.max_batch,
                              max_seq=args.max_seq,
                              greedy=not args.sample,
                              temperature=args.temperature), mesh=mesh)
    for i in range(args.requests):
        plen = int(rng.integers(2, 9))
        server.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, plen,
                                       dtype=np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    done = server.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    ttft = sorted(r.ttft for r in done)
    print(f"[{args.engine}] served {len(done)} requests, {toks} tokens in "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s, TTFT p50 "
          f"{ttft[len(ttft)//2]*1e3:.1f} ms)")
    if args.engine == "paged":
        print(f"  {server.stats['prefill_calls']} prefill dispatches / "
              f"{server.stats['prefill_traces']} traces over buckets "
              f"{sorted(server.stats['buckets'])}, "
              f"{server.stats['decode_steps']} decode steps, "
              f"{server.stats['cross_slot_mismatches']} cross-slot "
              f"mismatches")
        server.close()
    for r in done[:4]:
        print(f"  req {r.uid}: prompt {r.prompt.tolist()} -> {r.out_tokens}")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
