"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests keep their single device.
Construction goes through repro.compat so the same launchers run on every
supported JAX (see src/repro/compat/).
"""
from __future__ import annotations

from repro.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh with Auto axis types (tests / examples)."""
    return _compat_make_mesh(shape, axes)
