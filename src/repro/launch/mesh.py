"""Production mesh builders + the elastic shrink helper.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests keep their single device.
Construction goes through repro.compat so the same launchers run on every
supported JAX (see src/repro/compat/).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.compat import Mesh
from repro.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh with Auto axis types (tests / examples)."""
    return _compat_make_mesh(shape, axes)


def shrink_mesh(mesh: Optional[Mesh], drop_axis_index: int,
                axis: str = "data", min_axis_size: int = 1) -> Optional[Mesh]:
    """Rebuild ``mesh`` without one slice along ``axis`` — the elastic
    straggler-eviction path: dropping index ``drop_axis_index`` along the
    data axis evicts that slice's devices (the suspected-slow host) and the
    remaining device grid becomes a mesh with the same axis names.

    Returns ``None`` when the mesh cannot shrink: no mesh, the axis is
    absent, or shrinking would take it below ``min_axis_size`` (the
    trainer's ``min_data_parallel`` floor). Raises on an out-of-range index
    — the caller named a slice that does not exist.

    The surviving devices keep their grid positions (no re-layout), so
    every other slice's placement is stable across the shrink — only the
    evicted slice's shards move, through the elastic state reshard.
    """
    if mesh is None or axis not in mesh.axis_names:
        return None
    ax = mesh.axis_names.index(axis)
    devices = np.asarray(mesh.devices)
    size = devices.shape[ax]
    if not 0 <= drop_axis_index < size:
        raise ValueError(
            f"drop_axis_index {drop_axis_index} out of range for "
            f"{axis}={size}")
    if size <= 1 or size - 1 < min_axis_size:
        return None
    kept = np.delete(devices, drop_axis_index, axis=ax)
    # the Mesh constructor (via repro.compat) takes the device grid as-is —
    # no re-layout, unlike the make_mesh convenience path. Axis types carry
    # over where the installed JAX has them (pre-AxisType JAX has neither
    # the attribute nor the kwarg, and Auto is its only behavior)
    axis_types = getattr(mesh, "axis_types", None)
    if axis_types is not None:
        return Mesh(kept, mesh.axis_names, axis_types=axis_types)
    return Mesh(kept, mesh.axis_names)
