"""Production mesh builders + the elastic shrink/grow helpers.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests keep their single device.
Construction goes through repro.compat so the same launchers run on every
supported JAX (see src/repro/compat/).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.compat import Mesh
from repro.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh with Auto axis types (tests / examples)."""
    return _compat_make_mesh(shape, axes)


def slice_for_process(mesh: Optional[Mesh], process_index: int,
                      axis: str = "data") -> Optional[int]:
    """Map a process (host) index to the ``axis`` slice wholly owned by its
    devices — the attribution step between "process P is slow" (per-host
    heartbeats, runtime/monitor.py) and "drop slice i" (``shrink_mesh``).

    Returns ``None`` when no single slice is wholly owned by that process
    (no mesh, the axis is absent, or the host's devices straddle slices —
    e.g. a host owning a whole *model* column): the caller falls back to
    its by-convention choice rather than evicting healthy devices.
    """
    if mesh is None or axis not in mesh.axis_names:
        return None
    ax = mesh.axis_names.index(axis)
    devices = np.asarray(mesh.devices)
    moved = np.moveaxis(devices, ax, 0)
    for i in range(moved.shape[0]):
        procs = {getattr(d, "process_index", 0) for d in moved[i].flat}
        if procs == {process_index}:
            return i
    return None


def shrink_mesh(mesh: Optional[Mesh], drop_axis_index: Optional[int] = None,
                axis: str = "data", min_axis_size: int = 1,
                drop_process_index: Optional[int] = None) -> Optional[Mesh]:
    """Rebuild ``mesh`` without one slice along ``axis`` — the elastic
    straggler-eviction path: dropping index ``drop_axis_index`` along the
    data axis evicts that slice's devices (the suspected-slow host) and the
    remaining device grid becomes a mesh with the same axis names.

    Returns ``None`` when the mesh cannot shrink: no mesh, the axis is
    absent, or shrinking would take it below ``min_axis_size`` (the
    trainer's ``min_data_parallel`` floor). Raises on an out-of-range index
    — the caller named a slice that does not exist.

    The surviving devices keep their grid positions (no re-layout), so
    every other slice's placement is stable across the shrink — only the
    evicted slice's shards move, through the elastic state reshard.

    ``drop_process_index`` names the slow *host* instead of a grid index
    (the attribution path): it resolves through ``slice_for_process`` and
    returns ``None`` when that host does not own a whole slice — the
    caller keeps its by-convention fallback rather than guessing.
    """
    if mesh is None or axis not in mesh.axis_names:
        return None
    if drop_process_index is not None:
        if drop_axis_index is not None:
            raise ValueError(
                "pass drop_axis_index or drop_process_index, not both")
        drop_axis_index = slice_for_process(mesh, drop_process_index, axis)
        if drop_axis_index is None:
            return None
    elif drop_axis_index is None:
        raise ValueError("need drop_axis_index or drop_process_index")
    ax = mesh.axis_names.index(axis)
    devices = np.asarray(mesh.devices)
    size = devices.shape[ax]
    if not 0 <= drop_axis_index < size:
        raise ValueError(
            f"drop_axis_index {drop_axis_index} out of range for "
            f"{axis}={size}")
    if size <= 1 or size - 1 < min_axis_size:
        return None
    kept = np.delete(devices, drop_axis_index, axis=ax)
    # the Mesh constructor (via repro.compat) takes the device grid as-is —
    # no re-layout, unlike the make_mesh convenience path. Axis types carry
    # over where the installed JAX has them (pre-AxisType JAX has neither
    # the attribute nor the kwarg, and Auto is its only behavior)
    axis_types = getattr(mesh, "axis_types", None)
    if axis_types is not None:
        return Mesh(kept, mesh.axis_names, axis_types=axis_types)
    return Mesh(kept, mesh.axis_names)


def grow_mesh(mesh: Optional[Mesh], slice_devices,
              insert_axis_index: Optional[int] = None,
              axis: str = "data") -> Optional[Mesh]:
    """Rebuild ``mesh`` with one extra slice along ``axis`` — the elastic
    re-admission path: an evicted host that returned contributes its
    devices back as a slice, re-inserted at ``insert_axis_index`` (its old
    grid position, so a shrink→grow round trip restores the original
    device grid exactly; default: appended after the last slice).

    ``slice_devices`` must match the shape of one existing slice (the
    grid with ``axis`` removed; a flat sequence of the right length is
    reshaped) and be disjoint from the surviving devices. Returns ``None``
    when there is no mesh or the axis is absent; raises ``ValueError`` on
    a shape mismatch, device overlap, or out-of-range insert index — the
    caller offered a slice that cannot rejoin this grid.

    Surviving devices keep their grid positions, mirroring ``shrink_mesh``:
    only the returning slice's shards materialize fresh (from the restored
    checkpoint / the live-state reshard in ``Trainer.readmit``).
    """
    if mesh is None or axis not in mesh.axis_names:
        return None
    ax = mesh.axis_names.index(axis)
    devices = np.asarray(mesh.devices)
    size = devices.shape[ax]
    if insert_axis_index is None:
        insert_axis_index = size
    if not 0 <= insert_axis_index <= size:
        raise ValueError(
            f"insert_axis_index {insert_axis_index} out of range for "
            f"{axis}={size} (0..{size} valid)")
    slice_shape = devices.shape[:ax] + devices.shape[ax + 1:]
    new = np.asarray(slice_devices, dtype=object)
    if new.shape != slice_shape:
        if new.size != int(np.prod(slice_shape)):
            raise ValueError(
                f"slice of {new.size} devices cannot fill a "
                f"{slice_shape} grid slice")
        new = new.reshape(slice_shape)
    overlap = set(d.id for d in devices.flat) & set(d.id for d in new.flat)
    if overlap:
        raise ValueError(
            f"returning slice overlaps the live mesh: device ids "
            f"{sorted(overlap)}")
    grown = np.insert(devices, insert_axis_index, new, axis=ax)
    axis_types = getattr(mesh, "axis_types", None)
    if axis_types is not None:
        return Mesh(grown, mesh.axis_names, axis_types=axis_types)
    return Mesh(grown, mesh.axis_names)
