"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch phi3-medium-14b \
      --steps 100 --seq 512 --batch 8 [--devices 8 --mesh 2x4] \
      [--ckpt-dir /tmp/ckpt] [--comm-mode hybrid]

``--devices N`` forces N host platform devices (set before jax import, so
this module parses argv at import time — launcher-only pattern; library code
never touches XLA_FLAGS).
"""
import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config of the arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="", help="e.g. 2x4 => data=2,model=4")
    ap.add_argument("--comm-mode", default="hybrid")
    ap.add_argument("--no-local-agg", action="store_true")
    ap.add_argument("--no-opau", action="store_true")
    ap.add_argument("--no-opsw", action="store_true")
    ap.add_argument("--capacity-mode", default="exact",
                    choices=("exact", "capped"))
    ap.add_argument("--capacity-factor", type=float, default=1.0)
    ap.add_argument("--bucket-bytes", type=int, default=4 * 1024 * 1024,
                    help="fused dense-gradient bucket size; 0 = per-tensor")
    ap.add_argument("--embed-impl", default="jnp",
                    choices=("jnp", "pallas"),
                    help="embedding gather/scatter kernels (pallas = TPU "
                    "Pallas, interpret-mode off-TPU)")
    ap.add_argument("--zipf-a", type=float, default=1.3,
                    help="skew of the synthetic token distribution")
    ap.add_argument("--plan-zipf", action="store_true",
                    help="let the planner assume the declared --zipf-a skew "
                         "(default: conservative uniform-draw bound)")
    ap.add_argument("--table-zipf", default="",
                    help="per-table declared skew for the planner, e.g. "
                         "'embed=1.3,enc_embed=1.05' (overrides --plan-zipf "
                         "for the named tables)")
    ap.add_argument("--capacity-growth", type=float, default=1.5,
                    help="capacity headroom multiplier applied when a "
                         "table's overflow EMA triggers a growth replan")
    ap.add_argument("--overflow-tolerance", type=float, default=0.5,
                    help="dropped-rows EMA (per table, per step) above "
                         "which the replan loop grows that table's capacity")
    ap.add_argument("--wire-auto", action="store_true",
                    help="profiled per-parameter wire-dtype selection: "
                         "outlier-prone gradient buckets keep f32 on the "
                         "wire, the rest ride the wire dtype")
    ap.add_argument("--wire-outlier-ratio", type=float, default=64.0,
                    help="per-bucket |g|inf/rms ratio above which --wire-"
                         "auto pins the bucket's parameters to f32")
    ap.add_argument("--hw-profile", default=None,
                    help="fitted hardware profile JSON (tools/"
                         "profile_collectives.py fit): measured intra/inter "
                         "α+β constants for the planner's argmin and the "
                         "two-level schedule choice")
    ap.add_argument("--no-fused-apply", action="store_true",
                    help="keep the per-param optimizer apply even when the "
                         "plan is eligible for the bucket-native fused "
                         "update (the fused-apply regression baseline)")
    ap.add_argument("--kernel-autotune", action="store_true",
                    help="measured block_e sweep for the Pallas embedding "
                         "kernels, cached on disk (REPRO_AUTOTUNE_CACHE); "
                         "no effect off --embed-impl pallas")
    ap.add_argument("--no-overlap", action="store_true",
                    help="pin bucket collectives after the full backward "
                         "instead of issuing each at gradient readiness "
                         "(the overlap regression baseline)")
    ap.add_argument("--replan-every", type=int, default=0,
                    help="profile->replan period in steps (0 = static plan)")
    ap.add_argument("--replan-warmup", type=int, default=2)
    ap.add_argument("--replan-drift", type=float, default=1.5,
                    help="capacity drift factor that triggers a replan")
    ap.add_argument("--profile-decay", type=float, default=0.9)
    ap.add_argument("--remesh-on-straggle", action="store_true",
                    help="elastic straggler response: on a sustained step-"
                         "time regression, checkpoint, drop the slow data "
                         "slice, re-price the plan for the smaller world, "
                         "and resume on the live state")
    ap.add_argument("--remesh-cooldown", type=int, default=50,
                    help="steps after an auto-remesh before the monitor "
                         "may escalate again (anti-thrash)")
    ap.add_argument("--min-data-parallel", type=int, default=1,
                    help="never shrink the data axis below this many slices")
    ap.add_argument("--heartbeat", action="store_true",
                    help="per-host straggler attribution: each data slice's "
                         "step-time scalar rides the fused metrics psum so "
                         "the auto-remesh evicts the *named* slow slice "
                         "instead of the last by convention")
    ap.add_argument("--no-attribution", action="store_true",
                    help="keep the by-convention last-slice eviction even "
                         "when heartbeats are on")
    ap.add_argument("--probation-steps", type=int, default=100,
                    help="probation window (steps) after readmit(): the "
                         "re-admitted slice re-straggling inside it is "
                         "re-evicted without a second full escalation")
    ap.add_argument("--probation-sustained", type=int, default=2,
                    help="outlier heartbeats on probation that re-evict")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="bound (steps) on the age of gradients the "
                         "bounded-staleness sparse fallback may apply; "
                         "0 disables the staleness machinery entirely")
    ap.add_argument("--stale-on-jitter", action="store_true",
                    help="under sustained step-time jitter below the "
                         "eviction threshold, flip sparse tables to stale "
                         "pushes (and back once the jitter drains); needs "
                         "--max-staleness > 0")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--remat", default="block")
    ap.add_argument("--attention", default="naive")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args()


ARGS = _parse()
if ARGS.devices:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={ARGS.devices} "
        + os.environ.get("XLA_FLAGS", ""))

import logging  # noqa: E402
import jax  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import RunConfig, ShapeConfig, get_config, reduced  # noqa: E402
from repro.data.pipeline import SyntheticLM  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: E402


def main():
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    print(f"jax {jax.__version__}  devices={jax.device_count()}  "
          f"explicit_sharding={compat.has_explicit_sharding()}")
    args = ARGS
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    table_zipf = tuple(
        (k, float(v)) for k, v in
        (kv.split("=", 1) for kv in args.table_zipf.split(",") if kv))
    run_cfg = RunConfig(
        comm_mode=args.comm_mode, local_agg=not args.no_local_agg,
        opau=not args.no_opau, opsw=not args.no_opsw,
        capacity_mode=args.capacity_mode,
        capacity_factor=args.capacity_factor,
        capacity_growth=args.capacity_growth,
        overflow_tolerance=args.overflow_tolerance,
        zipf_a=args.zipf_a if args.plan_zipf else None,
        table_zipf=table_zipf,
        wire_dtype_auto=args.wire_auto,
        wire_outlier_ratio=args.wire_outlier_ratio,
        hw_profile=args.hw_profile, overlap=not args.no_overlap,
        fused_apply=not args.no_fused_apply,
        kernel_autotune=args.kernel_autotune,
        bucket_bytes=args.bucket_bytes, embed_impl=args.embed_impl,
        learning_rate=args.lr, remat=args.remat,
        attention_impl=args.attention, seed=args.seed,
        heartbeat=args.heartbeat, max_staleness=args.max_staleness)
    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(dims) == 2 else \
            ("pod", "data", "model")
        mesh = make_mesh(dims, axes)
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed,
                     zipf_a=args.zipf_a, is_encdec=cfg.is_encdec,
                     frames_dim=cfg.d_model if cfg.family == "audio" else 0,
                     frames_len=max(args.seq // 4, 1))
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         log_every=args.log_every,
                         replan_every=args.replan_every,
                         replan_warmup=args.replan_warmup,
                         replan_drift=args.replan_drift,
                         profile_decay=args.profile_decay,
                         remesh_on_straggle=args.remesh_on_straggle,
                         remesh_cooldown=args.remesh_cooldown,
                         min_data_parallel=args.min_data_parallel,
                         attribution=not args.no_attribution,
                         probation_steps=args.probation_steps,
                         probation_sustained=args.probation_sustained,
                         stale_on_jitter=args.stale_on_jitter)
    trainer = Trainer(cfg, shape, run_cfg, tcfg, ds, mesh=mesh)
    trainer.maybe_restore()

    import time
    t0 = time.time()

    def on_metrics(step, m):
        if step % args.log_every == 0:
            extra = ""
            if "observed_alpha" in m:
                extra = (f"  alpha {m['observed_alpha']:.4f}"
                         f"  replans {int(m.get('replans', 0))}")
            over = {t: v for t, v in m.get("overflow", {}).items() if v > 0}
            if over:
                extra += "  dropped " + ",".join(
                    f"{t}:{v:.1f}" for t, v in sorted(over.items()))
            if m.get("remeshes"):
                extra += f"  remeshes {int(m['remeshes'])}"
            if m.get("regrows"):
                extra += f"  regrows {int(m['regrows'])}"
            if "stale_mode" in m:
                extra += f"  stale {'on' if m['stale_mode'] else 'off'}"
            if m.get("ckpt_retries"):
                extra += f"  ckpt-retries {int(m['ckpt_retries'])}"
            if "apply_seconds" in m:
                extra += f"  apply {m['apply_seconds'] * 1e6:.0f}us"
            if m.get("n_overlapped_sparse"):
                extra += f"  ovl-sparse {int(m['n_overlapped_sparse'])}"
            if "ckpt_error" in m:
                extra += f"  CKPT-ERROR {m['ckpt_error']}"
            print(f"step {step:5d}  loss {m.get('loss', float('nan')):.4f}  "
                  f"{m.get('tokens_per_s', 0):.0f} tok/s  "
                  f"gnorm {m.get('grad_norm', float('nan')):.3f}{extra}")

    trainer.run(on_metrics=on_metrics)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps * shape.tokens / dt:.0f} tok/s avg)")


if __name__ == "__main__":
    main()
