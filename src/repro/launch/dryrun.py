import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) on the 16x16 production mesh and the 2x16x16
multi-pod mesh, record memory/cost/collective analysis for §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b \
      --shape train_4k [--multi-pod] [--comm-mode hybrid] [--out results/]
  PYTHONPATH=src python -m repro.launch.dryrun --sweep   # all cells
"""
import argparse
import json
import time
import traceback
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.compat import NamedSharding, P, cost_analysis, use_mesh
from repro.configs import (RunConfig, SHAPES, ALL_ARCHS, get_config,
                           shapes_for)
from repro.core.runtime import Runtime
from repro.core.transform import (analyze, batch_shardings, make_train_step,
                                  make_decode_step, make_prefill_step,
                                  state_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.optim.optimizer import make_optimizer, TrainState
from repro.utils.hlo import analyze_hlo
from repro.utils.roofline import roofline_from_analysis, HW
from repro.utils.traffic import estimate_traffic
from repro.utils.tree import tree_bytes


def _abstract_state(model, optimizer):
    params = model.abstract_params()
    return jax.eval_shape(optimizer.init, params)


def _ns_tree(mesh, pspec_tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               run_cfg: RunConfig):
    """Build + lower + compile one cell. Returns (compiled, rt, plan, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rt = Runtime(cfg, run_cfg, shape, mesh=mesh)
    model = build_model(cfg, rt)
    plan = analyze(model, rt)
    rt.plan = plan
    optimizer = make_optimizer(rt)

    with use_mesh(mesh):
        if shape.kind == "train":
            step = make_train_step(model, optimizer, rt, plan)
            state = _abstract_state(model, optimizer)
            sh = state_shardings(plan, state)
            bs = batch_shardings(plan, model.input_specs(shape))
            lowered = jax.jit(step, in_shardings=(sh, bs),
                              out_shardings=(sh, None),
                              donate_argnums=0).lower(
                state, model.input_specs(shape))
        elif shape.kind == "prefill":
            step = make_prefill_step(model, rt, plan)
            from repro.core.transform import param_shardings
            psh = param_shardings(plan)
            bs = batch_shardings(plan, model.input_specs(shape))
            lowered = jax.jit(step, in_shardings=(psh, bs)).lower(
                model.abstract_params(), model.input_specs(shape))
        else:  # decode
            step = make_decode_step(model, rt, plan)
            from repro.core.transform import param_shardings
            psh = param_shardings(plan)
            cache = model.abstract_cache(shape)
            cps = model.cache_pspecs()
            csh = _ns_tree(mesh, cps) if cps is not None else None
            ba = rt.rules.rules.get("batch")
            tok_sh = NamedSharding(mesh, P(ba, None))
            len_sh = NamedSharding(mesh, P())
            lowered = jax.jit(
                step, in_shardings=(psh, csh, tok_sh, len_sh),
                out_shardings=(None, csh), donate_argnums=1).lower(
                model.abstract_params(), cache,
                jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    return compiled, rt, plan, model


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             run_cfg: RunConfig = None, verbose: bool = True) -> dict:
    run_cfg = run_cfg or RunConfig()
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.time()
    try:
        compiled, rt, plan, model = lower_cell(
            arch, shape_name, multi_pod=multi_pod, run_cfg=run_cfg)
    except Exception as e:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    cfg = get_config(arch)
    hlo = analyze_hlo(compiled.as_text(),
                      f32_collective_scale=0.5 if run_cfg.opsw else 1.0)

    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if cfg.is_encdec and cfg.enc_layers:
        # encoder layers process seq/enc_ratio frames, not the full tokens
        from repro.models.encdec import enc_ratio
        L = cfg.n_layers + cfg.enc_layers
        enc_share = cfg.enc_layers / L
        n_active = n_active * (1 - enc_share + enc_share / enc_ratio(cfg))
    if shape.kind == "train":
        tokens = shape.tokens
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.tokens
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2.0 * n_active * tokens

    peak_mem = (mem.argument_size_in_bytes + mem.temp_size_in_bytes +
                mem.output_size_in_bytes - mem.alias_size_in_bytes)
    traffic = estimate_traffic(
        cfg, shape, chips=chips, model_shards=rt.model_shards,
        remat=run_cfg.remat, zero_stage=plan.zero_stage)
    terms = roofline_from_analysis(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        cost={"flops": hlo.dot_flops, "bytes accessed": traffic.total},
        collective_bytes=hlo.collective_bytes,
        model_flops_global=model_flops,
        peak_memory=peak_mem,
        collective_breakdown=hlo.collective_by_kind,
    )
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": True,
        "chips": chips, "compile_s": compile_s,
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": peak_mem,
        },
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed") if k in cost},
        "traffic": traffic.to_dict(),
        "hlo": hlo.to_dict(),
        "plan": {"alpha": plan.alpha, "capacity": plan.capacity,
                 "embed_method": plan.embed_method,
                 "zero_stage": plan.zero_stage,
                 "methods": plan.methods(),
                 "tables": plan.tables(),
                 "census": plan.census()},
        "roofline": terms.to_dict(),
        "run_cfg": {"comm_mode": run_cfg.comm_mode,
                    "local_agg": run_cfg.local_agg,
                    "opau": run_cfg.opau, "opsw": run_cfg.opsw,
                    "capacity_mode": run_cfg.capacity_mode,
                    "remat": run_cfg.remat,
                    "explicit_sp": run_cfg.explicit_sp,
                    "dense_strategy": run_cfg.dense_strategy},
    }
    if verbose:
        r = terms
        print(f"[{arch} × {shape_name} × {mesh_name}] compile {compile_s:.1f}s"
              f"  peak/chip {peak_mem/1e9:.2f} GB"
              f"  compute {r.compute_s*1e3:.2f} ms  memory {r.memory_s*1e3:.2f} ms"
              f"  collective {r.collective_s*1e3:.2f} ms"
              f"  dominant={r.dominant}  roofline={r.roofline_fraction:.3f}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--comm-mode", default="hybrid")
    ap.add_argument("--capacity-mode", default="capped")
    ap.add_argument("--no-local-agg", action="store_true")
    ap.add_argument("--no-opau", action="store_true")
    ap.add_argument("--no-opsw", action="store_true")
    ap.add_argument("--remat", default="block")
    ap.add_argument("--explicit-sp", action="store_true")
    ap.add_argument("--dense-strategy", default="tp")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    run_cfg = RunConfig(
        comm_mode=args.comm_mode, capacity_mode=args.capacity_mode,
        local_agg=not args.no_local_agg, opau=not args.no_opau,
        opsw=not args.no_opsw, remat=args.remat,
        explicit_sp=args.explicit_sp, dense_strategy=args.dense_strategy)
    os.makedirs(args.out, exist_ok=True)

    cells = []
    if args.sweep:
        for arch in ALL_ARCHS:
            for shape in shapes_for(arch):
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    meshes = [False, True] if (args.both_meshes or args.sweep) else \
        [args.multi_pod]
    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            res = run_cell(arch, shape, multi_pod=mp, run_cfg=run_cfg)
            tag = f"__{args.tag}" if args.tag else ""
            name = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}{tag}.json"
            with open(os.path.join(args.out, name), "w") as f:
                json.dump(res, f, indent=1)
            if not res["ok"]:
                n_fail += 1
                print(f"FAIL [{arch} × {shape} × "
                      f"{'2x16x16' if mp else '16x16'}]: {res['error']}")
            jax.clear_caches()  # keep host memory bounded across the sweep
    print(f"dry-run complete: {len(cells)*len(meshes)-n_fail} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
