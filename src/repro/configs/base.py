"""Config system: model architecture, input shapes, mesh, and run options.

Every assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
with the exact published numbers. Smoke tests use ``reduced(CONFIG)``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (exact published numbers)."""

    name: str
    family: str                     # dense | moe | vlm | ssm | hybrid | audio | lstm
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    shared_expert: bool = False     # llama4-style shared expert alongside routed
    # --- SSM / hybrid ---
    ssm_state: int = 0
    conv_width: int = 4
    # --- enc-dec (audio) ---
    is_encdec: bool = False
    enc_layers: int = 0             # if encdec: encoder layers (n_layers = decoder)
    frontend_stub: bool = False     # input_specs() provides precomputed embeddings
    # --- misc ---
    tie_embeddings: bool = False
    use_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    source: str = ""

    # ---- derived, sharding-aware quantities ----
    def padded_heads(self, shards: int) -> int:
        """q heads padded to divisibility for TP (zero-init pad => exact)."""
        if self.n_heads == 0:
            return 0
        return _round_up(self.n_heads, shards)

    def padded_vocab(self, shards: int) -> int:
        return _round_up(self.vocab_size, shards)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve a 500k context (long_500k shape)?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer blocks)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":                     # rwkv6-ish census
            per_layer = 4 * d * d + 3 * d * f // 1 + 2 * d  # timemix + channelmix approx
            per_layer = 4 * d * d + 2 * d * f + 6 * d
        else:
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.n_experts > 0:
                ffn = self.n_experts * 3 * d * f
                if self.shared_expert:
                    ffn += 3 * d * f
            else:
                ffn = 3 * d * f
            per_layer = attn + ffn
            if self.family == "hybrid":
                per_layer += 3 * d * d // 1 + d * self.ssm_state * 2   # ssm head branch
        layers = L + (self.enc_layers if self.is_encdec else 0)
        body = layers * per_layer
        if self.is_encdec:  # cross attention in decoder
            body += L * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)
        return emb + body

    def active_param_count(self) -> int:
        """Params touched per token (MoE active experts only) for 6·N·D."""
        if self.n_experts == 0:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        routed_total = self.n_experts * 3 * d * f * L
        routed_active = self.experts_per_token * 3 * d * f * L
        return self.param_count() - routed_total + routed_active


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class RunConfig:
    """Distribution/runtime knobs — the Parallax plan inputs."""

    # paper's comm modes: hybrid (the contribution), ps, mpi (baselines)
    comm_mode: str = "hybrid"         # hybrid | ps | mpi
    local_agg: bool = True            # C2: dedup + hierarchical aggregation
    opau: bool = True                 # C3a: clip/EMA after aggregation, scalar-only
    opsw: bool = True                 # C3b: wire-dtype cast before collectives
    wire_dtype: str = "bfloat16"
    # sparse-exchange capacity mode (static-shape TPU adaptation)
    capacity_mode: str = "exact"      # exact | capped
    capacity_factor: float = 1.0      # multiplier on expected unique rows
    # overflow-driven capacity growth (capped mode): when a table's observed
    # ``*_dropped`` EMA stays above ``overflow_tolerance`` rows/step, the
    # replan loop regrows that table's capacity to
    # ceil(observed_unique * capacity_factor * capacity_growth) — headroom
    # past measured demand so one growth absorbs recurring bursts.
    capacity_growth: float = 1.5
    overflow_tolerance: float = 0.5
    # memory strategy for dense params (auto-escalated by the planner)
    zero_stage: int = 0               # 0: replicate, 1: shard opt state, 3: fsdp
    remat: str = "block"              # none | block | full
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"
    learning_rate: float = 1e-3
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    ema_decay: float = 0.0            # 0 disables EMA shadow params
    seed: int = 0
    # §Perf knobs (beyond-paper optimizations; default off = paper-faithful)
    explicit_sp: bool = False         # explicit AG/RS sequence-parallel blocks
    dense_strategy: str = "tp"        # tp | dp (dp: model axis joins data)
    # §Exchange-schedule knobs: these change how the Table-3 plan *executes*
    # (collective fusion, kernel choice), never what is exchanged — wire
    # bytes, placement, and math are those of the paper's plan (summation
    # order aside), so bucketing defaults on. Set bucket_bytes=0 for the
    # per-tensor baseline.
    # bucketed dense-gradient exchange (core/buckets.py): fuse per-tensor
    # all-reduces into flat buffers of at most this many wire bytes. 0
    # disables. Applies on data-parallel meshes (every non-batch axis size
    # 1); elsewhere the planner falls back to per-tensor collectives.
    bucket_bytes: int = 4 * 1024 * 1024
    # embedding gather/scatter implementation for the sparse hot path:
    # jnp (take/scatter-add) | pallas (kernels/embed_gather + embed_scatter,
    # interpret-mode off-TPU)
    embed_impl: str = "jnp"
    # per-message collective latency override (seconds) for the planner's
    # α + β·b argmin; None = utils/roofline.py HW.link_latency. 0 recovers
    # the paper's pure-byte Table-3 argmin.
    link_latency: Optional[float] = None
    # path to a fitted hardware profile (tools/profile_collectives.py fit):
    # JSON overriding link_bw/link_latency and — on multi-host meshes — the
    # inter-host inter_bw/inter_latency tier, so the planner's argmin and
    # the two-level-schedule choice run on measured constants, not defaults.
    hw_profile: Optional[str] = None
    # communication/computation overlap for the bucketed exchange: buckets
    # are ordered reverse-topologically by the backward pass and each
    # bucket's fused psum is issued inside the backward graph as soon as its
    # last gradient is produced (core/buckets.py custom_vjp taps). False
    # pins every bucket collective strictly after the full backward — the
    # regression baseline; the math is bit-identical either way.
    overlap: bool = True
    # attention implementation: naive (tests) | chunked (dry-run) | pallas (TPU)
    attention_impl: str = "chunked"
    attention_chunk: int = 1024
    moe_exec: str = "auto"            # auto | ep | tp
    # estimated fraction of vocab touched per replica-step (sparsity alpha);
    # None -> derived from shape (min(1, local_tokens / vocab)).
    sparsity_alpha: Optional[float] = None
    # declared token skew for the *planner*: when set, the census estimates
    # expected-unique under folded Zipf(zipf_a) instead of the uniform upper
    # bound (core/sparsity.py::expected_unique_zipf). None = uniform bound.
    zipf_a: Optional[float] = None
    # per-table planner declarations (tuples of (table_name, value) pairs so
    # the frozen config stays hashable): a table named here gets its own
    # census skew / activated-fraction instead of the global zipf_a /
    # sparsity_alpha — two tables with different skews legitimately land on
    # different methods and capacities in one analyze() call.
    table_zipf: tuple = ()            # e.g. (("embed", 1.3),)
    table_alpha: tuple = ()           # e.g. (("enc_embed", 0.99),)
    # profiled wire-dtype selection: when True, the replan loop reads the
    # in-graph dense-gradient magnitude census (per-bucket |g|inf / rms
    # scalars riding the fused metrics psum, core/buckets.py) and keeps a
    # bucket's parameters at float32 on the wire when its peak-to-rms ratio
    # exceeds ``wire_outlier_ratio`` (outlier-prone grads lose too much to
    # bf16 rounding); everything else rides ``wire_dtype``.
    wire_dtype_auto: bool = False
    wire_outlier_ratio: float = 64.0
    # fused bucket-apply (optim/optimizer.py update_fused): when the bucketed
    # exchange is active, keep adamw/momentum state as flat per-bucket f32
    # buffers and apply the update straight from the post-psum wire buffer —
    # no unflatten -> per-param update -> reflatten round trip. Bit-identical
    # to the per-param path at f32; eligibility also needs zero_stage 0 and
    # opau (core/buckets.py fused_apply_eligible).
    fused_apply: bool = True
    # roofline-guided measured autotune of the Pallas embed_gather /
    # embed_scatter_add block sizes (kernels/autotune.py): a small sweep per
    # (table shape, dtype, backend) cached on disk; False = fixed full-row
    # blocks. Tile choice never changes the math, only the schedule.
    kernel_autotune: bool = False
    # per-host heartbeat scalars riding the fused metrics psum: each data
    # slice contributes a host-stamped timing value decoded host-side for
    # straggler *attribution* (runtime/monitor.py names the slow process
    # instead of dropping the last slice by convention). Adds one batch
    # entry ("_heartbeat") and D scalar metrics; off by default so
    # non-Trainer callers keep their input pytrees.
    heartbeat: bool = False
    # bounded-staleness sparse fallback (the DeepSpark-style degraded mode,
    # applied per-table through the plan): sparse tables flipped to
    # ``stale`` apply s-step-old exchanged gradients through a staleness
    # buffer in the train state while dense buckets stay synchronous.
    # 0 disables the machinery entirely (no buffer in the state); >0 bounds
    # the age any applied sparse gradient may reach (asserted in-graph via
    # the ``staleness_violation`` metric).
    max_staleness: int = 0
    # post-build debug gate (analysis/contract.py): after every step
    # compile — including replans and remeshes — diff the compiled HLO's
    # collectives against the plan's exchange contract and raise
    # ContractViolation on mismatch. Costs one as_text() per build; off by
    # default.
    verify_contract: bool = False


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            heads: int = 4, kv_heads: int = 2, d_ff: int = 128,
            vocab: int = 512, experts: int = 4, head_dim: int = 16) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=layers, d_model=d_model, d_ff=d_ff,
        vocab_size=vocab, head_dim=head_dim,
    )
    if cfg.n_heads:
        kw.update(n_heads=heads, n_kv_heads=min(kv_heads, heads))
    else:
        kw.update(n_heads=0, n_kv_heads=0)
    if cfg.n_experts:
        kw.update(n_experts=min(experts, cfg.n_experts),
                  experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.ssm_state:
        kw.update(ssm_state=min(cfg.ssm_state, 8))
    if cfg.is_encdec:
        kw.update(enc_layers=layers)
    return replace(cfg, **kw)


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate registry lazily from the configs package
    if not _REGISTRY:
        from repro.configs import ALL_ARCHS  # noqa: F401 (side effect)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    if not _REGISTRY:
        from repro.configs import ALL_ARCHS  # noqa: F401
    return dict(_REGISTRY)
