"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,               # expert d_ff
    vocab_size=202048,
    head_dim=128,
    n_experts=128,
    experts_per_token=1,
    shared_expert=True,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
