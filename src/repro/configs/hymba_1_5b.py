"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676; hf]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,           # 1600 / 25
    ssm_state=16,
    conv_width=4,
    rope_theta=10000.0,
    source="arXiv:2411.13676; hf",
))
