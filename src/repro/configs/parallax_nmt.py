"""parallax-nmt — the paper's NMT (GNMT-style): 4-layer LSTMs of 1024 units,
bidirectional encoder, 1024-dim embeddings, WMT De-En vocab (~32k BPE per
side; paper Table 1: 94M dense / 75M sparse params).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="parallax-nmt",
    family="lstm",
    n_layers=4,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=1024,
    vocab_size=36548,       # WMT14 de-en shared BPE-ish
    head_dim=0,
    is_encdec=True,
    enc_layers=4,
    source="paper §7.1 / GNMT arXiv:1609.08144",
))
