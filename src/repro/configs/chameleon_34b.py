"""chameleon-34b [vlm] — early-fusion VQ image tokens [arXiv:2405.09818; unverified].

Early fusion means image patches are VQ-quantized into the shared vocab; the
VQ tokenizer is the modality frontend and is a STUB here — ``input_specs()``
provides token ids drawn from the unified text+image vocabulary.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    frontend_stub=True,
    rope_theta=10000.0,
    source="arXiv:2405.09818; unverified",
))
