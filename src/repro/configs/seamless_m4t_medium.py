"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

The speech frontend (fbank + conformer adaptor) is a STUB: ``input_specs()``
provides precomputed frame embeddings of shape (batch, frames, d_model) for
the encoder; the decoder consumes text token ids from the 256206 vocab.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,            # decoder layers
    enc_layers=12,          # encoder layers
    is_encdec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,          # MHA
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,            # 1024 / 16
    frontend_stub=True,
    source="arXiv:2308.11596; hf",
))
