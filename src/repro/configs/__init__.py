"""Architecture registry. Importing this package registers every config."""
from repro.configs.base import (
    ModelConfig, ShapeConfig, RunConfig, SHAPES,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    get_config, all_configs, reduced, register,
)

# assigned architectures (10) — import for registration side effect
from repro.configs import phi3_medium_14b      # noqa: F401
from repro.configs import stablelm_12b         # noqa: F401
from repro.configs import command_r_35b        # noqa: F401
from repro.configs import mistral_large_123b   # noqa: F401
from repro.configs import llama4_maverick_400b # noqa: F401
from repro.configs import grok_1_314b          # noqa: F401
from repro.configs import chameleon_34b        # noqa: F401
from repro.configs import rwkv6_7b             # noqa: F401
from repro.configs import hymba_1_5b           # noqa: F401
from repro.configs import seamless_m4t_medium  # noqa: F401
# paper's own models
from repro.configs import parallax_lm          # noqa: F401
from repro.configs import parallax_nmt         # noqa: F401

ALL_ARCHS = [
    "phi3-medium-14b",
    "stablelm-12b",
    "command-r-35b",
    "mistral-large-123b",
    "llama4-maverick-400b-a17b",
    "grok-1-314b",
    "chameleon-34b",
    "rwkv6-7b",
    "hymba-1.5b",
    "seamless-m4t-medium",
]

PAPER_ARCHS = ["parallax-lm", "parallax-nmt"]


def shapes_for(arch: str) -> list[str]:
    """The shape cells that apply to an arch (skips noted in DESIGN.md)."""
    cfg = get_config(arch)
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return names
