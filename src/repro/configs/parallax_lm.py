"""parallax-lm — the paper's own LM (Jozefowicz et al. BIGLSTM family):
1-layer LSTM of 2048 units projected to a 512-dim embedding, 800K vocab
(One Billion Word). The paper's canonical *sparse* model (Table 1: 9M dense /
814M sparse params). Used for the Table-1/4 reproductions.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="parallax-lm",
    family="lstm",
    n_layers=1,
    d_model=512,            # embedding/projection dim
    n_heads=0,
    n_kv_heads=0,
    d_ff=2048,              # LSTM hidden units
    vocab_size=800000,
    head_dim=0,
    source="paper §7.1 / arXiv:1602.02410",
))
