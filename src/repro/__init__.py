"""repro — Parallax (sparsity-aware hybrid-communication data-parallel
training) reproduced as a TPU-native JAX framework. See DESIGN.md."""

__version__ = "1.0.0"

from repro.configs import (  # noqa: F401
    ModelConfig, ShapeConfig, RunConfig, SHAPES, ALL_ARCHS, PAPER_ARCHS,
    get_config, all_configs, reduced, shapes_for,
)
from repro.core import (  # noqa: F401
    Runtime, Plan, analyze, get_runner,
)
from repro.data import shard, SyntheticLM  # noqa: F401
