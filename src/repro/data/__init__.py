from repro.data.pipeline import (
    SyntheticLM, Dataset, shard, make_batch_specs,
)
