"""Data pipeline with the paper's ``shard()`` API (Table 2).

``shard(ds)`` splits a dataset into disjoint per-replica streams — here by
deterministic index striding, so (a) every replica sees a disjoint subset,
(b) the union over replicas equals the single-device stream (the correctness
precondition for data-parallel ≡ single-device), and (c) training can resume
mid-epoch from a step counter alone (fault tolerance: no iterator state in
checkpoints).

Synthetic corpora draw tokens from a Zipf-like distribution so embedding-row
sparsity (α) behaves like natural text rather than uniform noise.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import numpy as np


@dataclass
class Dataset:
    """A deterministic, index-addressable batch source."""
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    replica_id: int = 0
    num_replicas: int = 1
    zipf_a: float = 1.3
    is_encdec: bool = False
    frames_dim: int = 0
    frames_len: int = 0
    # per-stream skew: the encoder-side src_tokens stream can carry its own
    # distribution (None = same as zipf_a; 0 = uniform over the vocab, i.e.
    # a near-dense table) — the two-table per-parameter planning scenario
    src_zipf_a: Optional[float] = None
    # workload shift: the first ``burst_steps`` batches draw tokens at
    # ``burst_zipf_a`` (0 = uniform) before settling to zipf_a — a sustained
    # high-unique burst that overflows a capped dedupe buffer and exercises
    # the overflow-driven capacity-growth replan
    burst_steps: int = 0
    burst_zipf_a: float = 0.0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_replicas == 0
        return self.global_batch // self.num_replicas

    def _rng(self, step: int) -> np.random.Generator:
        # step-addressed GLOBAL stream: every replica generates the same
        # global batch and slices its disjoint rows, so the union over
        # replicas is exactly the single-device stream (paper §3.1) and
        # resume needs only the step counter.
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def _tokens(self, rng, shape, a: Optional[float] = None) -> np.ndarray:
        a = self.zipf_a if a is None else a
        if a <= 1.0:
            # a <= 1 has no proper Zipf normalization: uniform ids
            return rng.integers(0, self.vocab, size=shape, dtype=np.int64) \
                .astype(np.int32)
        # bounded Zipf: rejection-free via truncated zipf ranks
        ranks = rng.zipf(a, size=shape)
        return ((ranks - 1) % self.vocab).astype(np.int32)

    def _step_a(self, step: int) -> Optional[float]:
        if self.burst_steps and step < self.burst_steps:
            return self.burst_zipf_a
        return None

    def batch(self, step: int) -> dict:
        rng = self._rng(step)
        b, s = self.global_batch, self.seq_len
        toks = self._tokens(rng, (b, s + 1), self._step_a(step))
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.is_encdec and self.frames_dim:
            out["frames"] = rng.standard_normal(
                (b, self.frames_len, self.frames_dim)).astype(np.float32) * 0.02
        elif self.is_encdec:
            out["src_tokens"] = self._tokens(rng, (b, s), self.src_zipf_a)
        if self.num_replicas > 1:
            sl = slice(self.replica_id, None, self.num_replicas)
            out = {k: v[sl] for k, v in out.items()}
        return out

    def unique_counts(self, steps: int = 8, start: int = 0) -> list:
        """Empirical unique token ids per (per-replica) batch — the ground
        truth the census estimators and the runtime profiler are pinned
        against (tests/test_replan.py)."""
        return [int(np.unique(self.batch(s)["tokens"]).size)
                for s in range(start, start + steps)]

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def SyntheticLM(vocab: int, seq_len: int, global_batch: int, seed: int = 0,
                **kw) -> Dataset:
    return Dataset(vocab=vocab, seq_len=seq_len, global_batch=global_batch,
                   seed=seed, **kw)


def shard(ds: Dataset, replica_id: int = 0, num_replicas: int = 1) -> Dataset:
    """The paper's shard() API: disjoint per-replica split."""
    return dataclasses.replace(ds, replica_id=replica_id,
                               num_replicas=num_replicas)


def make_batch_specs(model, shape_cfg) -> dict:
    """ShapeDtypeStructs for a training batch (mirrors Model.input_specs)."""
    return model.input_specs(shape_cfg)
