"""Fault-tolerant sharded checkpointing.

Layout (one directory per step, step_%08d names):
    ckpt_dir/
      step_00000100.tmp/        # written first
        manifest.json           # tree structure, shapes, dtypes, shard map
        shard_<host>_<i>.npz    # one file per (host, leaf-group)
      step_00000100/            # atomic rename commits the checkpoint

Guarantees:
  * atomicity — readers only ever see fully-written checkpoints (tmp dir is
    renamed after fsync of the manifest; a crash mid-write leaves only .tmp).
  * elasticity — restore reshards to ANY mesh: arrays are saved as full
    logical tensors per leaf (gathered per host), so a 16x16 checkpoint
    restores onto 2x16x16 or a single device (tests/test_checkpoint.py).
  * async — AsyncCheckpointer snapshots device arrays to host then writes in
    a background thread, keeping the train loop running (the straggler /
    failure story needs frequent checkpoints to be cheap).

For multi-host deployment the same format shards by process index; in this
single-process repro host == process 0 holds everything.
"""
from __future__ import annotations

import json
import logging
import os
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import ml_dtypes  # numpy extension dtypes (bfloat16 etc.)
import numpy as np

from repro.utils.tree import named_leaves

log = logging.getLogger("repro.ckpt")

# np.savez cannot store ml_dtypes (bfloat16 -> void); store a bit-view and
# record the logical dtype in the manifest.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _tree_paths(tree) -> list[str]:
    return [n for n, _ in named_leaves(tree)]


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    extra: Optional[dict] = None) -> str:
    """Write state atomically; returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = named_leaves(state)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    arrays = {}
    for i, (path, leaf) in enumerate(leaves):
        if leaf is None:
            manifest["leaves"].append({"path": path, "none": True})
            continue
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if logical_dtype in _VIEW_AS:
            arr = arr.view(_VIEW_AS[logical_dtype])
        key = f"a{i}"
        arrays[key] = arr
        manifest["leaves"].append({
            "path": path, "key": key, "shape": list(arr.shape),
            "dtype": logical_dtype, "none": False,
        })
    np.savez(os.path.join(tmp, "shard_0_0.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):          # idempotent re-save of the same step
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


_STEP_DIR = re.compile(r"^step_(\d{8,})$")   # the step_%08d writer's names


def _committed_steps(ckpt_dir: str) -> list[int]:
    """Step numbers of committed checkpoints under ``ckpt_dir``, ignoring
    anything this writer could not have produced: stray files users drop
    next to checkpoints (logs, notes, 'latest' symlinks), in-flight
    ``.tmp`` dirs, and unpadded ``step_7``-style names (the read/delete
    paths open ``step_%08d``, so counting those would turn a stray into a
    crash or a mis-aimed GC) — all used to crash the int() parse of the
    whole directory."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_DIR.match(d)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, state_like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``state_like``, resharding to
    ``shardings`` (elastic restore: any mesh, any device count)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_0_0.npz"))

    by_path = {l["path"]: l for l in manifest["leaves"]}
    flat_sh = named_leaves(shardings) if shardings is not None else None
    sh_by_path = dict(flat_sh) if flat_sh else {}

    def restore_leaf(path, like):
        ent = by_path.get(path)
        if ent is None or ent.get("none"):
            return like
        arr = data[ent["key"]]
        if ent["dtype"] in _VIEW_AS:
            arr = arr.view(getattr(ml_dtypes, ent["dtype"]))
        sh = sh_by_path.get(path)
        if sh is not None:
            return jax.device_put(arr, sh)
        return jax.device_put(arr)

    from repro.utils.tree import tree_map_with_path_names
    state = tree_map_with_path_names(restore_leaf, state_like)
    return state, manifest["step"], manifest.get("extra", {})


def gc_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    for s in _committed_steps(ckpt_dir)[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host + background write; at most one write in flight.

    Transient background-write failures (a filesystem hiccup, a racing GC)
    are retried up to ``retries`` times with exponential backoff
    (``backoff * 2**attempt`` seconds) before the failure is surfaced —
    previously a failed write silently waited for the next periodic save,
    widening the restore gap by up to ``ckpt_every`` steps. The cumulative
    retry count is ``total_retries`` (surfaced as ``stats ckpt_retries``
    through the monitor), so a flaky checkpoint path is visible even when
    every write eventually lands."""

    def __init__(self, ckpt_dir: str, keep: int = 3, retries: int = 3,
                 backoff: float = 0.05):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.retries = retries
        self.backoff = backoff
        self.total_retries = 0
        self._thread: Optional[threading.Thread] = None
        self.last_committed: Optional[int] = None
        self._error: Optional[BaseException] = None

    @property
    def error(self) -> Optional[BaseException]:
        """The last background-write failure, without consuming it — lets
        the monitor surface a failing checkpoint path in the per-step stats
        instead of only on the next ``wait()`` (which may be ckpt_every
        steps after the bytes stopped reaching disk)."""
        return self._error

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_sync(self, step: int, state: Any,
                  extra: Optional[dict] = None) -> None:
        """Synchronous commit on the caller thread — the pre-remesh safety
        checkpoint: wait out any in-flight write, write + GC, record the
        commit. Same retention protocol as the async path, one home.

        A *stale* background failure is logged and discarded rather than
        re-raised: it must not block the fresh commit this call exists to
        make (the caller wants a checkpoint of the state it holds *now*;
        only a failure of that fresh write propagates)."""
        try:
            self.wait()
        except Exception:
            log.exception("discarding stale async checkpoint failure "
                          "before synchronous save of step %d", step)
        save_checkpoint(self.ckpt_dir, step, state, extra)
        gc_checkpoints(self.ckpt_dir, self.keep)
        self.last_committed = step

    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        self.wait()
        # snapshot on the caller thread (device -> host) so training can
        # overwrite donated buffers immediately afterwards
        host_state = jax.tree.map(
            lambda a: None if a is None else np.asarray(jax.device_get(a)),
            state)

        def work():
            for attempt in range(self.retries + 1):
                try:
                    save_checkpoint(self.ckpt_dir, step, host_state, extra)
                    gc_checkpoints(self.ckpt_dir, self.keep)
                    self.last_committed = step
                    return
                except BaseException as e:
                    if attempt >= self.retries:
                        self._error = e   # surfaced on next wait()
                        return
                    self.total_retries += 1
                    log.warning(
                        "background checkpoint write of step %d failed "
                        "(%s: %s); retry %d/%d", step, type(e).__name__, e,
                        attempt + 1, self.retries)
                    time.sleep(self.backoff * (2 ** attempt))

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
