"""The one record both analysis passes report.

A finding is a structured diff entry, not a log line: ``kind`` names the
violated rule, ``where`` locates it (an HLO op name or ``path:line``),
``expected``/``actual`` carry the two sides of the diff, and ``plan_leaf``
ties a contract finding back to the plan element (bucket index, table
name) whose contract the op broke.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Finding:
    kind: str                 # rule id, e.g. "missing-collective"
    where: str = ""           # HLO op name or "path:line"
    expected: str = ""
    actual: str = ""
    plan_leaf: str = ""       # bucket index / table name / config field
    message: str = ""

    def to_dict(self) -> dict:
        return {"kind": self.kind, "where": self.where,
                "expected": self.expected, "actual": self.actual,
                "plan_leaf": self.plan_leaf, "message": self.message}

    def __str__(self) -> str:
        parts = [self.kind]
        if self.where:
            parts.append(f"at {self.where}")
        if self.plan_leaf:
            parts.append(f"[{self.plan_leaf}]")
        if self.expected or self.actual:
            parts.append(f"expected {self.expected!r} got {self.actual!r}")
        if self.message:
            parts.append(f"— {self.message}")
        return " ".join(parts)
