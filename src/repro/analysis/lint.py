"""AST lint: the SPMD hygiene rules the repo enforces piecemeal today.

Four rules, each reported as a :class:`~repro.analysis.findings.Finding`
with ``kind`` = the rule id and ``where`` = ``path:line``:

``jax-mesh-api``
    Version-dependent mesh/sharding/shard_map APIs must be reached
    through ``repro.compat``, never imported from ``jax`` directly —
    outside the ``compat`` package itself. Generalizes the regex gate in
    ``tests/test_compat.py`` (which only bans the spellings that differ
    across JAX versions) to the whole API family.

``unhashable-config-field``
    ``RunConfig`` instances key plan/compile caches, so every field must
    be hashable: annotations and defaults may not use list/dict/set.

``tap-fwd-not-identity``
    A ``custom_vjp`` whose primal is an identity tap (returns its inputs
    untouched — the bucket-exchange taps in ``core/buckets.py``) must
    keep its ``fwd`` bitwise-identity too: the fwd's primal output may
    only repackage parameter names, never cast or transform them, or the
    tapped and untapped steps stop being bit-identical.

``raw-collective``
    ``psum``/``psum_scatter`` are manual-region primitives; calls belong
    only to the modules that implement the manual exchange machinery
    (``MANUAL_COLLECTIVE_MODULES``). Everything else must express
    reductions through the planner so the contract checker can account
    for them.

The rules are AST-based on purpose: ``tests/test_compat.py`` regex-scans
raw file text (including strings and comments), so this module must
detect the forbidden spellings without ever containing them.
"""
from __future__ import annotations

import ast
import os

from repro.analysis.findings import Finding

# modules allowed to call raw psum/psum_scatter: the manual-region
# exchange machinery itself (runtime.py owns the region flag; these run
# inside it) plus the collective microbenchmark tool
MANUAL_COLLECTIVE_MODULES = (
    "src/repro/core/runtime.py",
    "src/repro/core/buckets.py",
    "src/repro/core/embedding.py",
    "src/repro/core/sp.py",
    "src/repro/core/xent.py",
    "src/repro/models/moe.py",
    "tools/profile_collectives.py",
)

# names that must come from repro.compat (assembled, never spelled as
# "jax.<name>" — see module docstring)
_MESH_NAMES = {"sharding", "make_mesh", "set_mesh", "shard_map"}
_JAX_SHARDING = "jax" + "." + "sharding"
_JAX_SHMAP = "jax" + "." + "experimental" + "." + "shard_map"
_COLLECTIVE_CALLS = {"psum", "psum_scatter"}


def _attr_chain(node: ast.AST) -> str:
    """Dotted name of an attribute chain rooted at a Name, else ''."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_compat(path: str) -> bool:
    norm = path.replace(os.sep, "/")
    return "/compat/" in norm or norm.endswith("/compat")


def _rel(path: str, root: str | None) -> str:
    if root:
        try:
            return os.path.relpath(path, root).replace(os.sep, "/")
        except ValueError:
            pass
    return path.replace(os.sep, "/")


# ---------------------------------------------------------------------------
# rule: jax-mesh-api
# ---------------------------------------------------------------------------

def _check_mesh_api(tree: ast.AST, path: str) -> list:
    findings = []

    def flag(node, what):
        findings.append(Finding(
            "jax-mesh-api", where=f"{path}:{node.lineno}",
            expected="import from repro.compat", actual=what,
            message="version-dependent mesh/sharding API outside compat"))

    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            if node.module in (_JAX_SHARDING, _JAX_SHMAP):
                flag(node, f"from {node.module} import ...")
            elif node.module == "jax" and any(
                    a.name in _MESH_NAMES for a in node.names):
                names = [a.name for a in node.names
                         if a.name in _MESH_NAMES]
                flag(node, f"from jax import {', '.join(names)}")
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name in (_JAX_SHARDING, _JAX_SHMAP):
                    flag(node, f"import {a.name}")
        elif isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            if chain.startswith(_JAX_SHARDING) or chain in (
                    _JAX_SHMAP,
                    "jax." + "make_mesh",
                    "jax." + "set_mesh",
                    "jax." + "shard_map"):
                flag(node, chain)
    # attribute chains nest (jax.sharding.X contains jax.sharding): one
    # finding per line is enough
    seen, out = set(), []
    for f in findings:
        if f.where not in seen:
            seen.add(f.where)
            out.append(f)
    return out


# ---------------------------------------------------------------------------
# rule: unhashable-config-field
# ---------------------------------------------------------------------------

_UNHASHABLE = {"list", "List", "dict", "Dict", "set", "Set"}


def _annotation_unhashable(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in _UNHASHABLE:
            return True
        if isinstance(sub, ast.Attribute) and sub.attr in _UNHASHABLE:
            return True
    return False


def _check_config_hashable(tree: ast.AST, path: str) -> list:
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.ClassDef) and node.name == "RunConfig"):
            continue
        for stmt in node.body:
            if not isinstance(stmt, ast.AnnAssign):
                continue
            fname = getattr(stmt.target, "id", "?")
            bad = _annotation_unhashable(stmt.annotation)
            if not bad and stmt.value is not None:
                bad = isinstance(stmt.value,
                                 (ast.List, ast.Dict, ast.Set, ast.ListComp,
                                  ast.DictComp, ast.SetComp))
            if bad:
                findings.append(Finding(
                    "unhashable-config-field",
                    where=f"{path}:{stmt.lineno}", plan_leaf=fname,
                    expected="hashable field type (tuple, not list/dict)",
                    actual=ast.unparse(stmt.annotation),
                    message="RunConfig keys plan/compile caches"))
    return findings


# ---------------------------------------------------------------------------
# rule: tap-fwd-not-identity
# ---------------------------------------------------------------------------

def _is_custom_vjp(dec: ast.AST) -> bool:
    chain = _attr_chain(dec)
    return chain.endswith("custom_vjp")


def _identity_return(fn: ast.FunctionDef) -> bool:
    """Does the function just return (a tuple of) its own parameters?"""
    params = {a.arg for a in fn.args.args}
    rets = [s for s in fn.body if isinstance(s, ast.Return)]
    if len(rets) != 1 or rets[0].value is None:
        return False

    def pure(node):
        if isinstance(node, ast.Name):
            return node.id in params
        if isinstance(node, ast.Tuple):
            return all(pure(e) for e in node.elts)
        return False

    return pure(rets[0].value)


def _primal_pure(node: ast.AST, params: set) -> bool:
    """Is an fwd's primal-output expression a pure repackaging of
    parameter names (no casts, ops, or calls)?"""
    if isinstance(node, ast.Name):
        return node.id in params
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_primal_pure(e, params) for e in node.elts)
    return False


def _check_tap_identity(tree: ast.AST, path: str) -> list:
    findings = []
    # collect every function def by name per enclosing scope walk; names
    # are unique enough within the factories that define taps
    fns = {n.name: n for n in ast.walk(tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    taps = {name for name, fn in fns.items()
            if any(_is_custom_vjp(d) for d in fn.decorator_list)
            and _identity_return(fn)}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "defvjp"):
            continue
        target = _attr_chain(node.func.value)
        if target not in taps or not node.args:
            continue
        fwd_name = node.args[0].id if isinstance(node.args[0], ast.Name) \
            else None
        fwd = fns.get(fwd_name)
        if fwd is None:
            continue
        params = {a.arg for a in fwd.args.args}
        for ret in [s for s in ast.walk(fwd) if isinstance(s, ast.Return)]:
            val = ret.value
            primal = val.elts[0] if isinstance(val, ast.Tuple) and val.elts \
                else val
            if primal is not None and not _primal_pure(primal, params):
                findings.append(Finding(
                    "tap-fwd-not-identity",
                    where=f"{path}:{ret.lineno}", plan_leaf=target,
                    expected="fwd returns the primal inputs untouched",
                    actual=ast.unparse(primal),
                    message="identity-tap fwd must keep bitwise-identity "
                            "residuals"))
    return findings


# ---------------------------------------------------------------------------
# rule: raw-collective
# ---------------------------------------------------------------------------

def _check_raw_collectives(tree: ast.AST, path: str, rel: str) -> list:
    if any(rel.endswith(m) for m in MANUAL_COLLECTIVE_MODULES):
        return []
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = None
        if isinstance(node.func, ast.Attribute):
            name = node.func.attr
        elif isinstance(node.func, ast.Name):
            name = node.func.id
        if name in _COLLECTIVE_CALLS:
            findings.append(Finding(
                "raw-collective", where=f"{path}:{node.lineno}",
                expected="collectives only inside the manual-region "
                         "machinery", actual=f"{name}(...)",
                message="raw collective outside MANUAL_COLLECTIVE_MODULES"))
    return findings


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def lint_file(path: str, root: str | None = None) -> list:
    """Run every rule over one file -> findings (empty = clean)."""
    rel = _rel(path, root)
    if _is_compat(rel):
        return []
    try:
        with open(path, encoding="utf-8") as f:
            tree = ast.parse(f.read(), filename=path)
    except SyntaxError as e:
        return [Finding("syntax-error", where=f"{path}:{e.lineno}",
                        actual=str(e.msg))]
    findings = []
    findings += _check_mesh_api(tree, rel)
    findings += _check_config_hashable(tree, rel)
    findings += _check_tap_identity(tree, rel)
    findings += _check_raw_collectives(tree, rel, rel)
    return findings


def lint_paths(paths, root: str | None = None) -> list:
    """Lint every ``.py`` under the given files/directories."""
    findings = []
    for p in paths:
        if os.path.isfile(p):
            findings += lint_file(p, root)
            continue
        for dirpath, _, names in os.walk(p):
            for name in sorted(names):
                if name.endswith(".py"):
                    findings += lint_file(os.path.join(dirpath, name), root)
    return findings


def lint_repo(root: str | None = None) -> list:
    """Lint the repo's source trees: ``src/``, ``benchmarks/``,
    ``tools/`` (tests keep their own gates)."""
    if root is None:
        root = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    paths = [os.path.join(root, d) for d in ("src", "benchmarks", "tools")]
    return lint_paths([p for p in paths if os.path.isdir(p)], root)
