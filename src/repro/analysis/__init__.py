"""Static analysis: plan-contract checking and SPMD hygiene lint.

Two passes over two artifacts:

  * ``contract`` — diff a compiled train step's HLO against the collective
    contract its :class:`~repro.core.plan.Plan` implies (bucket count and
    wire sizes, two-level psum structure, sparse row-buffer pushes, the
    overlap schedule, the single fused scalar psum).
  * ``lint`` — AST rules over the repo source: version-dependent JAX mesh
    APIs stay inside ``repro.compat``, config dataclasses stay hashable,
    custom_vjp identity taps stay bitwise-identity, raw collectives stay
    inside the manual-region machinery.

Both report :class:`~repro.analysis.findings.Finding` records; clean code
produces an empty list.
"""
from repro.analysis.findings import Finding
from repro.analysis.contract import (ContractViolation, check_contract,
                                     verify_step_contract)
from repro.analysis.lint import lint_file, lint_paths, lint_repo

__all__ = [
    "Finding", "ContractViolation", "check_contract",
    "verify_step_contract", "lint_file", "lint_paths", "lint_repo",
]
