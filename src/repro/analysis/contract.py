"""Diff a compiled step's collectives against its Plan's contract.

The planner (core/plan.py + core/buckets.py) decides how every gradient
moves; this pass verifies the compiled program actually implements that
decision. Expected side: ``Plan.exchange_contract()`` — per-bucket
(kind, element-count) sequences, the overlap mode, and each sparse
table's method/capacity. Observed side: ``utils/hlo.scheduled_events``
on the ENTRY schedule (position = execution order on scheduled modules)
plus module-wide ``analyze_hlo`` counts for what hides inside loop
bodies.

Matching is by ELEMENT COUNT, not bytes or dtype: the CPU dry-run
backend upcasts bf16 wires to f32 in the dumped HLO, but the counts
survive the upcast unchanged. Wire-dtype conformance is therefore a
separate opt-in check (``strict_dtype=True``, for backends that keep
the wire dtype).

What the checker knows (calibrated against the real lowering):

  * each ring bucket is ONE fused all-reduce of exactly ``sum(sizes)``
    elements — plus one pin element per gradient leaf when overlap is
    off (the data-dependence pin in ``_exchange_bucket``);
  * each two-level bucket is a consecutive reduce-scatter(E/L) →
    all-reduce(E/L) → all-gather(E) triple, E padded to the local
    replica count L;
  * heartbeat/census scalars ride exactly ONE small fused psum;
  * a gatherv table's exchange shows as row-buffer all-gathers
    (elements a multiple of the replica count, at least
    replicas x capacity) plus integer uid all-gathers;
  * with overlap on, the first bucket collective is scheduled BEFORE
    the last dot-bearing while loop; with overlap off the pin holds
    every bucket collective until the backward has drained.
"""
from __future__ import annotations

from repro.analysis.findings import Finding
from repro.utils import hlo as H

# HLO spelling of the jnp wire dtypes the planner hands out
_WIRE_HLO = {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
             "float64": "f64"}
_INT_DTYPES = {"s32", "u32", "s64", "u64"}

# entry all-reduces at or under this many elements are metric scalars,
# not gradient traffic (the fused census/heartbeat psum is tens of
# elements; the smallest real bucket is thousands)
SCALAR_MAX = 4096


class ContractViolation(AssertionError):
    """Raised by the verify gate when a compiled step breaks its plan."""

    def __init__(self, findings):
        self.findings = list(findings)
        lines = "\n  ".join(str(f) for f in self.findings)
        super().__init__(
            f"compiled step violates its plan contract "
            f"({len(self.findings)} finding(s)):\n  {lines}")


def _match_elems(pool: list, kind: str, elems: int):
    """Pop and return the first unclaimed event of ``kind`` moving exactly
    ``elems`` elements, or None."""
    for e in pool:
        if e["collective"] == kind and e["elems"] == elems:
            pool.remove(e)
            return e
    return None


def _check_buckets(plan, events: list, strict_dtype: bool) -> tuple:
    """Match per-bucket expected collectives against the ENTRY schedule.
    -> (findings, matched bucket-event positions, leftover pool)."""
    findings: list[Finding] = []
    bp = plan.bucket_plan
    contract = plan.exchange_contract()
    n_leaves = contract["n_leaves"]
    planned = bp.expected_collectives(n_leaves)
    flipped = bp.expected_collectives(n_leaves, overlap=not bp.overlap)
    pool = [e for e in events if e["collective"]]
    positions: list[int] = []
    for want, alt in zip(planned, flipped):
        leaf = f"bucket[{want['bucket']}]"
        hlo_dtype = _WIRE_HLO.get(want["dtype"], want["dtype"])
        for (kind, elems), (akind, aelems) in zip(want["collectives"],
                                                  alt["collectives"]):
            ev = _match_elems(pool, kind, elems)
            if ev is None and aelems != elems:
                ev = _match_elems(pool, kind, aelems)
                if ev is not None:
                    findings.append(Finding(
                        "schedule", where=ev["name"], plan_leaf=leaf,
                        expected=f"{kind} of {elems} elems "
                                 f"(overlap={bp.overlap})",
                        actual=f"{aelems} elems (pin for "
                               f"overlap={not bp.overlap})",
                        message="pin elements show the step was compiled "
                                "under the opposite overlap mode"))
            if ev is None:
                findings.append(Finding(
                    "missing-collective", plan_leaf=leaf,
                    expected=f"{kind} of {elems} elems ({want['dtype']})",
                    actual="no matching collective in ENTRY schedule"))
                continue
            positions.append(ev["pos"])
            if strict_dtype and ev["dtype"] != hlo_dtype:
                findings.append(Finding(
                    "wire-dtype", where=ev["name"], plan_leaf=leaf,
                    expected=hlo_dtype, actual=str(ev["dtype"]),
                    message="collective rides the wrong wire dtype"))
    return findings, positions, pool


def _check_sparse(plan, pool: list) -> list:
    """Presence of each gatherv table's row-buffer collectives; claims the
    matching all-gathers so they are not misread as dense traffic."""
    findings: list[Finding] = []
    bp = plan.bucket_plan
    replicas = bp.replicas if bp is not None else 1
    for name, t in plan.exchange_contract()["tables"].items():
        if t["method"] != "mpi_gatherv":
            continue
        cap = max(t["capacity"], 1)
        rows, uids = [], []
        for e in list(pool):
            if e["collective"] != "all-gather":
                continue
            if e["dtype"] in _INT_DTYPES:
                # uid buffer: (replicas, capacity[+1]) ids
                if e["elems"] % replicas == 0 and e["elems"] >= replicas:
                    uids.append(e)
                    pool.remove(e)
            elif (e["elems"] % replicas == 0
                  and e["elems"] >= replicas * cap):
                # row buffer: (replicas, capacity[+1], row width)
                rows.append(e)
                pool.remove(e)
        if not rows:
            findings.append(Finding(
                "missing-sparse-collective", plan_leaf=name,
                expected=f"row-buffer all-gather >= {replicas}x{cap} rows",
                actual="none in ENTRY schedule",
                message="gatherv table exchange not found"))
        if not uids:
            findings.append(Finding(
                "missing-sparse-collective", plan_leaf=name,
                expected="integer uid all-gather",
                actual="none in ENTRY schedule",
                message="gatherv uid exchange not found"))
    return findings


def _check_scalars(pool: list) -> list:
    """Exactly one small fused psum carries every metric scalar."""
    findings: list[Finding] = []
    small = [e for e in pool
             if e["collective"] == "all-reduce" and e["elems"] <= SCALAR_MAX]
    if not small:
        findings.append(Finding(
            "missing-collective", plan_leaf="metrics",
            expected="one fused scalar psum (<= "
                     f"{SCALAR_MAX} elems)", actual="none"))
    for e in small[1:]:
        findings.append(Finding(
            "unfused-scalars", where=e["name"],
            expected="one fused scalar psum",
            actual=f"extra {e['elems']}-elem all-reduce",
            message="metric scalars must ride a single fused psum"))
    for e in small:
        pool.remove(e)
    large = [e for e in pool if e["collective"] == "all-reduce"]
    for e in large:
        findings.append(Finding(
            "unexpected-collective", where=e["name"],
            expected="no all-reduce outside the bucket contract",
            actual=f"{e['elems']}-elem all-reduce ({e['dtype']})",
            message="gradient traffic outside the planned buckets"))
    return findings


def _check_schedule(plan, text: str, positions: list) -> list:
    """Overlap placement: first bucket collective vs last dot-bearing
    loop in the ENTRY schedule."""
    bp = plan.bucket_plan
    sched = H.dot_bearing_events(text)
    if not positions or sched["last_loop"] is None:
        return []  # nothing to order against (non-scanning model)
    first = min(positions)
    last = sched["last_loop"]
    # with one bucket the fused collective only becomes ready once every
    # gradient exists — after the whole backward — so overlap can place
    # nothing early; the before-the-last-loop guarantee needs >= 2 buckets
    if bp.overlap and len(bp.buckets) >= 2 and first > last:
        return [Finding(
            "schedule", plan_leaf="bucket[0]",
            expected="first bucket collective scheduled before the last "
                     "dot-bearing loop (overlap=True)",
            actual=f"first collective at pos {first}, last loop at {last}",
            message="exchange does not overlap the backward")]
    if not bp.overlap and first < last:
        return [Finding(
            "schedule", plan_leaf="bucket[0]",
            expected="every bucket collective after the last dot-bearing "
                     "loop (overlap=False pin)",
            actual=f"first collective at pos {first}, last loop at {last}",
            message="pinned exchange issued mid-backward")]
    return []


def _check_module_counts(plan, text: str) -> list:
    """Module-wide totals — catches gradient collectives hidden inside
    while bodies where the ENTRY schedule cannot see them."""
    findings: list[Finding] = []
    summary = H.analyze_hlo(text)
    observed = summary.collective_count.get("all-reduce", 0)
    bp = plan.bucket_plan
    if bp is not None:
        expected = len(bp.buckets) + 1  # one psum per bucket + scalar psum
        if observed > expected:
            findings.append(Finding(
                "collective-count", plan_leaf="dense",
                expected=f"{expected} all-reduces "
                         f"({len(bp.buckets)} buckets + 1 scalar psum)",
                actual=f"{observed:g} module-wide",
                message="more all-reduces than the bucket plan allows"))
    else:
        # unbucketed: at least one all-reduce per allreduce-method leaf
        # (XLA fuses nothing for us here; loop trip counts multiply)
        n_ar = plan.methods().get("allreduce", 0)
        if n_ar and sum(summary.collective_count.values()) == 0:
            findings.append(Finding(
                "missing-collective", plan_leaf="dense",
                expected=f">= 1 collective for {n_ar} allreduce leaves",
                actual="no collectives in module",
                message="unbucketed dense exchange absent"))
    return findings


def check_contract(plan, hlo_text: str, *,
                   strict_dtype: bool = False) -> list:
    """Diff the compiled step (``compiled.as_text()``) against ``plan``.

    Returns a list of :class:`Finding` — empty when the program
    implements the plan. ``strict_dtype`` additionally requires each
    bucket collective to ride the planned wire dtype in HLO (off by
    default: the CPU dry-run upcasts bf16 collectives to f32)."""
    findings: list[Finding] = []
    bp = plan.bucket_plan
    if bp is not None and H.is_scheduled(hlo_text):
        events = H.scheduled_events(hlo_text)
        bfinds, positions, pool = _check_buckets(plan, events, strict_dtype)
        findings += bfinds
        findings += _check_sparse(plan, pool)
        findings += _check_scalars(pool)
        findings += _check_schedule(plan, hlo_text, positions)
    findings += _check_module_counts(plan, hlo_text)
    return findings


def verify_step_contract(plan, hlo_text: str, *,
                         strict_dtype: bool = False) -> None:
    """The post-build debug gate (``RunConfig.verify_contract``): raise
    :class:`ContractViolation` when the compiled step's collectives do
    not implement the plan."""
    findings = check_contract(plan, hlo_text, strict_dtype=strict_dtype)
    if findings:
        raise ContractViolation(findings)
