"""Explicit sequence-parallel block collectives (§Perf iteration A).

GSPMD-auto emits all-reduce + all-gather pairs of *full* activations at the
TP block boundaries (and the CPU backend widens them to f32). This module
makes the Megatron-SP schedule explicit and wire-dtype-controlled:

  proj_in   all-gather the seq-sharded residual ONCE per block half (bf16),
            then local matmuls against every column-sharded weight;
            backward reduce-scatters d_x.
  proj_out  local matmul -> psum-scatter the partial outputs back to the
            seq-sharded residual; backward all-gathers d_out.

Per layer the wire carries exactly 4 (fwd) + 4 (bwd) + 4 (remat recompute)
seq-scattered bf16 activation units instead of ~10 full-size f32 units —
napkin: ≥3x on the dominant collective term. Weight grads ride one psum over
the replica axes at the wire dtype (OPSW), subsuming the XLA-inserted AR.

Implemented like core/embedding.py: one custom_vjp whose fwd/bwd are
non-differentiated shard_maps (exact manual transpose: AG^T = RS, RS^T = AG).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import Mesh, P, shard_map


@dataclass(frozen=True)
class SpCtx:
    mesh: Mesh
    batch_axes: tuple
    model_axis: str
    wire_dtype: Any
    n_out_sharded: tuple        # per-weight: True if out dim is model-sharded

    @property
    def m(self) -> int:
        return self.mesh.shape[self.model_axis]


def _wspec(ctx, sharded):
    return P(None, ctx.model_axis) if sharded else P(None, None)


# ---------------------------------------------------------------------------
# proj_in: AG(x over seq) once, then k local matmuls
# ---------------------------------------------------------------------------

def _in_fwd_local(x_loc, ws, ctx: SpCtx):
    xf = jax.lax.all_gather(x_loc.astype(ctx.wire_dtype), ctx.model_axis,
                            axis=1, tiled=True).astype(x_loc.dtype)
    ys = tuple(xf @ w for w in ws)
    return ys, xf


def _in_bwd_local(xf, ws, d_ys, ctx: SpCtx):
    # d_x: sum of partial products, reduce-scattered back to seq shards.
    # Outputs whose weight is NOT model-sharded are replicated: every shard
    # holds the full logical cotangent, so their d_x contribution must be
    # counted once (scaled by 1/m) across the psum_scatter.
    d_xf = None
    d_ws = []
    for w, d_y, sharded in zip(ws, d_ys, ctx.n_out_sharded):
        contrib = d_y @ w.T
        if not sharded and ctx.m > 1:
            contrib = contrib / ctx.m
        d_xf = contrib if d_xf is None else d_xf + contrib
        d_w = jnp.einsum("bsd,bsf->df", xf, d_y).astype(ctx.wire_dtype)
        if ctx.batch_axes:
            d_w = jax.lax.psum(d_w, ctx.batch_axes)   # dense grad exchange
        d_ws.append(d_w)
    d_x = jax.lax.psum_scatter(d_xf.astype(ctx.wire_dtype), ctx.model_axis,
                               scatter_dimension=1, tiled=True)
    return (d_x,) + tuple(d_ws)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _proj_in(ctx: SpCtx, x, *ws):
    return _proj_in_fwd(ctx, x, *ws)[0]


def _proj_in_fwd(ctx: SpCtx, x, *ws):
    ba = ctx.batch_axes or None
    in_specs = (P(ba, ctx.model_axis, None),) + tuple(
        _wspec(ctx, s) for s in ctx.n_out_sharded)
    out_specs = tuple(
        P(ba, None, ctx.model_axis if s else None)
        for s in ctx.n_out_sharded)
    fn = shard_map(
        lambda x_loc, *w: _in_fwd_local(x_loc, w, ctx)[0],
        mesh=ctx.mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False)
    return fn(x, *ws), (x, ws)


def _proj_in_bwd(ctx: SpCtx, res, d_ys):
    x, ws = res
    ba = ctx.batch_axes or None
    in_specs = (P(ba, ctx.model_axis, None),) + tuple(
        _wspec(ctx, s) for s in ctx.n_out_sharded) + tuple(
        P(ba, None, ctx.model_axis if s else None)
        for s in ctx.n_out_sharded)
    out_specs = (P(ba, ctx.model_axis, None),) + tuple(
        _wspec(ctx, s) for s in ctx.n_out_sharded)

    def body(x_loc, *rest):
        k = len(ws)
        w_loc, d_y_loc = rest[:k], rest[k:]
        xf = jax.lax.all_gather(x_loc.astype(ctx.wire_dtype), ctx.model_axis,
                                axis=1, tiled=True).astype(x_loc.dtype)
        outs = _in_bwd_local(xf, w_loc, d_y_loc, ctx)
        return tuple(o.astype(a.dtype) for o, a in
                     zip(outs, (x_loc,) + tuple(w_loc)))

    fn = shard_map(body, mesh=ctx.mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(x, *ws, *d_ys)


_proj_in.defvjp(_proj_in_fwd, _proj_in_bwd)


# ---------------------------------------------------------------------------
# proj_out: local matmul then psum-scatter to seq shards
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _proj_out(ctx: SpCtx, h, w):
    return _proj_out_fwd(ctx, h, w)[0]


def _proj_out_fwd(ctx: SpCtx, h, w):
    ba = ctx.batch_axes or None

    def body(h_loc, w_loc):
        partial_out = (h_loc @ w_loc).astype(ctx.wire_dtype)
        out = jax.lax.psum_scatter(partial_out, ctx.model_axis,
                                   scatter_dimension=1, tiled=True)
        return out.astype(h_loc.dtype)

    fn = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(ba, None, ctx.model_axis), P(ctx.model_axis, None)),
        out_specs=P(ba, ctx.model_axis, None), check_vma=False)
    return fn(h, w), (h, w)


def _proj_out_bwd(ctx: SpCtx, res, d_out):
    h, w = res
    ba = ctx.batch_axes or None

    def body(h_loc, w_loc, d_loc):
        d_full = jax.lax.all_gather(d_loc.astype(ctx.wire_dtype),
                                    ctx.model_axis, axis=1,
                                    tiled=True).astype(h_loc.dtype)
        d_h = d_full @ w_loc.T
        d_w = jnp.einsum("bsf,bsd->fd", h_loc, d_full).astype(ctx.wire_dtype)
        if ctx.batch_axes:
            d_w = jax.lax.psum(d_w, ctx.batch_axes)
        return d_h, d_w.astype(w_loc.dtype)

    fn = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(ba, None, ctx.model_axis), P(ctx.model_axis, None),
                  P(ba, ctx.model_axis, None)),
        out_specs=(P(ba, None, ctx.model_axis), P(ctx.model_axis, None)),
        check_vma=False)
    return fn(h, w, d_out)


_proj_out.defvjp(_proj_out_fwd, _proj_out_bwd)


# ---------------------------------------------------------------------------
# public API (global semantics)
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# local_proj: seq-local matmul + AG of the (small) output — for projections
# whose weights are replicated over the model axis (GQA KV). Trades a small
# output all-gather for the m-fold redundant full-sequence matmul.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _local_proj(ctx: SpCtx, x, *ws):
    return _local_proj_fwd(ctx, x, *ws)[0]


def _local_proj_fwd(ctx: SpCtx, x, *ws):
    ba = ctx.batch_axes or None

    def body(x_loc, *w):
        ys = tuple(
            jax.lax.all_gather((x_loc @ wi).astype(ctx.wire_dtype),
                               ctx.model_axis, axis=1,
                               tiled=True).astype(x_loc.dtype)
            for wi in w)
        return ys

    fn = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(ba, ctx.model_axis, None),) + (P(None, None),) * len(ws),
        out_specs=tuple(P(ba, None, None) for _ in ws), check_vma=False)
    return fn(x, *ws), (x, ws)


def _local_proj_bwd(ctx: SpCtx, res, d_ys):
    x, ws = res
    ba = ctx.batch_axes or None

    def body(x_loc, *rest):
        k = len(ws)
        w_loc, d_y = rest[:k], rest[k:]
        d_x = None
        d_ws = []
        for wi, d_yi in zip(w_loc, d_y):
            d_yloc = jax.lax.psum_scatter(
                d_yi.astype(ctx.wire_dtype), ctx.model_axis,
                scatter_dimension=1, tiled=True).astype(x_loc.dtype)
            contrib = d_yloc @ wi.T
            d_x = contrib if d_x is None else d_x + contrib
            d_w = jnp.einsum("bsd,bsf->df", x_loc, d_yloc)
            d_w = jax.lax.psum(d_w.astype(ctx.wire_dtype),
                               (ctx.model_axis,) + tuple(ctx.batch_axes))
            d_ws.append(d_w.astype(wi.dtype))
        return (d_x,) + tuple(d_ws)

    fn = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(ba, ctx.model_axis, None),) + (P(None, None),) * len(ws)
        + tuple(P(ba, None, None) for _ in ws),
        out_specs=(P(ba, ctx.model_axis, None),) + (P(None, None),) * len(ws),
        check_vma=False)
    return fn(x, *ws, *d_ys)


_local_proj.defvjp(_local_proj_fwd, _local_proj_bwd)


def local_proj(rt, x, ws: list) -> tuple:
    """Seq-local projection + output AG (replicated weights only)."""
    ctx = SpCtx(mesh=rt.mesh, batch_axes=rt.batch_axes, model_axis="model",
                wire_dtype=rt.wire_dtype,
                n_out_sharded=tuple(False for _ in ws))
    return _local_proj(ctx, x, *ws)


def kv_local_favorable(rt, cfg) -> bool:
    """Cost model: seq-local KV (+output AG) vs KV-on-gathered-x.

    saved compute/chip ≈ 4 passes · 2·T·D·KVdim·(m-1)/m / peak
    added wire/chip    ≈ 3 units · 2·T·KVdim·wire_bytes·(m-1)/m / link_bw
    """
    from repro.utils.roofline import HW
    m = rt.mesh.shape["model"]
    d, kvdim = cfg.d_model, cfg.kv_dim
    saved = 4 * 2 * d * kvdim * (m - 1) / m / HW.peak_flops
    added = 3 * 2 * kvdim * (m - 1) / m / HW.link_bw
    # SP-TP training lives near the collective roof: wire seconds are worth
    # ~2x compute seconds unless compute clearly dominates (hypothesis log,
    # §Perf iteration A2: confirmed on mistral/command-r, refuted on phi3
    # without the penalty).
    return saved > 2.0 * added


def sp_active(rt, x) -> bool:
    rc = rt.run_cfg
    if not getattr(rc, "explicit_sp", False) or rt.mesh is None:
        return False
    if "model" not in rt.mesh.axis_names:
        return False
    if "model" in (rt.batch_axes or ()):
        return False    # dp strategy: the model axis carries batch, no TP
    m = rt.mesh.shape["model"]
    return (m > 1 and x.ndim == 3 and x.shape[1] % m == 0
            and rt.shape_cfg.kind != "decode")


def proj_in(rt, x, ws: list, out_sharded: list) -> tuple:
    """x: (B,S,D) seq-sharded residual; ws: weights (D, F_i). One AG."""
    ctx = SpCtx(mesh=rt.mesh, batch_axes=rt.batch_axes, model_axis="model",
                wire_dtype=rt.wire_dtype, n_out_sharded=tuple(out_sharded))
    return _proj_in(ctx, x, *ws)


def proj_out(rt, h, w) -> jax.Array:
    """h: (B,S,F) col-sharded; w: (F, D) row-sharded. Matmul + RS."""
    ctx = SpCtx(mesh=rt.mesh, batch_axes=rt.batch_axes, model_axis="model",
                wire_dtype=rt.wire_dtype, n_out_sharded=(True,))
    return _proj_out(ctx, h, w)
