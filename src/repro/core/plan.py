"""The Parallax plan: logical-axis → mesh resolution and the per-parameter
communication plan (C1 hybrid communication, generalized per DESIGN.md §2).

Every parameter gets a ``ParamPlan`` naming its exchange *method*:

  allreduce    dense, replicated over data/pod (+TP over model where the
               logical axes say so); gradients ring-all-reduced by XLA.
               == paper's MPI/NCCL path, cost 2(N-1)b/N.
  fsdp         dense, additionally sharded over data (ZeRO-3); pull =
               all-gather before use, push = reduce-scatter.  == paper's PS
               path applied to a dense parameter, cost 2b.
  ps           sparse (embedding rows): row-sharded over model ("server
               shards"); pull = psum of deduped row-buffer (2αb), push =
               owner-local scatter-add + shard psum over data.  == paper's PS
               path for sparse parameters.
  mpi_gatherv  sparse baseline: all-gather of per-replica (ids, rows) +
               local densify, cost 2(N-1)αb.  == paper's AllGatherv path.

The method is chosen by core/cost_model.py from the Table-3 transfer model;
``RunConfig.comm_mode`` can force the paper's BASE (ps) / MPI (mpi) baselines.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.compat import Mesh, NamedSharding, P
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models.layers import ParamSpec


# ---------------------------------------------------------------------------
# logical axis rules
# ---------------------------------------------------------------------------

def default_rules(mesh: Optional[Mesh], shape_kind: str, batch: int,
                  dense_strategy: str = "tp") -> dict:
    """logical axis name -> mesh axes (tuple) or None."""
    if mesh is None:
        return {}
    names = mesh.axis_names
    has_pod = "pod" in names
    if dense_strategy == "dp" and shape_kind != "decode":
        # §Perf iteration B: the model axis joins data parallelism; params
        # fully sharded (ZeRO-3) and gathered per layer. No TP, no SP.
        batch_axes = ("pod", "data", "model") if has_pod else ("data", "model")
        ba = list(batch_axes)
        while ba and batch % math.prod(mesh.shape[a] for a in ba) != 0:
            ba.pop(0)
        rules = {k: None for k in (
            "seq_sp", "vocab", "embed", "q_heads", "kv_heads", "heads_hd",
            "mlp", "experts", "moe_mlp", "layers", "state", "lstm_hidden",
            "conv")}
        rules["batch"] = tuple(ba) if ba else None
        rules["kv_seq"] = ("model",)
        return rules
    batch_axes = ("pod", "data") if has_pod else ("data",)
    # batch must divide the data(+pod) axes; drop axes until it does
    ba = list(batch_axes)
    while ba and batch % math.prod(mesh.shape[a] for a in ba) != 0:
        ba.pop(0)
    rules = {
        "batch": tuple(ba) if ba else None,
        "seq_sp": ("model",),          # sequence-parallel residual stream
        "vocab": ("model",),           # PS server shards (row-sharded)
        "embed": None,
        "q_heads": ("model",),
        "kv_heads": None,              # replicated: TP > n_kv  (DESIGN.md)
        "heads_hd": ("model",),        # flattened q_heads*head_dim rows
        "mlp": ("model",),
        "experts": ("model",),
        "moe_mlp": None,               # expert d_ff when experts are sharded
        "kv_seq": ("model",),          # decode cache sequence dim
        "layers": None,
        "state": None,
        "lstm_hidden": ("model",),
        "conv": None,
    }
    if shape_kind == "decode" and (not ba):
        # tiny-batch decode (long_500k): spread the cache over every axis
        rules["kv_seq"] = tuple(a for a in ("pod", "data", "model") if a in names)
    return rules


@dataclass
class MeshRules:
    mesh: Optional[Mesh]
    rules: dict

    def axis_size(self, logical: str) -> int:
        if self.mesh is None:
            return 1
        ax = self.rules.get(logical)
        if ax is None:
            return 1
        return math.prod(self.mesh.shape[a] for a in ax)

    def pspec(self, axes: tuple, shape: Optional[tuple] = None) -> P:
        """Resolve logical axes to a PartitionSpec with divisibility checks."""
        if self.mesh is None:
            return P()
        used: set[str] = set()
        out = []
        for i, name in enumerate(axes):
            entry = None
            if name is not None:
                cand = self.rules.get(name)
                if cand:
                    cand = tuple(a for a in cand if a not in used)
                    if cand:
                        size = math.prod(self.mesh.shape[a] for a in cand)
                        if shape is None or shape[i] % size == 0:
                            # single axes stay unwrapped: old JAX compares
                            # P(('model',)) != P('model') (no canonicalization)
                            entry = cand[0] if len(cand) == 1 else cand
                            used.update(cand)
            out.append(entry)
        return P(*out)

    def sharding(self, axes: tuple, shape: Optional[tuple] = None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(axes, shape))


# ---------------------------------------------------------------------------
# per-parameter plan
# ---------------------------------------------------------------------------

def plan_leaves(tree: Any) -> list:
    """Flatten a ParamPlan tree in leaf order (the order bucket indices and
    gradient leaves share)."""
    return jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, ParamPlan))


@dataclass
class ParamPlan:
    name: str
    method: str                        # allreduce | fsdp | ps | mpi_gatherv
    pspec: P
    opt_pspec: P                       # optimizer-state sharding (ZeRO-1/3)
    wire_dtype: Any
    sparse: bool
    bytes: int
    capacity: int = 0                  # sparse tables: dedupe-buffer rows
    stale: bool = False                # bounded-staleness push mode: this
                                       # table applies s-step-old exchanged
                                       # gradients (jitter fallback)
    est_cost: dict = field(default_factory=dict)


@dataclass
class Plan:
    model_cfg: ModelConfig
    run_cfg: RunConfig
    shape_cfg: ShapeConfig
    mesh: Optional[Mesh]
    rules: MeshRules
    params: Any = None                 # tree of ParamPlan (aligned with specs)
    alpha: float = 1.0                 # estimated sparse-access ratio
    capacity: int = 0                  # binding sparse-exchange row capacity
    zero_stage: int = 0
    embed_method: str = "ps"           # the "embed" table's exchange method
    bucket_plan: Any = None            # core/buckets.py BucketPlan (None =
                                       # per-tensor dense collectives)
    fused_apply: bool = False          # optimizer reads the flat bucket
                                       # buffers directly (fused m/v/EMA
                                       # layout; optim/optimizer.py)
    table_tiles: dict = field(default_factory=dict)  # name -> (gather_block,
                                       # scatter_block) Pallas lane tiles from
                                       # the kernel autotune cache (0 = the
                                       # fixed full-row block)
    # ---- per-parameter planning (one record per sparse table) ----
    table_methods: dict = field(default_factory=dict)   # name -> method
    table_capacity: dict = field(default_factory=dict)  # name -> buffer rows
    table_wire: dict = field(default_factory=dict)      # name -> jnp dtype
    table_alpha: dict = field(default_factory=dict)     # name -> priced α
                                       # (the activated fraction the Table-3
                                       # argmin ran at — recorded so a
                                       # checkpoint manifest can reproduce
                                       # the method choice on restore)
    grown_tables: tuple = ()           # tables whose capacity the overflow
                                       # rule grew in this plan's census
    stale_tables: tuple = ()           # tables running the bounded-staleness
                                       # push (jitter fallback; empty = all
                                       # synchronous)
    table_serve: dict = field(default_factory=dict)  # decode-shape plans
                                       # only: name -> serve-mesh pricing
                                       # (cost_model.serve_table_pricing —
                                       # pull bytes/seconds per decode step
                                       # and per-token exchange seconds)

    # ---- totals for Table-1 style census ----
    def census(self) -> dict:
        dense = sparse = 0
        for p in jax.tree.leaves(self.params, is_leaf=lambda x: isinstance(x, ParamPlan)):
            if p.sparse:
                sparse += p.bytes
            else:
                dense += p.bytes
        return {"dense_bytes": dense, "sparse_bytes": sparse, "alpha": self.alpha}

    def methods(self) -> dict:
        out: dict[str, int] = {}
        for p in jax.tree.leaves(self.params, is_leaf=lambda x: isinstance(x, ParamPlan)):
            out[p.method] = out.get(p.method, 0) + 1
        return out

    def tables(self) -> dict:
        """Per-sparse-table plan summary (JSON-friendly) — one entry per
        table: its exchange method, buffer capacity, wire dtype, and the α
        the cost model priced it at. The summary round-trips through the
        checkpoint manifest (``Trainer`` saves it in ``extra['plan']``) and
        is enough to re-derive the same plan on restore: capacities and
        grown flags override the census, α reproduces the method argmin."""
        return {t: {
            "method": m,
            "capacity": self.table_capacity.get(t, self.capacity),
            "wire_dtype": jnp.dtype(self.table_wire[t]).name
            if t in self.table_wire else None,
            "grown": t in self.grown_tables,
            "alpha": self.table_alpha.get(t),
            "stale": t in self.stale_tables,
            # decode-shape plans carry serve-mesh pricing (per-step pull
            # bytes/seconds + per-token exchange seconds at the decode batch)
            "serve": self.table_serve.get(t),
        } for t, m in self.table_methods.items()}

    def exchange_contract(self) -> dict:
        """Everything ``analysis/contract.py`` needs to derive the expected
        collective set of a compiled step from this plan alone: the
        per-bucket dense collectives (kind + element count, in issue
        order), the overlap mode, and each sparse table's method/capacity/
        wire so the checker knows which row-buffer collectives to expect.
        ``n_leaves`` is the gradient leaf count — the overlap=False pin
        rides one element per leaf on every bucket psum."""
        leaves = jax.tree.leaves(
            self.params, is_leaf=lambda x: isinstance(x, ParamPlan))
        n_leaves = len(leaves)
        bp = self.bucket_plan
        return {
            "n_leaves": n_leaves,
            "methods": self.methods(),
            "bucketed": bp is not None,
            "overlap": bool(bp.overlap) if bp is not None else False,
            "replicas": bp.replicas if bp is not None else 1,
            "buckets": (bp.expected_collectives(n_leaves)
                        if bp is not None else []),
            "n_sparse_push": bp.n_sparse_push if bp is not None else 0,
            "tables": {t: {
                "method": m,
                "capacity": self.table_capacity.get(t, self.capacity),
                "wire_dtype": jnp.dtype(self.table_wire[t]).name
                if t in self.table_wire else None,
                "stale": t in self.stale_tables,
            } for t, m in self.table_methods.items()},
        }


def _drifted(old_cap: int, new_cap: int, factor: float) -> bool:
    hi = max(old_cap, new_cap)
    lo = max(min(old_cap, new_cap), 1)
    return old_cap != new_cap and hi / lo >= factor


def plan_diff(old: Plan, new: Plan, capacity_drift: float = 1.5) -> dict:
    """Structural diff between two Plans for the replan loop.

    ``changed`` is True when any parameter's exchange method flips, any
    pspec/opt_pspec differs (state must reshard), any parameter's wire dtype
    moves (the jitted step must re-trace), any table's capacity drifts by
    more than ``capacity_drift``x in either direction, the overflow rule
    grew a table's capacity (growth is never deadbanded — sustained overflow
    means rows are being silently zeroed under the live plan), or the plans
    price *different world sizes* (``mesh_changed`` — the elastic remesh
    path: the cost model's α·messages term depends on N, so a plan diffed
    across meshes always warrants a rebuild even if every method held).
    """
    leaf = lambda x: isinstance(x, ParamPlan)
    olds = {p.name: p for p in jax.tree.leaves(old.params, is_leaf=leaf)}
    flips, wire_flips, pspecs_changed = [], [], False
    for p in jax.tree.leaves(new.params, is_leaf=leaf):
        q = olds.get(p.name)
        if q is None:
            pspecs_changed = True
            continue
        if p.method != q.method:
            flips.append((p.name, q.method, p.method))
        if jnp.dtype(p.wire_dtype) != jnp.dtype(q.wire_dtype):
            wire_flips.append((p.name, jnp.dtype(q.wire_dtype).name,
                               jnp.dtype(p.wire_dtype).name))
        if tuple(p.pspec) != tuple(q.pspec) or \
                tuple(p.opt_pspec) != tuple(q.opt_pspec):
            pspecs_changed = True
    capacity_drifted = _drifted(old.capacity, new.capacity, capacity_drift)
    for t, cap in new.table_capacity.items():
        if t in old.table_capacity:
            capacity_drifted |= _drifted(old.table_capacity[t], cap,
                                         capacity_drift)
    capacity_grown = any(
        new.table_capacity.get(t, 0) > old.table_capacity.get(t, 0)
        for t in new.grown_tables)
    mesh_shape = lambda p: dict(p.mesh.shape) if p.mesh is not None else None
    mesh_changed = mesh_shape(old) != mesh_shape(new)
    # sync <-> stale transitions (the jitter fallback): the train step's
    # update rule for the flipped table changes, so the jit must re-trace
    stale_flips = [
        (t, t in old.stale_tables, t in new.stale_tables)
        for t in sorted(set(old.stale_tables) ^ set(new.stale_tables))]
    return {
        "changed": bool(flips) or bool(wire_flips) or pspecs_changed
                   or capacity_drifted or capacity_grown or mesh_changed
                   or bool(stale_flips),
        "mesh_changed": mesh_changed,
        "mesh": (mesh_shape(old), mesh_shape(new)),
        "rebuilt": False,             # set by the caller that acts on the diff
        "flips": flips,
        "wire_flips": wire_flips,
        "stale_flips": stale_flips,
        "pspecs_changed": pspecs_changed,
        "capacity_drifted": capacity_drifted,
        "capacity_grown": capacity_grown,
        "capacity": (old.capacity, new.capacity),
        "table_capacity": (dict(old.table_capacity),
                           dict(new.table_capacity)),
        "table_methods": (dict(old.table_methods), dict(new.table_methods)),
        "alpha": (old.alpha, new.alpha),
        "embed_method": (old.embed_method, new.embed_method),
        "buckets": (len(old.bucket_plan.buckets) if old.bucket_plan else 0,
                    len(new.bucket_plan.buckets) if new.bucket_plan else 0),
    }


def _fsdp_axes(mesh: Mesh, dense_strategy: str = "tp") -> tuple:
    axes = ("data", "model") if dense_strategy == "dp" else ("data",)
    return tuple(a for a in axes if a in mesh.axis_names)


def add_fsdp(pspec: P, shape: tuple, mesh: Mesh,
             dense_strategy: str = "tp") -> P:
    """ZeRO-3: additionally shard the largest free dim over the data axis."""
    fax = _fsdp_axes(mesh, dense_strategy)
    if not fax:
        return pspec
    size = math.prod(mesh.shape[a] for a in fax)
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    used = {a for e in entries if e for a in ((e,) if isinstance(e, str) else e)}
    if any(a in used for a in fax):
        return pspec
    # pick the largest unsharded, divisible dim
    best, best_dim = None, -1
    for i, (e, d) in enumerate(zip(entries, shape)):
        if e is None and d % size == 0 and d > best_dim:
            best, best_dim = i, d
    if best is None:
        return pspec
    entries[best] = fax if len(fax) > 1 else fax[0]
    return P(*entries)


def per_device_bytes(specs: Any, rules: MeshRules, plans: Any, dtype_bytes: int = 2,
                     opt_bytes: int = 8) -> float:
    """Rough params+optimizer per-chip bytes under the plan (for escalation)."""
    total = 0.0
    for spec, plan in zip(
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec)),
        jax.tree.leaves(plans, is_leaf=lambda x: isinstance(x, ParamPlan)),
    ):
        n = math.prod(spec.shape)
        shards = _pspec_shards(plan.pspec, rules.mesh)
        opt_shards = _pspec_shards(plan.opt_pspec, rules.mesh)
        total += n * dtype_bytes / shards + n * opt_bytes / opt_shards
    return total


def _pspec_shards(pspec: P, mesh: Optional[Mesh]) -> int:
    if mesh is None:
        return 1
    s = 1
    for e in pspec:
        if e is None:
            continue
        for a in (e,) if isinstance(e, str) else e:
            s *= mesh.shape[a]
    return s
