"""Runtime handle threaded through model code: mesh, rules, plan, dtypes.

Models never hard-code mesh axes — they name *logical* axes and the runtime
resolves them (or no-ops on a single device, so the same model code runs in
unit tests, smoke tests, and the 512-chip dry-run).
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
from repro.compat import Mesh, NamedSharding

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.plan import MeshRules, Plan, default_rules
from repro.core.embedding import EmbedCtx


# ---------------------------------------------------------------------------
# manual (shard_map) regions
# ---------------------------------------------------------------------------
# The bucketed gradient exchange (core/buckets.py) traces the whole loss
# under a full-manual shard_map over the mesh. Model code written for global
# semantics is told so via this trace-time flag: sharding constraints become
# no-ops (arrays are per-device values; the batch axes are live named axes)
# and the embedding exchange runs its per-device bodies directly instead of
# opening a nested shard_map.

_MANUAL_REGION = contextvars.ContextVar("repro_manual_region", default=False)


@contextlib.contextmanager
def manual_region():
    """Mark the current trace as running inside a manual shard_map body."""
    token = _MANUAL_REGION.set(True)
    try:
        yield
    finally:
        _MANUAL_REGION.reset(token)


def in_manual_region() -> bool:
    return _MANUAL_REGION.get()


@dataclass
class Runtime:
    model_cfg: ModelConfig
    run_cfg: RunConfig
    shape_cfg: ShapeConfig
    mesh: Optional[Mesh] = None
    rules: MeshRules = None
    plan: Optional[Plan] = None

    def __post_init__(self):
        strategy = self.run_cfg.dense_strategy
        if strategy == "auto" and self.mesh is not None:
            from repro.core.cost_model import MeshDims, pick_dense_strategy
            names = self.mesh.axis_names
            dims = MeshDims(
                model=self.mesh.shape["model"] if "model" in names else 1,
                data=self.mesh.shape["data"] if "data" in names else 1,
                pod=self.mesh.shape["pod"] if "pod" in names else 1)
            strategy = pick_dense_strategy(self.model_cfg, self.shape_cfg,
                                           dims)
        elif strategy == "auto":
            strategy = "tp"
        self.resolved_strategy = strategy
        if self.rules is None:
            self.rules = MeshRules(
                self.mesh,
                default_rules(self.mesh, self.shape_cfg.kind,
                              self.shape_cfg.global_batch, strategy),
            )

    # ---- dtypes ----
    @property
    def dtype(self):
        return jnp.dtype(self.run_cfg.compute_dtype)

    @property
    def param_dtype(self):
        return jnp.dtype(self.run_cfg.param_dtype)

    @property
    def wire_dtype(self):
        # OPSW: cast to the cheap wire dtype before collectives; baseline f32
        return jnp.dtype(self.run_cfg.wire_dtype) if self.run_cfg.opsw else jnp.float32

    # ---- mesh helpers ----
    @property
    def batch_axes(self) -> tuple:
        if self.mesh is None:
            return ()
        r = self.rules.rules.get("batch")
        return r or ()

    @property
    def model_shards(self) -> int:
        return max(self.rules.axis_size("vocab"),
                   self.rules.axis_size("mlp"))

    @property
    def replicas(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    def constrain(self, x, axes: tuple):
        """with_sharding_constraint by logical axes (no-op off-mesh and
        inside manual regions, where x is a per-device value)."""
        if self.mesh is None or in_manual_region():
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.rules.pspec(axes, x.shape)))

    def pad_heads(self, h: int) -> int:
        shards = self.rules.axis_size("q_heads")
        return ((h + shards - 1) // shards) * shards

    @property
    def padded_vocab(self) -> int:
        shards = max(self.model_shards, 1)
        v = self.model_cfg.vocab_size
        return ((v + shards - 1) // shards) * shards

    # ---- the Parallax sparse path (per-table: each sparse parameter can
    # carry its own method, capacity, and wire dtype in the plan) ----
    def sparse_defer_exact(self, name: str = "embed") -> bool:
        """Can this gatherv table's push be deferred post-backward without
        changing the math? The deferred path densifies locally in the
        table's param dtype and re-extracts the wire rows, so it is bitwise
        only when the param dtype holds wire values exactly — and local
        aggregation must be on (duplicate ids would double-count on
        re-extract)."""
        wire = self.wire_dtype
        if self.plan is not None:
            wire = self.plan.table_wire.get(name, wire)
        return bool(self.run_cfg.local_agg
                    and (jnp.dtype(wire) == self.param_dtype
                         or self.param_dtype == jnp.dtype(jnp.float32)))

    def sparse_push_overlapped(self, name: str = "embed") -> bool:
        """Does this table's push exchange run inside the backward as part
        of the overlap schedule? When true the model threads the push
        result into the remaining backward (embedding.overlap_gate) so the
        scheduler must issue the row-buffer collectives at gradient
        readiness instead of parking them after the backward has drained —
        the push otherwise feeds only the optimizer, which constrains
        nothing."""
        if self.mesh is None or not in_manual_region():
            return False
        if not getattr(self.run_cfg, "overlap", True) or not self.batch_axes:
            return False
        if self.plan is None or self.plan.bucket_plan is None:
            return False
        method = self.plan.table_methods.get(name, self.plan.embed_method)
        return method in ("mpi_gatherv", "ps_gather", "ps")

    def embed_ctx(self, name: str = "embed") -> EmbedCtx:
        method, wire = "dense", self.wire_dtype
        if self.plan is not None:
            method = self.plan.table_methods.get(name, self.plan.embed_method)
            wire = self.plan.table_wire.get(name, wire)
        elif self.mesh is not None:
            method = "ps" if self.run_cfg.comm_mode in ("hybrid", "ps") else "mpi_gatherv"
        manual = in_manual_region()
        defer = (manual and method == "mpi_gatherv"
                 and not getattr(self.run_cfg, "overlap", True)
                 and self.plan is not None
                 and self.plan.bucket_plan is not None
                 and self.sparse_defer_exact(name))
        tiles = (self.plan.table_tiles.get(name, (0, 0))
                 if self.plan is not None else (0, 0))
        return EmbedCtx(
            mesh=self.mesh,
            method=method,
            batch_axes=self.batch_axes,
            model_axis="model" if (self.mesh and "model" in self.mesh.axis_names) else "",
            vocab_padded=self.padded_vocab,
            wire_dtype=wire,
            local_agg=self.run_cfg.local_agg,
            exact=self.run_cfg.capacity_mode == "exact",
            manual=manual,
            impl=self.run_cfg.embed_impl,
            defer_push=defer,
            gather_block=int(tiles[0]),
            scatter_block=int(tiles[1]),
            stale=bool(self.plan is not None
                       and name in getattr(self.plan, "stale_tables", ())),
            census=self.shape_cfg.kind != "decode",
        )

    def embed_capacity_for(self, name: str = "embed") -> int:
        if self.plan is not None:
            cap = self.plan.table_capacity.get(name, self.plan.capacity)
            if cap:
                return cap
        # exact fallback: local token count
        toks = self.shape_cfg.tokens // max(self.replicas, 1)
        if self.shape_cfg.kind == "decode":
            toks = max(self.shape_cfg.global_batch // max(self.replicas, 1), 1)
        return max(min(toks, self.padded_vocab), 8)

    @property
    def embed_capacity(self) -> int:
        return self.embed_capacity_for("embed")
