"""PS-style sharded embedding — the paper's sparse path, TPU-native.

The embedding table is row-sharded over the ``model`` mesh axis: each shard
is a "parameter server" for its vocab rows (DESIGN.md §2). One custom_vjp
wraps the whole lookup; its forward and backward are each *non-differentiated*
shard_maps, so every byte on the wire is written explicitly (no autodiff
transpose of collectives — shard_map transposition of replicated operands is
subtle, and the paper's contribution is exactly this exchange schedule):

  local aggregation (C2): each replica dedupes its local ids (sort/unique)
      before any wire traffic; backward segment-sums cotangent rows into the
      same deduped buffer.
  pull (forward): fetch owned rows shard-locally, psum the deduped row
      buffer over ``model`` → per-replica wire bytes ≈ 2αb (Table 3, PS).
  push (backward): either
      ``ps``        owner-local scatter-add into the dense shard + psum over
                    ``data``/``pod`` (2·b/M per chip), or
      ``ps_gather`` all-gather the sparse (ids, rows) buffers over the
                    replica axes + owner-local scatter-add (D·αb),
      picked per workload by core/cost_model.py.
  mpi_gatherv: the paper's MPI baseline — table replicated; push =
      all-gather of sparse buffers over every replica (2(N-1)αb).

Static-shape adaptation: the dedupe buffer has ``capacity`` rows per replica
(DESIGN.md "Static shapes caveat"); ``exact`` capacity == local token count
never drops; overflow is counted in the metrics.
"""
from __future__ import annotations

from dataclasses import dataclass, replace as _dc_replace
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import Mesh, P, shard_map


@dataclass(frozen=True)
class EmbedCtx:
    """Static context for the sharded lookup (hashable for custom_vjp)."""
    mesh: Optional[Mesh]
    method: str                 # ps | ps_gather | mpi_gatherv | dense
    batch_axes: tuple           # mesh axes the batch is sharded over
    model_axis: str             # mesh axis of the row shards
    vocab_padded: int
    wire_dtype: Any             # dtype on the wire (OPSW)
    local_agg: bool             # C2: dedupe before exchange
    exact: bool = True          # exact capacity: size buffer per call-site

    @property
    def model_shards(self) -> int:
        if self.mesh is None or not self.model_axis or \
                self.method in ("dense", "allreduce", "mpi_gatherv"):
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def replicas(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n


def _count_unique(ids_flat: jax.Array) -> jax.Array:
    sorted_ids = jnp.sort(ids_flat)
    return 1 + jnp.sum(sorted_ids[1:] != sorted_ids[:-1]).astype(jnp.int32)


def _dedupe(ids_flat: jax.Array, capacity: int, vocab_padded: int,
            local_agg: bool
            ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """dedupe + observed census: also returns the true unique count
    (pre-capacity) — the in-graph sparsity measurement the runtime profiler
    consumes (core/sparsity.py::SparsityProfile)."""
    t = ids_flat.shape[0]
    if not local_agg:
        # no dedupe: the activated row-buffer is the raw token count. The
        # census reports the buffer actually exchanged — and the LA-off
        # ablation path stays sort-free.
        return (ids_flat.astype(jnp.int32),
                jnp.arange(t, dtype=jnp.int32),
                jnp.zeros((), jnp.int32),
                jnp.asarray(t, jnp.int32))
    capacity = min(capacity, t)
    uids, inv = jnp.unique(
        ids_flat, size=capacity, fill_value=vocab_padded, return_inverse=True)
    n_unique = _count_unique(ids_flat)
    dropped = jnp.maximum(n_unique - capacity, 0)
    valid = uids[inv] == ids_flat
    inv = jnp.where(valid, inv, capacity)
    return uids.astype(jnp.int32), inv.astype(jnp.int32), dropped, n_unique


def dedupe(ids_flat: jax.Array, capacity: int, vocab_padded: int,
           local_agg: bool) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(unique_ids[capacity], inverse[T], n_dropped). Sentinel = vocab_padded.

    inverse entries that overflowed capacity point one-past-end (= capacity),
    which readers treat as a zero row.
    """
    uids, inv, dropped, _ = _dedupe(ids_flat, capacity, vocab_padded,
                                    local_agg)
    return uids, inv, dropped


# ---------------------------------------------------------------------------
# per-device bodies (never auto-differentiated)
# ---------------------------------------------------------------------------

def _fwd_local(table_shard, ids_loc, ctx: EmbedCtx, capacity: int):
    """-> out (B_loc,S,E), uids (1,cap), inv (B_loc,S), dropped, uniq."""
    b_loc, s = ids_loc.shape
    flat = ids_loc.reshape(-1).astype(jnp.int32)
    uids, inv, dropped, n_unique = _dedupe(flat, capacity, ctx.vocab_padded,
                                           ctx.local_agg)
    # observed census: mean unique ids per replica-step (scalar; cheap).
    # Inside shard_map the count varies over the batch axes — average them
    # (a scalar psum, OPAU-style); over the model axis ids are replicated.
    uniq = n_unique.astype(jnp.float32)
    in_shard_map = ctx.mesh is not None and \
        ctx.method not in ("dense", "allreduce")
    if in_shard_map and ctx.batch_axes:
        uniq = jax.lax.psum(uniq, ctx.batch_axes) / ctx.replicas
    vs = table_shard.shape[0]
    if ctx.model_shards > 1:
        m = jax.lax.axis_index(ctx.model_axis)
        local = uids - m * vs
        owned = (local >= 0) & (local < vs)
        rows = jnp.take(table_shard, jnp.clip(local, 0, vs - 1), axis=0)
        rows = jnp.where(owned[:, None], rows, 0).astype(ctx.wire_dtype)
        rows = jax.lax.psum(rows, ctx.model_axis)     # pull: ~2αb over model
        rows = rows.astype(table_shard.dtype)
    else:
        rows = jnp.take(table_shard, jnp.clip(uids, 0, vs - 1), axis=0)
        rows = jnp.where((uids < vs)[:, None], rows, 0)
    rows_pad = jnp.concatenate([rows, jnp.zeros_like(rows[:1])], axis=0)
    out = jnp.take(rows_pad, inv, axis=0).reshape(b_loc, s, -1)
    return out, uids[None], inv.reshape(b_loc, s), dropped, uniq


def _bwd_local(uids_row, inv_loc, d_out_loc, vs_shard, ctx: EmbedCtx):
    """-> d_table shard (vs_shard, E). Runs the push exchange."""
    uids = uids_row[0]
    cap = uids.shape[0]
    d_flat = d_out_loc.reshape(-1, d_out_loc.shape[-1])
    # C2 local aggregation: segment-sum cotangents into the deduped buffer
    d_rows = jnp.zeros((cap + 1, d_flat.shape[-1]), jnp.float32)
    d_rows = d_rows.at[inv_loc.reshape(-1)].add(d_flat.astype(jnp.float32))
    d_rows = d_rows[:cap].astype(ctx.wire_dtype)

    if ctx.method == "mpi_gatherv":
        # paper's MPI baseline: all-gather (ids, rows) over every replica
        if ctx.batch_axes:
            uids_all = jax.lax.all_gather(uids, ctx.batch_axes,
                                          tiled=False).reshape(-1)
            rows_all = jax.lax.all_gather(d_rows, ctx.batch_axes,
                                          tiled=False).reshape(-1, d_rows.shape[-1])
        else:
            uids_all, rows_all = uids, d_rows
        idx = jnp.where((uids_all >= 0) & (uids_all < vs_shard),
                        uids_all, vs_shard)
        d = jnp.zeros((vs_shard + 1, rows_all.shape[-1]), jnp.float32)
        d = d.at[idx].add(rows_all.astype(jnp.float32))
        return d[:vs_shard]

    m = jax.lax.axis_index(ctx.model_axis) if ctx.model_shards > 1 else 0
    if ctx.method == "ps_gather":
        # sparse all-gather over replicas, owner-local scatter (D·αb)
        if ctx.batch_axes:
            uids_all = jax.lax.all_gather(uids, ctx.batch_axes,
                                          tiled=False).reshape(-1)
            rows_all = jax.lax.all_gather(d_rows, ctx.batch_axes,
                                          tiled=False).reshape(-1, d_rows.shape[-1])
        else:
            uids_all, rows_all = uids, d_rows
        local = uids_all - m * vs_shard
        owned = (local >= 0) & (local < vs_shard)
        idx = jnp.where(owned, local, vs_shard)
        d = jnp.zeros((vs_shard + 1, rows_all.shape[-1]), jnp.float32)
        d = d.at[idx].add(rows_all.astype(jnp.float32))
        return d[:vs_shard]

    # "ps": owner-local scatter-add + dense shard psum over replicas (2b/M)
    local = uids - m * vs_shard
    owned = (local >= 0) & (local < vs_shard)
    idx = jnp.where(owned, local, vs_shard)
    d = jnp.zeros((vs_shard + 1, d_rows.shape[-1]), jnp.float32)
    d = d.at[idx].add(d_rows.astype(jnp.float32))
    d = d[:vs_shard]
    if ctx.batch_axes:
        d = jax.lax.psum(d.astype(ctx.wire_dtype), ctx.batch_axes
                         ).astype(jnp.float32)
    return d


# ---------------------------------------------------------------------------
# the differentiable global lookup (custom VJP around whole shard_maps)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _lookup(table, ids, ctx: EmbedCtx, capacity: int):
    out, _, _, dropped, uniq = _lookup_fwd_impl(table, ids, ctx, capacity)
    return out, dropped, uniq


def _lookup_fwd_impl(table, ids, ctx: EmbedCtx, capacity: int):
    if ctx.mesh is None or ctx.method in ("dense", "allreduce"):
        out, uids, inv, dropped, uniq = _fwd_local(table, ids, ctx, capacity)
        return out, uids, inv, dropped, uniq
    ba = ctx.batch_axes or None
    table_spec = P(None, None) if ctx.method == "mpi_gatherv" \
        else P(ctx.model_axis, None)
    fn = shard_map(
        lambda t, i: _fwd_local(t, i, ctx, capacity),
        mesh=ctx.mesh,
        in_specs=(table_spec, P(ba, None)),
        out_specs=(P(ba, None, None), P(ba, None), P(ba, None), P(), P()),
        check_vma=False,
    )
    return fn(table, ids)


def _lookup_fwd(table, ids, ctx: EmbedCtx, capacity: int):
    out, uids, inv, dropped, uniq = _lookup_fwd_impl(table, ids, ctx,
                                                     capacity)
    return (out, dropped, uniq), (uids, inv, jnp.zeros((0,), table.dtype))


def _lookup_bwd(ctx: EmbedCtx, capacity: int, res, cts):
    d_out, _, _ = cts
    uids, inv, dtype_probe = res
    vocab_rows = ctx.vocab_padded
    vs = vocab_rows // ctx.model_shards
    if ctx.mesh is None or ctx.method in ("dense", "allreduce"):
        # global-semantics dense path: the scatter-add cotangent is the full
        # gradient; XLA inserts the dense all-reduce across replicas (no
        # named-axis collectives outside shard_map)
        d_table = _bwd_local(uids, inv, d_out, vocab_rows,
                             _dc_replace(ctx, batch_axes=()))
    else:
        ba = ctx.batch_axes or None
        table_spec = P(None, None) if ctx.method == "mpi_gatherv" \
            else P(ctx.model_axis, None)
        fn = shard_map(
            lambda u, i, d: _bwd_local(u, i, d, vs, ctx),
            mesh=ctx.mesh,
            in_specs=(P(ba, None), P(ba, None), P(ba, None, None)),
            out_specs=table_spec,
            check_vma=False,
        )
        d_table = fn(uids, inv, d_out)
    return (d_table.astype(dtype_probe.dtype),
            np.zeros(inv.shape, dtype=jax.dtypes.float0))


_lookup.defvjp(_lookup_fwd, _lookup_bwd)


def lookup(table: jax.Array, ids: jax.Array, *, ctx: EmbedCtx,
           capacity: int) -> tuple[jax.Array, dict]:
    """Embedding lookup through the PS exchange. ids: (B, S) global ids."""
    if ctx.mesh is not None and ctx.method in ("dense", "allreduce"):
        local_tokens = ids.size        # global dedupe in global semantics
    else:
        local_tokens = max(ids.size // max(ctx.replicas, 1), 1)
    if ctx.exact:
        # exact mode never drops: buffer sized to this call's local tokens
        capacity = min(local_tokens, ctx.vocab_padded)
    else:
        capacity = min(capacity, local_tokens, ctx.vocab_padded)
    out, dropped, uniq = _lookup(table, ids, ctx, capacity)
    nrows = capacity if ctx.local_agg else local_tokens
    metrics = {"embed_rows": jnp.asarray(nrows, jnp.int32),
               "embed_dropped": jax.lax.stop_gradient(dropped),
               "embed_unique": jax.lax.stop_gradient(uniq)}
    return out.astype(table.dtype), metrics
