"""PS-style sharded embedding — the paper's sparse path, TPU-native.

The embedding table is row-sharded over the ``model`` mesh axis: each shard
is a "parameter server" for its vocab rows (DESIGN.md §2). One custom_vjp
wraps the whole lookup; its forward and backward are each *non-differentiated*
shard_maps, so every byte on the wire is written explicitly (no autodiff
transpose of collectives — shard_map transposition of replicated operands is
subtle, and the paper's contribution is exactly this exchange schedule):

  local aggregation (C2): each replica dedupes its local ids (sort/unique)
      before any wire traffic; backward segment-sums cotangent rows into the
      same deduped buffer.
  pull (forward): fetch owned rows shard-locally, psum the deduped row
      buffer over ``model`` → per-replica wire bytes ≈ 2αb (Table 3, PS).
  push (backward): either
      ``ps``        owner-local scatter-add into the dense shard + psum over
                    ``data``/``pod`` (2·b/M per chip), or
      ``ps_gather`` all-gather the sparse (ids, rows) buffers over the
                    replica axes + owner-local scatter-add (D·αb),
      picked per workload by core/cost_model.py.
  mpi_gatherv: the paper's MPI baseline — table replicated; push =
      all-gather of sparse buffers over every replica (2(N-1)αb).

Static-shape adaptation: the dedupe buffer has ``capacity`` rows per replica
(DESIGN.md "Static shapes caveat"); ``exact`` capacity == local token count
never drops; overflow is counted in the metrics.
"""
from __future__ import annotations

from dataclasses import dataclass, replace as _dc_replace
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import Mesh, P, shard_map


@dataclass(frozen=True)
class EmbedCtx:
    """Static context for the sharded lookup (hashable for custom_vjp)."""
    mesh: Optional[Mesh]
    method: str                 # ps | ps_gather | mpi_gatherv | dense
    batch_axes: tuple           # mesh axes the batch is sharded over
    model_axis: str             # mesh axis of the row shards
    vocab_padded: int
    wire_dtype: Any             # dtype on the wire (OPSW)
    local_agg: bool             # C2: dedupe before exchange
    exact: bool = True          # exact capacity: size buffer per call-site
    manual: bool = False        # already inside a manual (shard_map) region:
                                # run the per-device bodies directly — the
                                # batch axes are live named axes (the
                                # bucketed-exchange path, core/buckets.py)
    impl: str = "jnp"           # gather/scatter impl: jnp | pallas kernels
    defer_push: bool = False    # overlap=False bucketed baseline: the VJP
                                # returns the locally-densified gradient and
                                # core/buckets.py reruns the gatherv push
                                # post-backward (deferred_push)
    gather_block: int = 0       # Pallas embed_gather lane tile (autotuned;
                                # 0 = the fixed full-row block)
    scatter_block: int = 0      # Pallas embed_scatter_add lane tile
    stale: bool = False         # bounded-staleness push mode: the exchange
                                # still runs every step (replica
                                # consistency), but the train step applies
                                # the *previous* step's exchanged gradient
                                # through the staleness buffer
                                # (core/transform.py); marker only here —
                                # surfaced as the {name}_stale_mode metric
    census: bool = True         # cross-replica observed-census reduction:
                                # off on the serve path (decode-kind
                                # Runtime), where nothing consumes the
                                # profile and the scalar psum would ride
                                # every decode step's critical path

    @property
    def model_shards(self) -> int:
        if self.mesh is None or not self.model_axis or \
                self.method in ("dense", "allreduce", "mpi_gatherv"):
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def replicas(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n


def _dedupe(ids_flat: jax.Array, capacity: int, vocab_padded: int,
            local_agg: bool
            ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """dedupe + observed census: also returns the true unique count
    (pre-capacity) — the in-graph sparsity measurement the runtime profiler
    consumes (core/sparsity.py::SparsityProfile).

    One argsort produces everything: the sorted order gives first-occurrence
    flags, their cumsum is each id's unique rank ("slot"), and scattering
    first occurrences by slot rebuilds the ascending unique buffer —
    byte-compatible with ``jnp.unique(size=capacity, fill_value=...)`` +
    a separate census sort, at half the sorts.
    """
    t = ids_flat.shape[0]
    if not local_agg:
        # no dedupe: the activated row-buffer is the raw token count. The
        # census reports the buffer actually exchanged — and the LA-off
        # ablation path stays sort-free.
        return (ids_flat.astype(jnp.int32),
                jnp.arange(t, dtype=jnp.int32),
                jnp.zeros((), jnp.int32),
                jnp.asarray(t, jnp.int32))
    capacity = min(capacity, t)
    order = jnp.argsort(ids_flat)                       # the one sort
    sorted_ids = ids_flat[order].astype(jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool),
                             sorted_ids[1:] != sorted_ids[:-1]])
    n_unique = jnp.sum(first).astype(jnp.int32)
    slot = (jnp.cumsum(first) - 1).astype(jnp.int32)    # unique rank, sorted
    dropped = jnp.maximum(n_unique - capacity, 0)
    # ascending unique ids; slots past capacity overflow into a discard row
    uids = jnp.full((capacity + 1,), vocab_padded, jnp.int32)
    uids = uids.at[jnp.where(first & (slot < capacity), slot, capacity)
                   ].set(sorted_ids)[:capacity]
    # inverse: original position -> slot (capacity == overflowed sentinel)
    inv = jnp.zeros((t,), jnp.int32).at[order].set(
        jnp.minimum(slot, capacity))
    return uids, inv, dropped, n_unique


def dedupe(ids_flat: jax.Array, capacity: int, vocab_padded: int,
           local_agg: bool) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(unique_ids[capacity], inverse[T], n_dropped). Sentinel = vocab_padded.

    inverse entries that overflowed capacity point one-past-end (= capacity),
    which readers treat as a zero row.
    """
    uids, inv, dropped, _ = _dedupe(ids_flat, capacity, vocab_padded,
                                    local_agg)
    return uids, inv, dropped


# ---------------------------------------------------------------------------
# per-device bodies (never auto-differentiated)
# ---------------------------------------------------------------------------

def _gather_rows(table_shard, local_ids, ctx: EmbedCtx):
    """Owned-row pull: rows for local-space ids in [0, Vs), zeros elsewhere.

    The per-shard half of the PS pull — either the Pallas embed_gather
    kernel (ids in SMEM drive the table DMA; interpret-mode off-TPU) or its
    jnp oracle (kernels/ref.py — one source of truth for the take+mask
    semantics), per ``RunConfig.embed_impl``.
    """
    if ctx.impl == "pallas":
        from repro.kernels import ops
        return ops.embed_gather(table_shard, local_ids,
                                block_e=ctx.gather_block)
    from repro.kernels import ref
    return ref.embed_gather_ref(table_shard, local_ids, 0)


def _scatter_rows(local_ids, rows, vs: int, ctx: EmbedCtx):
    """Owner-local push: scatter deduped cotangent rows into the (Vs, E)
    f32 gradient shard, dropping unowned ids.

    The Pallas embed_scatter_add kernel requires unique ids (the dedupe
    buffer is sorted-unique), so it only serves the local_agg path; gathered
    cross-replica buffers (ps_gather / mpi_gatherv) take the jnp oracle
    (kernels/ref.py), whose scatter-add accumulates duplicates.
    """
    if ctx.impl == "pallas" and ctx.local_agg:
        from repro.kernels import ops
        return ops.embed_scatter_add(local_ids, rows, vs,
                                     block_e=ctx.scatter_block)
    from repro.kernels import ref
    return ref.embed_scatter_add_ref(local_ids, rows, vs)


def _fwd_local(table_shard, ids_loc, ctx: EmbedCtx, capacity: int):
    """-> out (B_loc,S,E), uids (1,cap), inv (B_loc,S), dropped, uniq."""
    b_loc, s = ids_loc.shape
    flat = ids_loc.reshape(-1).astype(jnp.int32)
    uids, inv, dropped, n_unique = _dedupe(flat, capacity, ctx.vocab_padded,
                                           ctx.local_agg)
    # observed census: mean unique ids per replica-step (scalar; cheap).
    # Inside shard_map the count varies over the batch axes — average them
    # (a scalar psum, OPAU-style); over the model axis ids are replicated.
    # In a manual (bucketed) region the average instead rides the fused
    # scalar-metrics psum in core/buckets.py — no collective here.
    uniq = n_unique.astype(jnp.float32)
    in_shard_map = ctx.mesh is not None and not ctx.manual and \
        ctx.method not in ("dense", "allreduce")
    if in_shard_map and ctx.batch_axes:
        if ctx.census:
            uniq = jax.lax.psum(uniq, ctx.batch_axes) / ctx.replicas
        else:
            # census off (serve path): drop the measurement rather than
            # declare a device-varying scalar replicated (out_specs P())
            uniq = jnp.zeros_like(uniq)
    vs = table_shard.shape[0]
    if ctx.model_shards > 1:
        m = jax.lax.axis_index(ctx.model_axis)
        rows = _gather_rows(table_shard, uids - m * vs, ctx)
        rows = rows.astype(ctx.wire_dtype)
        rows = jax.lax.psum(rows, ctx.model_axis)     # pull: ~2αb over model
        rows = rows.astype(table_shard.dtype)
    else:
        rows = _gather_rows(table_shard, uids, ctx)
    rows_pad = jnp.concatenate([rows, jnp.zeros_like(rows[:1])], axis=0)
    out = jnp.take(rows_pad, inv, axis=0).reshape(b_loc, s, -1)
    return out, uids[None], inv.reshape(b_loc, s), dropped, uniq


def _bwd_local(uids_row, inv_loc, d_out_loc, vs_shard, ctx: EmbedCtx):
    """-> d_table shard (vs_shard, E). Runs the push exchange."""
    uids = uids_row[0]
    cap = uids.shape[0]
    d_flat = d_out_loc.reshape(-1, d_out_loc.shape[-1])
    # C2 local aggregation: segment-sum cotangents into the deduped buffer
    d_rows = jnp.zeros((cap + 1, d_flat.shape[-1]), jnp.float32)
    d_rows = d_rows.at[inv_loc.reshape(-1)].add(d_flat.astype(jnp.float32))
    d_rows = d_rows[:cap].astype(ctx.wire_dtype)

    if ctx.method == "mpi_gatherv":
        if ctx.defer_push:
            # overlap=False bucketed baseline: no collectives here — return
            # the locally-densified gradient; core/buckets.py re-extracts
            # the deduped rows (deferred_push) and runs the identical
            # all-gather exchange after the full backward, pinned.
            return _scatter_rows(uids, d_rows, vs_shard,
                                 _dc_replace(ctx, local_agg=False))
        # paper's MPI baseline: all-gather (ids, rows) over every replica.
        # Gathered ids duplicate across replicas -> jnp scatter-add (the
        # overwrite-style Pallas kernel needs unique ids), via local_agg=False
        if ctx.batch_axes:
            uids_all = jax.lax.all_gather(uids, ctx.batch_axes,
                                          tiled=False).reshape(-1)
            rows_all = jax.lax.all_gather(d_rows, ctx.batch_axes,
                                          tiled=False).reshape(-1, d_rows.shape[-1])
        else:
            uids_all, rows_all = uids, d_rows
        return _scatter_rows(uids_all, rows_all, vs_shard,
                             _dc_replace(ctx, local_agg=False))

    m = jax.lax.axis_index(ctx.model_axis) if ctx.model_shards > 1 else 0
    if ctx.method == "ps_gather":
        # sparse all-gather over replicas, owner-local scatter (D·αb)
        if ctx.batch_axes:
            uids_all = jax.lax.all_gather(uids, ctx.batch_axes,
                                          tiled=False).reshape(-1)
            rows_all = jax.lax.all_gather(d_rows, ctx.batch_axes,
                                          tiled=False).reshape(-1, d_rows.shape[-1])
        else:
            uids_all, rows_all = uids, d_rows
        return _scatter_rows(uids_all - m * vs_shard, rows_all, vs_shard,
                             _dc_replace(ctx, local_agg=False))

    # "ps": owner-local scatter-add + dense shard psum over replicas (2b/M)
    d = _scatter_rows(uids - m * vs_shard, d_rows, vs_shard, ctx)
    if ctx.batch_axes:
        d = jax.lax.psum(d.astype(ctx.wire_dtype), ctx.batch_axes
                         ).astype(jnp.float32)
    return d


def pin_after(x, dep):
    """Return ``x`` bitwise-unchanged, with a scheduling dependence on
    ``dep``: one element of ``x`` is re-written with itself at an index
    derived from ``dep``'s first element. A dynamic self-write is exact for
    every value (NaN and -0.0 included — nothing from ``dep`` ever mixes
    into ``x``'s values) and the compiler cannot fold it away because the
    index is data-dependent, so every consumer of the result orders after
    ``dep`` is computed. Out-of-range indices are safe: dynamic slice and
    update clamp identically."""
    flat = x.reshape(-1)
    idx = jax.lax.convert_element_type(dep.reshape(-1)[0], jnp.int32)
    piece = jax.lax.dynamic_slice(flat, (idx,), (1,))
    return jax.lax.dynamic_update_slice(flat, piece, (idx,)).reshape(x.shape)


@jax.custom_vjp
def _gate(table, act):
    return table, act


def _gate_fwd(table, act):
    return (table, act), None


def _gate_bwd(_, cts):
    d_table, d_act = cts
    # d_table is the already-exchanged push result (the lookup VJP ran the
    # row-buffer collectives); pinning the activation cotangent on it makes
    # the rest of the backward depend on the push having been issued
    return d_table, pin_after(d_act, d_table)


_gate.defvjp(_gate_fwd, _gate_bwd)


def overlap_gate(table, activation):
    """Overlap-schedule gate for an in-backward sparse push (Parallax §4:
    sparse exchanges issue at gradient readiness, concurrent with the rest
    of the backward). Thread a sparse table and an activation whose
    cotangent feeds the *remaining* backward (e.g. the encoder output for a
    decoder-side table) through this identity pair: in the backward, the
    activation's cotangent gains a value-exact data dependence
    (``pin_after``) on the table's pushed gradient, so the scheduler must
    issue the push collectives before the remaining backward instead of
    parking them after it (the push result otherwise feeds only the
    optimizer, which constrains nothing). Bitwise no-op on every value in
    both directions."""
    return _gate(table, activation)


def deferred_push(g_local, uids, ctx: EmbedCtx, pin=None):
    """Post-backward gatherv push for a deferred table (``EmbedCtx.
    defer_push``): re-extract the deduped wire rows from the locally-
    densified gradient, all-gather (ids, rows) over the replicas, densify —
    the exact exchange ``_bwd_local`` would have run in-backward. Exact
    because the densify round-trip over unique ids is the identity (sentinel
    rows read the appended zero row, and dedupe rows carried zeros there
    anyway), and the wire cast chain replays bitwise when the table's param
    dtype holds wire values exactly (Runtime.sparse_defer_exact gates this).

    ``pin``: the overlap=False data-dependence vector — its sum rides an
    extra row of the all-gathered buffer (dropped after), so the scheduler
    cannot issue this collective before the backward has drained.
    """
    vs, e = g_local.shape
    gpad = jnp.concatenate([g_local.astype(jnp.float32),
                            jnp.zeros((1, e), jnp.float32)], axis=0)
    rows = jnp.take(gpad, uids, axis=0).astype(ctx.wire_dtype)
    cap = uids.shape[0]
    if pin is not None:
        pin_row = jnp.broadcast_to(jnp.sum(pin), (1, e))
        rows = jnp.concatenate([rows, pin_row.astype(rows.dtype)], axis=0)
    if ctx.batch_axes:
        uids_all = jax.lax.all_gather(uids, ctx.batch_axes,
                                      tiled=False).reshape(-1)
        rows_all = jax.lax.all_gather(rows, ctx.batch_axes,
                                      tiled=False).reshape(-1, rows.shape[0], e)
        rows_all = rows_all[:, :cap].reshape(-1, e)
    else:
        uids_all, rows_all = uids, rows[:cap]
    d = _scatter_rows(uids_all, rows_all, vs,
                      _dc_replace(ctx, local_agg=False))
    return d.astype(g_local.dtype)


# ---------------------------------------------------------------------------
# the differentiable global lookup (custom VJP around whole shard_maps)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _lookup(table, ids, ctx: EmbedCtx, capacity: int):
    out, _, _, dropped, uniq = _lookup_fwd_impl(table, ids, ctx, capacity)
    return out, dropped, uniq


def _lookup_fwd_impl(table, ids, ctx: EmbedCtx, capacity: int):
    if ctx.mesh is None or ctx.method in ("dense", "allreduce") or ctx.manual:
        # dense/allreduce: global semantics, XLA owns the aggregation.
        # manual: core/buckets.py already mapped the batch axes — the
        # per-device body runs directly, its collectives on live named axes.
        out, uids, inv, dropped, uniq = _fwd_local(table, ids, ctx, capacity)
        return out, uids, inv, dropped, uniq
    ba = ctx.batch_axes or None
    table_spec = P(None, None) if ctx.method == "mpi_gatherv" \
        else P(ctx.model_axis, None)
    fn = shard_map(
        lambda t, i: _fwd_local(t, i, ctx, capacity),
        mesh=ctx.mesh,
        in_specs=(table_spec, P(ba, None)),
        out_specs=(P(ba, None, None), P(ba, None), P(ba, None), P(), P()),
        check_vma=False,
    )
    return fn(table, ids)


def _lookup_fwd(table, ids, ctx: EmbedCtx, capacity: int):
    out, uids, inv, dropped, uniq = _lookup_fwd_impl(table, ids, ctx,
                                                     capacity)
    return (out, dropped, uniq), (uids, inv, jnp.zeros((0,), table.dtype))


def _lookup_bwd(ctx: EmbedCtx, capacity: int, res, cts):
    d_out, _, _ = cts
    uids, inv, dtype_probe = res
    vocab_rows = ctx.vocab_padded
    vs = vocab_rows // ctx.model_shards
    if ctx.mesh is None or ctx.method in ("dense", "allreduce"):
        # global-semantics dense path: the scatter-add cotangent is the full
        # gradient; XLA inserts the dense all-reduce across replicas (no
        # named-axis collectives outside shard_map). Under a manual region
        # the same local partial gradient feeds the bucketed exchange.
        d_table = _bwd_local(uids, inv, d_out, vocab_rows,
                             _dc_replace(ctx, batch_axes=()))
    elif ctx.manual:
        # inside the bucketed-exchange manual region: the push collectives
        # (all-gathers for mpi_gatherv) run on the live named axes; the
        # resulting gradient is the replica-sum, rescaled by core/buckets.py
        d_table = _bwd_local(uids, inv, d_out, vs, ctx)
    else:
        ba = ctx.batch_axes or None
        table_spec = P(None, None) if ctx.method == "mpi_gatherv" \
            else P(ctx.model_axis, None)
        fn = shard_map(
            lambda u, i, d: _bwd_local(u, i, d, vs, ctx),
            mesh=ctx.mesh,
            in_specs=(P(ba, None), P(ba, None), P(ba, None, None)),
            out_specs=table_spec,
            check_vma=False,
        )
        d_table = fn(uids, inv, d_out)
    return (d_table.astype(dtype_probe.dtype),
            np.zeros(inv.shape, dtype=jax.dtypes.float0))


_lookup.defvjp(_lookup_fwd, _lookup_bwd)


def lookup(table: jax.Array, ids: jax.Array, *, ctx: EmbedCtx,
           capacity: int, name: str = "embed") -> tuple[jax.Array, dict]:
    """Embedding lookup through the PS exchange. ids: (B, S) global ids.

    ``name`` keys the observed-census metrics (``{name}_unique`` /
    ``{name}_dropped``) so a model with several sparse tables profiles each
    one separately — the per-parameter replan loop reads them by table.
    """
    if ctx.manual:
        local_tokens = max(ids.size, 1)   # ids are already per-replica local
    elif ctx.mesh is not None and ctx.method in ("dense", "allreduce"):
        local_tokens = ids.size        # global dedupe in global semantics
    else:
        local_tokens = max(ids.size // max(ctx.replicas, 1), 1)
    if ctx.exact:
        # exact mode never drops: buffer sized to this call's local tokens
        capacity = min(local_tokens, ctx.vocab_padded)
    else:
        capacity = min(capacity, local_tokens, ctx.vocab_padded)
    out, dropped, uniq = _lookup(table, ids, ctx, capacity)
    nrows = capacity if ctx.local_agg else local_tokens
    metrics = {f"{name}_rows": jnp.asarray(nrows, jnp.int32),
               f"{name}_dropped": jax.lax.stop_gradient(dropped),
               f"{name}_unique": jax.lax.stop_gradient(uniq)}
    if ctx.stale:
        # the jitter fallback is live for this table: its push is applied
        # one step late (bounded by RunConfig.max_staleness, asserted via
        # the staleness_violation metric in core/transform.py)
        metrics[f"{name}_stale_mode"] = jnp.ones((), jnp.float32)
    if ctx.defer_push:
        # smuggle the dedupe buffer out to the post-backward deferred push
        # (core/buckets.py pops this before the fused metrics psum). Same
        # args as the VJP's dedupe -> identical buffer (and XLA CSEs the
        # shared argsort).
        flat = ids.reshape(-1).astype(jnp.int32)
        uids, _, _, _ = _dedupe(flat, capacity, ctx.vocab_padded,
                                ctx.local_agg)
        metrics[f"{name}_uids"] = jax.lax.stop_gradient(uids)
    return out.astype(table.dtype), metrics
