"""Bucketed dense-gradient exchange (Horovod-style tensor fusion, in JAX).

The hybrid plan minimizes *bytes* on the wire, but under global semantics
XLA materializes one all-reduce per gradient tensor at its producing op —
so a model with n dense parameters pays n per-message latencies (the α in
α + β·b, see core/cost_model.py) however small the tensors are. GSPMD has
no "unreduced" value state, so no downstream concatenation can merge those
collectives; the only place the exchange can be fused is *before* XLA ever
sees a global gradient.

This module therefore traces loss+grad inside one full-manual ``shard_map``
over the mesh: inside, gradients are per-replica partials and aggregation
is written explicitly —

  * dense (method == allreduce) gradients are flattened into a few flat
    wire-dtype buffers of at most ``RunConfig.bucket_bytes`` each, grouped
    by (method, exchange dtype, pspec); each buffer rides ONE psum,
  * the loss and every scalar metric ride a single fused scalar psum,
  * the sparse push keeps its own schedule: the embedding custom_vjp runs
    its per-device body directly on the live named axes (EmbedCtx.manual).

Overlap (RunConfig.overlap, default on): buckets are assigned in
*reverse-topological* order — greedy first-fit over the reversed parameter
flatten order, so bucket 0 holds the last-forward parameters whose
gradients the backward pass produces FIRST — and each bucket's fused psum
is issued inside the backward graph itself, at the point its last member
gradient is produced. The mechanism is a ``jax.custom_vjp`` identity "tap"
around each bucket's parameters: the forward is the identity, and the
backward performs the bucket's flatten → scale → cast → psum → slice-back
exchange on the incoming cotangents before handing them on. That places
the collective at the gradient-readiness frontier of the autodiff graph,
so the scheduler can run it concurrently with the rest of the backward —
tests/test_perf_paths.py asserts the first bucket's all-reduce is
scheduled before the final gradient op. ``overlap=False`` pins every
bucket collective strictly after the full backward (a data-dependence
pin: one element of every gradient leaf rides each bucket's psum input
and is sliced off after) — the regression baseline. Both paths compute
bit-identical values: the exchange is an elementwise psum, so grouping
and issue order never change the math.

Multi-host meshes (MeshDims.hosts > 1, fitted inter-tier constants): a
bucket whose cost-model argmin prefers it rides a *two-level* schedule —
intra-host reduce-scatter, inter-host all-reduce of the 1/L shard,
intra-host all-gather — instead of one flat psum, provided the mesh
exposes the host tier as the leading "pod" batch axis.

Applicability (``bucketable``): pure data-parallel meshes — every mesh axis
that is not a batch axis has size 1, every dense parameter exchanges by
all-reduce, and the model opens no nested shard_map of its own (MoE EP
does). Anywhere else ``assign_buckets`` returns None and the planner keeps
the per-tensor global-semantics path. Correctness contract: the bucketed
step computes what the unbucketed step computes (same plan, same math;
summation order differs only within float tolerance) — tests/test_perf_paths
asserts the 3-step trajectory at f32 and the collective-count drop in HLO.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.compat import P, shard_map
from repro.core import cost_model, embedding
from repro.core.plan import ParamPlan, Plan, plan_leaves
from repro.core.runtime import manual_region
from repro.utils.roofline import HW


def _plan_leaves(plan: Plan) -> list[ParamPlan]:
    # the one flatten order bucket indices are defined against — shared with
    # the trainer's wire_dtype_hints param_names via core/plan.plan_leaves
    return plan_leaves(plan.params)


def _effective_pspec(pspec, mesh) -> tuple:
    """Pspec with size-1 mesh axes dropped — the *physical* layout. Two
    parameters whose pspecs differ only in size-1 axes shard identically,
    so their flattened gradients can share a fused buffer."""
    out = []
    for e in pspec:
        axes = (e,) if isinstance(e, str) else tuple(e or ())
        axes = tuple(a for a in axes if mesh.shape[a] > 1)
        out.append(axes[0] if len(axes) == 1 else (axes or None))
    return tuple(x for x in out if x is not None)


@dataclass(frozen=True)
class Bucket:
    key: tuple        # (method, wire dtype name, pspec entries) group key
    idx: tuple        # leaf positions in the flattened grads/plan tree —
                      # reverse-topological: bucket 0 holds the last-forward
                      # (first-backward) parameters
    sizes: tuple      # element count per member
    nbytes: int       # fused buffer wire bytes
    schedule: str = "ring"     # ring | two_level (cost_model argmin)


@dataclass
class BucketPlan:
    buckets: list
    batch_axes: tuple      # the manual/psum axes of the exchange
    replicas: int          # N: product of the batch axis sizes
    n_params: int          # bucketed gradient tensors
    wire_bytes: int        # sum of fused buffer bytes
    bucket_bytes: int      # the RunConfig knob that sized the buckets
    hw: Any = None         # the hardware model the planner priced against
    hosts: int = 1         # H: host groups among the replicas
    overlap: bool = True   # issue each bucket's psum at grad readiness
    n_sparse_push: int = 0  # gatherv tables with their own row-buffer push

    @property
    def dims(self) -> cost_model.MeshDims:
        return cost_model.MeshDims(data=self.replicas, hosts=self.hosts)

    def stats(self, hw=None) -> dict:
        """Exchange accounting for runtime/monitor.py — the cost-model view
        of what bucketing saved (per step, dense push only), priced with
        the same hardware model the planner's argmin used. Each bucket is
        priced at its chosen execution schedule; the unbucketed reference
        is one flat ring per member tensor."""
        hw = hw or self.hw or HW
        dims = self.dims
        ring = 2.0 * (self.replicas - 1) / max(self.replicas, 1)
        tier = cost_model.span_tier(dims, hw)
        est = 0.0
        for b in self.buckets:
            secs = cost_model.dense_schedule_seconds(b.nbytes, dims, hw)
            est += secs.get(b.schedule, secs["ring"])
        return {
            "n_buckets": len(self.buckets),
            "n_params_bucketed": self.n_params,
            "n_collectives_dense": len(self.buckets),
            "n_collectives_unbucketed": self.n_params,
            "n_two_level": sum(1 for b in self.buckets
                               if b.schedule == "two_level"),
            "hosts": self.hosts,
            "overlap": self.overlap,
            # sparse row-buffer pushes issued at gradient readiness inside
            # the backward (overlap=False defers them post-backward)
            "n_overlapped_sparse": self.n_sparse_push if self.overlap else 0,
            "wire_bytes": self.wire_bytes,
            "bucket_bytes": self.bucket_bytes,
            "est_seconds": est,
            "est_seconds_unbucketed": cost_model.exchange_seconds(
                ring * self.wire_bytes, self.n_params, hw, tier=tier),
        }

    def expected_collectives(self, n_leaves: int = 0,
                             overlap: bool | None = None) -> list:
        """The dense-exchange collective contract per bucket, as
        (kind, element-count) pairs in issue order — what the compiled
        step's ENTRY schedule must contain for this plan. Element counts
        (not bytes) because the CPU dry-run upcasts bf16 wires to f32 in
        HLO while the counts survive unchanged.

        ``n_leaves``: total gradient leaves in the step — the
        ``overlap=False`` pin appends one element per leaf to every
        bucket's psum input (see ``_exchange_bucket``), so the observed
        collectives grow by exactly that much when overlap is off.
        ``overlap`` overrides the plan's own mode — the contract checker
        uses the flipped variant to recognize (and report) a step compiled
        under the wrong schedule instead of failing to match at all."""
        if overlap is None:
            overlap = self.overlap
        pin = 0 if overlap else n_leaves
        out = []
        for k, b in enumerate(self.buckets):
            elems = sum(b.sizes) + pin
            if b.schedule == "two_level":
                local = max(self.dims.local_replicas, 1)
                padded = elems + ((-elems) % local)
                colls = [("reduce-scatter", padded // local),
                         ("all-reduce", padded // local),
                         ("all-gather", padded)]
            else:
                colls = [("all-reduce", elems)]
            out.append({"bucket": k, "dtype": b.key[1],
                        "schedule": b.schedule, "collectives": colls})
        return out


def _exchange_dtype(rt, p: Optional[ParamPlan] = None) -> Any:
    """The dtype a dense gradient rides the wire at — mirrors the OPSW cast
    in the unbucketed step (f32 grads drop to the parameter's planned wire
    dtype; everything else ships as-is). Per-parameter: the magnitude-census
    hints can pin individual parameters to f32, and the bucket group key
    includes this dtype so buckets never mix wire precisions."""
    d = jnp.dtype(rt.param_dtype)
    if rt.run_cfg.opsw and d == jnp.dtype(jnp.float32):
        return jnp.dtype(p.wire_dtype) if p is not None else rt.wire_dtype
    return d


def bucketable(plan: Plan, rt) -> bool:
    """Can this plan's dense exchange run as a manual bucketed region?"""
    if plan.mesh is None or rt.run_cfg.bucket_bytes <= 0:
        return False
    if rt.shape_cfg.kind != "train":
        return False
    ba = tuple(rt.batch_axes)
    if not ba or rt.replicas <= 1:
        return False
    # the loss must trace collective-free per replica: no TP/SP/EP axis may
    # be live (the model would need manual collectives this module doesn't
    # write), and MoE opens a nested shard_map of its own.
    for a in plan.mesh.axis_names:
        if a not in ba and plan.mesh.shape[a] != 1:
            return False
    if rt.model_cfg.n_experts > 0:
        return False
    for p in _plan_leaves(plan):
        if not p.sparse and p.method != "allreduce":
            return False          # fsdp pull/push needs its own manual path
        if p.sparse and p.method not in ("allreduce", "mpi_gatherv", "dense"):
            return False          # ps variants need model-axis shards anyway
    return True


def assign_buckets(plan: Plan, rt) -> Optional[BucketPlan]:
    """Group dense all-reduce parameters into fused exchange buffers.

    Greedy first-fit in *reverse* tree-flatten order — reverse-topological
    by the backward pass: the last-forward parameters produce their
    gradients first, so bucket 0 fills (and its collective becomes
    issuable) earliest in the backward. A parameter joins the open bucket
    of its (method, exchange dtype, pspec) group until the bucket reaches
    ``RunConfig.bucket_bytes``, then a new one opens. Sparse parameters
    whose argmin picked a sparse method keep their own exchange. On
    multi-host meshes each bucket also gets its execution schedule
    (ring vs two-level) from the cost-model argmin.

    The tied-embedding coherence rule: under a manual region a gatherv'd
    table gradient would mix a replica-summed sparse part with a local
    dense part (the tied head matmul) — unscalable by one factor. The
    planner resolves it by flipping such tables to the dense bucket
    (pspec is already replicated for mpi_gatherv, so only the method moves).
    """
    if not bucketable(plan, rt):
        return None
    if rt.model_cfg.tie_embeddings and plan.embed_method == "mpi_gatherv":
        def untie(p: ParamPlan):
            if p.sparse and p.method == "mpi_gatherv":
                p.method = "allreduce"
                plan.table_methods[p.name] = "allreduce"
            return p
        jax.tree.map(untie, plan.params,
                     is_leaf=lambda x: isinstance(x, ParamPlan))
        plan.embed_method = "allreduce"

    groups: dict[tuple, list] = {}
    leaves = list(enumerate(_plan_leaves(plan)))
    for i, p in reversed(leaves):        # reverse-topological: see docstring
        if p.method != "allreduce":
            continue
        itemsize = jnp.dtype(_exchange_dtype(rt, p)).itemsize
        cap = max(int(rt.run_cfg.bucket_bytes), itemsize)
        n = p.bytes // jnp.dtype(rt.param_dtype).itemsize
        key = (p.method, jnp.dtype(_exchange_dtype(rt, p)).name,
               _effective_pspec(p.pspec, plan.mesh))
        open_buckets = groups.setdefault(key, [[]])
        if open_buckets[-1] and \
                sum(s for _, s, _ in open_buckets[-1]) * itemsize + \
                n * itemsize > cap:
            open_buckets.append([])
        open_buckets[-1].append((i, n, None))

    hw = cost_model.resolve_hw(rt.run_cfg)
    hosts = cost_model.mesh_hosts(plan.mesh)
    batch_axes = tuple(rt.batch_axes)
    dims = cost_model.MeshDims(data=rt.replicas, hosts=hosts)
    # the two-level schedule needs the host tier as an actual mesh axis to
    # split the psum on: the leading "pod" batch axis (the layout
    # make_production_mesh uses for multi-host worlds)
    can_two_level = (hw.hierarchical and hosts > 1 and len(batch_axes) >= 2
                     and batch_axes[0] == "pod")
    buckets = []
    for key, bs in groups.items():
        itemsize = jnp.dtype(key[1]).itemsize
        for members in bs:
            if not members:
                continue
            idx = tuple(i for i, _, _ in members)
            sizes = tuple(s for _, s, _ in members)
            nbytes = sum(sizes) * itemsize
            schedule = "ring"
            if can_two_level:
                schedule, _ = cost_model.choose_dense_schedule(
                    nbytes, dims, hw)
            buckets.append(Bucket(key=key, idx=idx, sizes=sizes,
                                  nbytes=nbytes, schedule=schedule))
    if not buckets:
        return None
    return BucketPlan(
        buckets=buckets, batch_axes=batch_axes,
        replicas=rt.replicas, n_params=sum(len(b.idx) for b in buckets),
        wire_bytes=sum(b.nbytes for b in buckets),
        bucket_bytes=int(rt.run_cfg.bucket_bytes),
        hw=hw, hosts=hosts,
        overlap=bool(getattr(rt.run_cfg, "overlap", True)),
        n_sparse_push=sum(1 for _, p in leaves
                          if p.sparse and p.method == "mpi_gatherv"))


def fused_apply_eligible(plan: Plan, rt) -> bool:
    """Can the optimizer apply run bucket-natively (optim/optimizer.py
    ``update_fused``)? Needs the bucketed exchange (flat post-psum buffers
    exist), an optimizer with a fused path, replicated optimizer state
    (zero_stage 0 — the flat buffer has no per-leaf dims to ZeRO-shard),
    and OPAU on (the fused global-norm is the partial-sum form)."""
    return bool(plan.bucket_plan is not None
                and getattr(rt.run_cfg, "fused_apply", True)
                and rt.run_cfg.optimizer in ("adamw", "momentum")
                and rt.run_cfg.zero_stage == 0
                and rt.run_cfg.opau)


def plan_buckets(plan: Plan, rt) -> None:
    """Planner hook: (re)compute the bucket assignment for a plan in place.
    Runs after memory escalation so method flips to fsdp veto bucketing;
    re-runs on every replan so the assignment tracks the live plan. Also
    stamps fused-apply eligibility — the optimizer-state layout is part of
    the plan, so replans/remeshes migrate fused state deliberately."""
    plan.bucket_plan = assign_buckets(plan, rt)
    plan.fused_apply = fused_apply_eligible(plan, rt)


# ---------------------------------------------------------------------------
# the fused exchange step
# ---------------------------------------------------------------------------

def _two_level_psum(buf, batch_axes: tuple, local: int):
    """Two-level dense exchange for one flat buffer: intra-host
    reduce-scatter, inter-host all-reduce of the 1/L shard, intra-host
    all-gather. ``batch_axes[0]`` is the host tier ("pod"); the remaining
    axes are the L (= ``local``) intra-host replicas. Elementwise-identical
    to one flat psum — only b/L bytes ever cross the slow tier."""
    inter, intra = batch_axes[0], tuple(batch_axes[1:])
    n = buf.shape[0]
    pad = (-n) % local
    if pad:
        buf = jnp.concatenate([buf, jnp.zeros((pad,), buf.dtype)])
    piece = jax.lax.psum_scatter(buf, intra, scatter_dimension=0, tiled=True)
    piece = jax.lax.psum(piece, inter)
    out = jax.lax.all_gather(piece, intra, axis=0, tiled=True)
    return out[:n] if pad else out


def _exchange_bucket(b: Bucket, gparts: list, scale: float, bp: BucketPlan,
                     census: bool, pin=None):
    """The fused exchange for ONE bucket: flatten → 1/N scale → census →
    wire-dtype cast → psum (ring or two-level) → slice back. ``gparts`` are
    the members' local gradient leaves; returns (exchanged leaves cast back
    to the member dtypes, (|g|inf, rms) census scalars or None, the
    post-psum flat wire buffer — the fused bucket-apply path feeds it to
    the optimizer directly, pin excluded).

    The census reads what rides the wire, pre-cast; downstream the scalars
    join the fused metrics psum so the host sees the replica-*mean* of the
    per-replica maxima — a profile signal for wire-dtype selection
    (sparsity.wire_dtype_hints), not an exact global max.

    ``pin`` (overlap=False): a small vector appended to the psum input and
    sliced off after — a true data dependence on values from every gradient
    leaf, so the scheduler cannot issue this collective before the full
    backward has drained. ``lax.optimization_barrier`` would be the
    idiomatic pin, but the CPU backend expands barriers away before
    scheduling, and the regression baseline must hold everywhere."""
    wdt = jnp.dtype(b.key[1])
    parts = [(g.astype(jnp.float32) * scale).reshape(-1) for g in gparts]
    buf32 = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    stats = None
    if census:
        stats = (jnp.max(jnp.abs(buf32)),
                 jnp.sqrt(jnp.mean(jnp.square(buf32))))
    if pin is not None:
        buf32 = jnp.concatenate([buf32, pin])
    wire = buf32.astype(wdt)
    if b.schedule == "two_level":
        buf = _two_level_psum(wire, bp.batch_axes, bp.dims.local_replicas)
    else:
        buf = jax.lax.psum(wire, bp.batch_axes)   # ONE dense collective
    out, off = [], 0
    for g, sz in zip(gparts, b.sizes):
        out.append(buf[off:off + sz].reshape(g.shape).astype(g.dtype))
        off += sz
    return out, stats, buf[:off]


def make_bucketed_value_and_grad(model, rt, plan: Plan) -> Callable:
    """(params, batch) -> ((loss, metrics), grads), grads pre-aggregated.

    A drop-in for jax.value_and_grad(loss_fn, has_aux=True) whose gradient
    collectives are the bucketed exchange. Inside the manual body gradients
    are grads of the *local* mean loss; since the global loss is the equal-
    weight mean of local losses, the true gradient is their pmean — applied
    as a 1/N pre-scale (mirroring the 1/T the unbucketed mean bakes in)
    followed by the fused psum. Sparse gatherv gradients arrive replica-
    summed from the embedding push and take only the 1/N.

    Overlap (bp.overlap): each bucket's exchange runs inside the backward
    graph as the bwd of an identity ``custom_vjp`` "tap" wrapped around the
    bucket's parameters — applied *inside* the differentiated function, so
    autodiff routes the bucket's cotangents through the exchange at the
    moment its last member gradient is produced. Each tap also takes a
    zeros((2,)) census token whose cotangent smuggles the backward-computed
    (|g|inf, rms) scalars out to the forward metrics. overlap=False pins
    every bucket collective strictly after the full backward with a data-
    dependence pin over all gradient leaves — the scheduling baseline;
    both paths are bit-identical (the exchange is an elementwise psum,
    issue order never changes the math, and the pin is sliced off).
    """
    bp: BucketPlan = plan.bucket_plan
    assert bp is not None and plan.mesh is not None
    leaf = lambda x: isinstance(x, ParamPlan)
    pspecs = jax.tree.map(lambda p: p.pspec, plan.params, is_leaf=leaf)
    bspecs = {
        k: P(*([bp.batch_axes] + [None] * (len(v.shape) - 1)))
        if len(v.shape) else P()
        for k, v in model.input_specs().items()
    }
    heartbeat = bool(getattr(rt.run_cfg, "heartbeat", False))
    if heartbeat:
        # one scalar per replica slot, sharded so each replica holds only
        # its own — the attribution channel rides the fused metrics psum
        bspecs["_heartbeat"] = P(bp.batch_axes)
    scale = 1.0 / bp.replicas
    bucketed = {i for b in bp.buckets for i in b.idx}
    grad_census = bool(getattr(rt.run_cfg, "wire_dtype_auto", False))
    # fused bucket-apply: the optimizer wants the post-psum flat buffers
    # themselves (optim/optimizer.py update_fused), so the step also
    # returns them — under overlap they leave the backward through the tap
    # tokens' cotangents (wire -> f32 is exact for every wire dtype)
    want_bufs = bool(getattr(plan, "fused_apply", False))
    # sparse tables that kept their own exchange: the row-buffer census
    # targets these (their grads never transit a bucket, so without this
    # they could never earn an f32 wire pin)
    sparse_tables = {i: p.name for i, p in enumerate(_plan_leaves(plan))
                     if p.sparse and i not in bucketed}
    # overlap=False defers each eligible gatherv table's push: the lookup
    # VJP returns the locally-densified gradient (no collectives in the
    # backward) and the exchange reruns here, post-backward, behind the
    # same data-dependence pin as the dense buckets — the sparse half of
    # the scheduling baseline. Eligible = the densify round-trip is exact
    # in the table's param/wire dtypes (Runtime.sparse_defer_exact).
    deferred = {}
    if not bp.overlap:
        deferred = {i: (p.name, rt.embed_ctx(p.name))
                    for i, p in enumerate(_plan_leaves(plan))
                    if p.sparse and p.method == "mpi_gatherv"
                    and i not in bucketed
                    and rt.sparse_defer_exact(p.name)}

    def _make_tap(b: Bucket):
        total = sum(b.sizes)
        @jax.custom_vjp
        def tap(leaves, token):
            return leaves
        def fwd(leaves, token):
            return leaves, None
        def bwd(_, cts):
            ex, stats, buf = _exchange_bucket(b, list(cts), scale, bp,
                                              grad_census)
            tok_ct = (jnp.stack(stats) if stats is not None
                      else jnp.zeros((2,), jnp.float32))
            if want_bufs:
                tok_ct = jnp.concatenate([tok_ct, buf.astype(jnp.float32)])
            return tuple(ex), tok_ct
        tap.defvjp(fwd, bwd)
        return tap, 2 + (total if want_bufs else 0)

    taps_and_sizes = [_make_tap(b) for b in bp.buckets]
    taps = [t for t, _ in taps_and_sizes]
    token_sizes = [s for _, s in taps_and_sizes]

    def loss_tapped(params, tokens, batch):
        # taps must wrap the parameters *inside* the differentiated
        # function — wrapping before value_and_grad would leave the tap
        # bwd (the whole exchange) outside the traced gradient path
        pleaves, ptree = jax.tree_util.tree_flatten(params)
        for k, b in enumerate(bp.buckets):
            tapped = taps[k](tuple(pleaves[i] for i in b.idx), tokens[k])
            for j, i in enumerate(b.idx):
                pleaves[i] = tapped[j]
        return model.loss_fn(
            jax.tree_util.tree_unflatten(ptree, pleaves), batch)

    def body(params, batch):
        batch = dict(batch)
        hb = batch.pop("_heartbeat", None)
        bufs = []
        if bp.overlap:
            tokens = tuple(jnp.zeros((n,), jnp.float32)
                           for n in token_sizes)
            with manual_region():
                (loss, metrics), (grads, tgrads) = jax.value_and_grad(
                    loss_tapped, argnums=(0, 1), has_aux=True)(
                        params, tokens, batch)
            metrics = dict(metrics)
            gleaves, gtree = jax.tree_util.tree_flatten(grads)
            out = list(gleaves)       # bucketed leaves already exchanged
            if want_bufs:
                bufs = [tgrads[k][2:] for k in range(len(bp.buckets))]
            if grad_census:
                for k in range(len(bp.buckets)):
                    metrics[f"gbucket{k}_gmax"] = tgrads[k][0]
                    metrics[f"gbucket{k}_grms"] = tgrads[k][1]
        else:
            with manual_region():
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, batch)
            metrics = dict(metrics)
            gleaves, gtree = jax.tree_util.tree_flatten(grads)
            out = list(gleaves)
            # pin every bucket collective strictly after the full
            # backward — the deterministic contrast the overlap scheduling
            # regression tests against. One element of EVERY gradient leaf
            # rides each bucket's psum input (sliced off after): a data
            # dependence the compiler cannot drop, unlike an
            # optimization_barrier (expanded away pre-scheduling on CPU).
            pin = jnp.stack([g.reshape(-1)[0].astype(jnp.float32)
                             for g in gleaves])
            for k, b in enumerate(bp.buckets):
                ex, stats, buf = _exchange_bucket(
                    b, [gleaves[i] for i in b.idx], scale, bp, grad_census,
                    pin=pin)
                for j, i in enumerate(b.idx):
                    out[i] = ex[j]
                if want_bufs:
                    bufs.append(buf.astype(jnp.float32))
                if stats is not None:
                    metrics[f"gbucket{k}_gmax"] = stats[0]
                    metrics[f"gbucket{k}_grms"] = stats[1]
            # deferred sparse push: rerun each eligible gatherv exchange
            # here, behind the same pin, from the locally-densified grad
            # and the dedupe buffer the forward smuggled out via metrics
            for i, (name, ectx) in deferred.items():
                uids = metrics.pop(f"{name}_uids")
                gleaves[i] = embedding.deferred_push(
                    gleaves[i], uids, ectx, pin=pin)
        for i, g in enumerate(gleaves):
            if i in bucketed:
                continue
            # sparse push already exchanged inside the lookup's VJP
            # (replica-summed); only the loss-mean 1/N remains
            g32 = g.astype(jnp.float32) * scale
            if grad_census and i in sparse_tables and g32.ndim >= 2:
                # sparse row-buffer magnitude census: |g|inf and rms over
                # the rows the push actually touched (zero rows excluded —
                # the replica-sum inflates max and rms by the same factor,
                # so the peak-to-rms pin ratio is unaffected)
                name = sparse_tables[i]
                rows = jnp.any(g32 != 0.0, axis=tuple(range(1, g32.ndim)))
                width = g32.size // g32.shape[0]
                nnz = jnp.maximum(jnp.sum(rows.astype(jnp.float32)), 1.0)
                metrics[f"{name}_gmax"] = jnp.max(jnp.abs(g32))
                metrics[f"{name}_grms"] = jnp.sqrt(
                    jnp.sum(jnp.square(g32)) / (nnz * width))
            out[i] = g32.astype(g.dtype)
        grads_out = jax.tree_util.tree_unflatten(gtree, out)

        if hb is not None:
            # per-host straggler attribution (runtime/monitor.py): each
            # replica one-hot-encodes its own heartbeat scalar at N× so the
            # replica-*mean* the fused psum computes decodes back to slot
            # j's raw value — the channel adds D scalars to the existing
            # reduction, zero extra collectives
            slot = jnp.zeros((), jnp.int32)
            for a in bp.batch_axes:
                slot = slot * plan.mesh.shape[a] + jax.lax.axis_index(a)
            for j in range(bp.replicas):
                metrics[f"heartbeat{j}"] = hb[0] * jnp.where(
                    slot == j, float(bp.replicas), 0.0)
        # fused scalar reduction: loss + every scalar metric, one psum;
        # rank>=1 metric leaves (none today) pmean individually — returning
        # them raw through out_specs=P() would silently pass one device's
        # local value off as the global metric
        mleaves, mtree = jax.tree_util.tree_flatten(metrics)
        scalar_pos = [j for j, x in enumerate(mleaves)
                      if jnp.ndim(x) == 0]
        vec = jnp.stack([loss.astype(jnp.float32)] +
                        [mleaves[j].astype(jnp.float32)
                         for j in scalar_pos])
        vec = jax.lax.psum(vec, bp.batch_axes) * scale
        loss_out = vec[0]
        for k, j in enumerate(scalar_pos):
            mleaves[j] = vec[1 + k]
        for j, x in enumerate(mleaves):
            if jnp.ndim(x) > 0:
                mleaves[j] = jax.lax.psum(
                    x.astype(jnp.float32), bp.batch_axes) * scale
        metrics_out = jax.tree_util.tree_unflatten(mtree, mleaves)
        if want_bufs:
            # post-psum buffers are replica-identical; they leave the
            # manual region replicated for the fused optimizer apply
            return loss_out, metrics_out, grads_out, tuple(bufs)
        return loss_out, metrics_out, grads_out

    out_specs = (P(), P(), pspecs)
    if want_bufs:
        out_specs = out_specs + (tuple(P() for _ in bp.buckets),)
    fn = shard_map(body, mesh=plan.mesh, in_specs=(pspecs, bspecs),
                   out_specs=out_specs, check_vma=False)

    def value_and_grad_fn(params, batch):
        if want_bufs:
            loss, metrics, grads, bufs = fn(params, batch)
            return (loss, metrics), grads, list(bufs)
        loss, metrics, grads = fn(params, batch)
        return (loss, metrics), grads

    return value_and_grad_fn
