"""Bucketed dense-gradient exchange (Horovod-style tensor fusion, in JAX).

The hybrid plan minimizes *bytes* on the wire, but under global semantics
XLA materializes one all-reduce per gradient tensor at its producing op —
so a model with n dense parameters pays n per-message latencies (the α in
α + β·b, see core/cost_model.py) however small the tensors are. GSPMD has
no "unreduced" value state, so no downstream concatenation can merge those
collectives; the only place the exchange can be fused is *before* XLA ever
sees a global gradient.

This module therefore traces loss+grad inside one full-manual ``shard_map``
over the mesh: inside, gradients are per-replica partials and aggregation
is written explicitly —

  * dense (method == allreduce) gradients are flattened into a few flat
    wire-dtype buffers of at most ``RunConfig.bucket_bytes`` each, grouped
    by (method, exchange dtype, pspec); each buffer rides ONE psum,
  * the loss and every scalar metric ride a single fused scalar psum,
  * the sparse push keeps its own schedule: the embedding custom_vjp runs
    its per-device body directly on the live named axes (EmbedCtx.manual).

Applicability (``bucketable``): pure data-parallel meshes — every mesh axis
that is not a batch axis has size 1, every dense parameter exchanges by
all-reduce, and the model opens no nested shard_map of its own (MoE EP
does). Anywhere else ``assign_buckets`` returns None and the planner keeps
the per-tensor global-semantics path. Correctness contract: the bucketed
step computes what the unbucketed step computes (same plan, same math;
summation order differs only within float tolerance) — tests/test_perf_paths
asserts the 3-step trajectory at f32 and the collective-count drop in HLO.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.compat import P, shard_map
from repro.core import cost_model
from repro.core.plan import ParamPlan, Plan, plan_leaves
from repro.core.runtime import manual_region
from repro.utils.roofline import HW


def _plan_leaves(plan: Plan) -> list[ParamPlan]:
    # the one flatten order bucket indices are defined against — shared with
    # the trainer's wire_dtype_hints param_names via core/plan.plan_leaves
    return plan_leaves(plan.params)


def _effective_pspec(pspec, mesh) -> tuple:
    """Pspec with size-1 mesh axes dropped — the *physical* layout. Two
    parameters whose pspecs differ only in size-1 axes shard identically,
    so their flattened gradients can share a fused buffer."""
    out = []
    for e in pspec:
        axes = (e,) if isinstance(e, str) else tuple(e or ())
        axes = tuple(a for a in axes if mesh.shape[a] > 1)
        out.append(axes[0] if len(axes) == 1 else (axes or None))
    return tuple(x for x in out if x is not None)


@dataclass(frozen=True)
class Bucket:
    key: tuple        # (method, wire dtype name, pspec entries) group key
    idx: tuple        # leaf positions in the flattened grads/plan tree
    sizes: tuple      # element count per member
    nbytes: int       # fused buffer wire bytes


@dataclass
class BucketPlan:
    buckets: list
    batch_axes: tuple      # the manual/psum axes of the exchange
    replicas: int          # N: product of the batch axis sizes
    n_params: int          # bucketed gradient tensors
    wire_bytes: int        # sum of fused buffer bytes
    bucket_bytes: int      # the RunConfig knob that sized the buckets
    hw: Any = None         # the hardware model the planner priced against

    def stats(self, hw=None) -> dict:
        """Exchange accounting for runtime/monitor.py — the cost-model view
        of what bucketing saved (per step, dense push only), priced with
        the same hardware model the planner's argmin used."""
        hw = hw or self.hw or HW
        ring = 2.0 * (self.replicas - 1) / max(self.replicas, 1)
        return {
            "n_buckets": len(self.buckets),
            "n_params_bucketed": self.n_params,
            "n_collectives_dense": len(self.buckets),
            "n_collectives_unbucketed": self.n_params,
            "wire_bytes": self.wire_bytes,
            "bucket_bytes": self.bucket_bytes,
            "est_seconds": cost_model.exchange_seconds(
                ring * self.wire_bytes, len(self.buckets), hw),
            "est_seconds_unbucketed": cost_model.exchange_seconds(
                ring * self.wire_bytes, self.n_params, hw),
        }


def _exchange_dtype(rt, p: Optional[ParamPlan] = None) -> Any:
    """The dtype a dense gradient rides the wire at — mirrors the OPSW cast
    in the unbucketed step (f32 grads drop to the parameter's planned wire
    dtype; everything else ships as-is). Per-parameter: the magnitude-census
    hints can pin individual parameters to f32, and the bucket group key
    includes this dtype so buckets never mix wire precisions."""
    d = jnp.dtype(rt.param_dtype)
    if rt.run_cfg.opsw and d == jnp.dtype(jnp.float32):
        return jnp.dtype(p.wire_dtype) if p is not None else rt.wire_dtype
    return d


def bucketable(plan: Plan, rt) -> bool:
    """Can this plan's dense exchange run as a manual bucketed region?"""
    if plan.mesh is None or rt.run_cfg.bucket_bytes <= 0:
        return False
    if rt.shape_cfg.kind != "train":
        return False
    ba = tuple(rt.batch_axes)
    if not ba or rt.replicas <= 1:
        return False
    # the loss must trace collective-free per replica: no TP/SP/EP axis may
    # be live (the model would need manual collectives this module doesn't
    # write), and MoE opens a nested shard_map of its own.
    for a in plan.mesh.axis_names:
        if a not in ba and plan.mesh.shape[a] != 1:
            return False
    if rt.model_cfg.n_experts > 0:
        return False
    for p in _plan_leaves(plan):
        if not p.sparse and p.method != "allreduce":
            return False          # fsdp pull/push needs its own manual path
        if p.sparse and p.method not in ("allreduce", "mpi_gatherv", "dense"):
            return False          # ps variants need model-axis shards anyway
    return True


def assign_buckets(plan: Plan, rt) -> Optional[BucketPlan]:
    """Group dense all-reduce parameters into fused exchange buffers.

    Greedy first-fit in tree-flatten order (≈ backward-producer order under
    scan-over-layers): a parameter joins the open bucket of its
    (method, exchange dtype, pspec) group until the bucket reaches
    ``RunConfig.bucket_bytes``, then a new one opens. Sparse parameters
    whose argmin picked a sparse method keep their own exchange.

    The tied-embedding coherence rule: under a manual region a gatherv'd
    table gradient would mix a replica-summed sparse part with a local
    dense part (the tied head matmul) — unscalable by one factor. The
    planner resolves it by flipping such tables to the dense bucket
    (pspec is already replicated for mpi_gatherv, so only the method moves).
    """
    if not bucketable(plan, rt):
        return None
    if rt.model_cfg.tie_embeddings and plan.embed_method == "mpi_gatherv":
        def untie(p: ParamPlan):
            if p.sparse and p.method == "mpi_gatherv":
                p.method = "allreduce"
                plan.table_methods[p.name] = "allreduce"
            return p
        jax.tree.map(untie, plan.params,
                     is_leaf=lambda x: isinstance(x, ParamPlan))
        plan.embed_method = "allreduce"

    groups: dict[tuple, list] = {}
    for i, p in enumerate(_plan_leaves(plan)):
        if p.method != "allreduce":
            continue
        itemsize = jnp.dtype(_exchange_dtype(rt, p)).itemsize
        cap = max(int(rt.run_cfg.bucket_bytes), itemsize)
        n = p.bytes // jnp.dtype(rt.param_dtype).itemsize
        key = (p.method, jnp.dtype(_exchange_dtype(rt, p)).name,
               _effective_pspec(p.pspec, plan.mesh))
        open_buckets = groups.setdefault(key, [[]])
        if open_buckets[-1] and \
                sum(s for _, s, _ in open_buckets[-1]) * itemsize + \
                n * itemsize > cap:
            open_buckets.append([])
        open_buckets[-1].append((i, n, None))

    buckets = []
    for key, bs in groups.items():
        itemsize = jnp.dtype(key[1]).itemsize
        for members in bs:
            if not members:
                continue
            idx = tuple(i for i, _, _ in members)
            sizes = tuple(s for _, s, _ in members)
            buckets.append(Bucket(key=key, idx=idx, sizes=sizes,
                                  nbytes=sum(sizes) * itemsize))
    if not buckets:
        return None
    return BucketPlan(
        buckets=buckets, batch_axes=tuple(rt.batch_axes),
        replicas=rt.replicas, n_params=sum(len(b.idx) for b in buckets),
        wire_bytes=sum(b.nbytes for b in buckets),
        bucket_bytes=int(rt.run_cfg.bucket_bytes),
        hw=cost_model.resolve_hw(rt.run_cfg))


def plan_buckets(plan: Plan, rt) -> None:
    """Planner hook: (re)compute the bucket assignment for a plan in place.
    Runs after memory escalation so method flips to fsdp veto bucketing;
    re-runs on every replan so the assignment tracks the live plan."""
    plan.bucket_plan = assign_buckets(plan, rt)


# ---------------------------------------------------------------------------
# the fused exchange step
# ---------------------------------------------------------------------------

def make_bucketed_value_and_grad(model, rt, plan: Plan) -> Callable:
    """(params, batch) -> ((loss, metrics), grads), grads pre-aggregated.

    A drop-in for jax.value_and_grad(loss_fn, has_aux=True) whose gradient
    collectives are the bucketed exchange. Inside the manual body gradients
    are grads of the *local* mean loss; since the global loss is the equal-
    weight mean of local losses, the true gradient is their pmean — applied
    as a 1/N pre-scale (mirroring the 1/T the unbucketed mean bakes in)
    followed by the fused psum. Sparse gatherv gradients arrive replica-
    summed from the embedding push and take only the 1/N.
    """
    bp: BucketPlan = plan.bucket_plan
    assert bp is not None and plan.mesh is not None
    leaf = lambda x: isinstance(x, ParamPlan)
    pspecs = jax.tree.map(lambda p: p.pspec, plan.params, is_leaf=leaf)
    bspecs = {
        k: P(*([bp.batch_axes] + [None] * (len(v.shape) - 1)))
        if len(v.shape) else P()
        for k, v in model.input_specs().items()
    }
    scale = 1.0 / bp.replicas
    bucketed = {i for b in bp.buckets for i in b.idx}

    def body(params, batch):
        with manual_region():
            (loss, metrics), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
        metrics = dict(metrics)
        grad_census = getattr(rt.run_cfg, "wire_dtype_auto", False)
        gleaves, gtree = jax.tree_util.tree_flatten(grads)
        out = list(gleaves)
        for k, b in enumerate(bp.buckets):
            wdt = jnp.dtype(b.key[1])
            parts = [(gleaves[i].astype(jnp.float32) * scale).reshape(-1)
                     for i in b.idx]
            buf32 = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
            if grad_census:
                # dense-gradient magnitude census: per-bucket |g|inf and rms
                # of what rides the wire, pre-cast. The scalars join the
                # fused metrics psum below, so the host sees the replica-
                # *mean* of the per-replica maxima — a profile signal for
                # wire-dtype selection (sparsity.wire_dtype_hints), not an
                # exact global max. Only traced when the hints have a
                # consumer (wire_dtype_auto).
                metrics[f"gbucket{k}_gmax"] = jnp.max(jnp.abs(buf32))
                metrics[f"gbucket{k}_grms"] = jnp.sqrt(
                    jnp.mean(jnp.square(buf32)))
            buf = jax.lax.psum(buf32.astype(wdt), bp.batch_axes)  # ONE dense
            off = 0                                               # collective
            for i, sz in zip(b.idx, b.sizes):
                out[i] = buf[off:off + sz].reshape(gleaves[i].shape)
                off += sz
        for i, g in enumerate(gleaves):
            if i not in bucketed:
                # sparse push already exchanged inside the lookup's VJP
                # (replica-summed); only the loss-mean 1/N remains
                out[i] = (g.astype(jnp.float32) * scale).astype(g.dtype)
        grads_out = jax.tree_util.tree_unflatten(gtree, out)

        # fused scalar reduction: loss + every scalar metric, one psum;
        # rank>=1 metric leaves (none today) pmean individually — returning
        # them raw through out_specs=P() would silently pass one device's
        # local value off as the global metric
        mleaves, mtree = jax.tree_util.tree_flatten(metrics)
        scalar_pos = [j for j, x in enumerate(mleaves)
                      if jnp.ndim(x) == 0]
        vec = jnp.stack([loss.astype(jnp.float32)] +
                        [mleaves[j].astype(jnp.float32)
                         for j in scalar_pos])
        vec = jax.lax.psum(vec, bp.batch_axes) * scale
        loss_out = vec[0]
        for k, j in enumerate(scalar_pos):
            mleaves[j] = vec[1 + k]
        for j, x in enumerate(mleaves):
            if jnp.ndim(x) > 0:
                mleaves[j] = jax.lax.psum(
                    x.astype(jnp.float32), bp.batch_axes) * scale
        metrics_out = jax.tree_util.tree_unflatten(mtree, mleaves)
        return loss_out, metrics_out, grads_out

    fn = shard_map(body, mesh=plan.mesh, in_specs=(pspecs, bspecs),
                   out_specs=(P(), P(), pspecs), check_vma=False)

    def value_and_grad_fn(params, batch):
        loss, metrics, grads = fn(params, batch)
        return (loss, metrics), grads

    return value_and_grad_fn
