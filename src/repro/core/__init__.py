"""Parallax core: the paper's contribution (hybrid communication, local
aggregation, operation placement, automatic transformation) in JAX."""
from repro.core.runtime import Runtime
from repro.core.plan import Plan, ParamPlan, MeshRules, default_rules
from repro.core.transform import (
    analyze, get_runner, make_train_step, make_decode_step, make_prefill_step,
    state_shardings, batch_shardings, param_shardings,
)
from repro.core import cost_model, sparsity, embedding, xent
