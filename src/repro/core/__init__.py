"""Parallax core: the paper's contribution (hybrid communication, local
aggregation, operation placement, automatic transformation) in JAX."""
from repro.core.runtime import Runtime
from repro.core.plan import Plan, ParamPlan, MeshRules, default_rules, plan_diff
from repro.core.transform import (
    analyze, estimate_census, choose_methods, build_step, get_runner, Runner,
    make_train_step, make_decode_step, make_prefill_step,
    state_shardings, batch_shardings, param_shardings,
)
from repro.core.sparsity import (
    SparsityProfile, observed_census, expected_unique, expected_unique_zipf,
)
from repro.core import buckets, cost_model, sparsity, embedding, xent
from repro.core.buckets import BucketPlan, assign_buckets
