"""Parameter sparsity census (paper §3.2 / Table 1 analogue).

The paper defines sparsity α as "the average ratio of activated parameters
over all parameters" per iteration. In the TF version Parallax classifies a
parameter as sparse if its gradient is an IndexedSlices (i.e. the parameter
is only read through integer gathers). Here the classification is carried by
``ParamSpec.sparse`` (declared where the embedding is built — the JAX
analogue of the auto-diff tap), and α is *estimated* from the workload:

  α ≈ E[#unique ids per replica-step] / vocab_rows

with the expected-unique count under a uniform-draw upper bound
``V·(1 - (1-1/V)^T)`` (exact for uniform ids; an upper bound on duplicates
for any distribution, i.e. a conservative capacity).

Planning-time estimates are only the opening bid: the paper profiles actual
sparsity during early iterations and re-optimizes the transfer plan. The
runtime half of that loop lives here too — ``SparsityProfile`` maintains a
host-side EMA of the in-graph unique-row counts the embedding exchange emits
every step (``*_unique`` metrics), and ``observed_census`` folds the profile
back into a ``Census`` the planner can re-run on (transform.analyze(census=)).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Optional

import jax
import numpy as np

from repro.models.layers import ParamSpec
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig


def expected_unique(tokens: int, vocab: int) -> float:
    """E[#unique] for `tokens` uniform draws from `vocab` rows."""
    if tokens <= 0 or vocab <= 0:
        return 0.0
    return vocab * (1.0 - math.exp(tokens * math.log1p(-1.0 / vocab)))


def zipf_row_probs(vocab: int, a: float, folds: int = 8) -> np.ndarray:
    """P(id == i) when ids are drawn as ``(zipf(a) - 1) % vocab`` (the
    synthetic-corpus scheme in data/pipeline.py).

    Unbounded Zipf ranks fold onto [0, vocab); the first ``folds`` wraps are
    summed exactly and the remaining tail mass (which varies slowly over any
    vocab-sized window at large rank) is spread uniformly.
    """
    if a <= 1.0:
        raise ValueError("zipf exponent must be > 1")
    n = vocab * folds
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** -a
    # zeta(a) ~ partial sum + Euler-Maclaurin tail of the unbounded series
    tail = n ** (1.0 - a) / (a - 1.0) + 0.5 * n ** -a
    z = w.sum() + tail
    p = w.reshape(folds, vocab).sum(axis=0) / z
    return p + (tail / z) / vocab


def expected_unique_zipf(tokens: int, vocab: int, a: float = 1.3) -> float:
    """E[#unique] for `tokens` draws from the folded-Zipf(a) id distribution.

    E[U] = sum_i 1 - (1 - p_i)^T — the skew-aware counterpart of
    ``expected_unique`` (which systematically over-estimates for Zipf ids).
    """
    if tokens <= 0 or vocab <= 0:
        return 0.0
    p = np.minimum(zipf_row_probs(vocab, a), 1.0 - 1e-12)
    return float(np.sum(-np.expm1(tokens * np.log1p(-p))))


@dataclass
class Census:
    dense_params: int
    sparse_params: int
    alpha: float               # per-replica activated fraction of sparse rows
    local_tokens: int
    capacity: int              # static sparse-exchange buffer rows


def run_census(specs: Any, model_cfg: ModelConfig, shape_cfg: ShapeConfig,
               run_cfg: RunConfig, replicas: int) -> Census:
    dense = sparse = 0
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec)):
        n = math.prod(s.shape)
        if s.sparse:
            sparse += n
        else:
            dense += n
    if shape_cfg.kind == "train":
        local_tokens = shape_cfg.tokens // max(replicas, 1)
    elif shape_cfg.kind == "prefill":
        local_tokens = shape_cfg.tokens // max(replicas, 1)
    else:  # decode: one token per sequence per step
        local_tokens = max(shape_cfg.global_batch // max(replicas, 1), 1)
    vocab = model_cfg.vocab_size
    if run_cfg.sparsity_alpha is not None:
        alpha = run_cfg.sparsity_alpha
        uniq = alpha * vocab
    else:
        if run_cfg.zipf_a is not None and vocab:
            uniq = expected_unique_zipf(local_tokens, vocab, run_cfg.zipf_a)
        else:
            uniq = expected_unique(local_tokens, vocab)
        alpha = uniq / vocab if vocab else 0.0
    if run_cfg.capacity_mode == "exact":
        capacity = min(local_tokens, vocab)
    else:
        capacity = min(int(math.ceil(uniq * run_cfg.capacity_factor)), local_tokens, vocab)
    capacity = max(capacity, 8)
    return Census(dense, sparse, alpha, local_tokens, capacity)


# ---------------------------------------------------------------------------
# runtime profile: observed sparsity (the paper's early-iteration profiling)
# ---------------------------------------------------------------------------

@dataclass
class SparsityProfile:
    """Host-side EMA of in-graph unique-row counts per sparse parameter.

    The jitted step emits ``*_unique`` scalar metrics (mean unique ids per
    replica-step, from core/embedding.py's dedupe census); ``update`` folds
    each host-materialized metrics dict into an EMA. ``observed_census``
    turns the profile into a Census the planner re-runs on.
    """
    decay: float = 0.9
    ema: dict = field(default_factory=dict)     # metric name -> EMA count
    last: dict = field(default_factory=dict)    # metric name -> last count
    steps: int = 0                              # steps with census data

    def update(self, metrics: dict) -> None:
        seen = False
        for k, v in metrics.items():
            if not k.endswith("_unique"):
                continue
            try:
                v = float(v)
            except (TypeError, ValueError):
                continue
            seen = True
            self.last[k] = v
            prev = self.ema.get(k)
            self.ema[k] = v if prev is None else \
                self.decay * prev + (1.0 - self.decay) * v
        if seen:
            self.steps += 1

    def ready(self, min_steps: int = 1) -> bool:
        return bool(self.ema) and self.steps >= min_steps

    @property
    def observed_unique(self) -> float:
        """Per-replica unique rows per step (max over sparse params — the
        capacity-binding table)."""
        return max(self.ema.values(), default=0.0)

    def alpha(self, vocab: int) -> float:
        return self.observed_unique / vocab if vocab else 0.0


def observed_census(profile: SparsityProfile, base: Census,
                    vocab: int, run_cfg: RunConfig) -> Census:
    """Fold a runtime profile into a planning Census.

    α and capacity follow the measured EMA; totals and local_tokens stay
    structural (they don't drift at runtime).
    """
    if not profile.ema or vocab <= 0:
        return base
    uniq = min(profile.observed_unique, vocab, base.local_tokens)
    alpha = uniq / vocab
    if run_cfg.capacity_mode == "exact":
        capacity = base.capacity      # exact mode sizes buffers per call-site
    else:
        capacity = min(int(math.ceil(uniq * run_cfg.capacity_factor)),
                       base.local_tokens, vocab)
    capacity = max(capacity, 8)
    return replace(base, alpha=alpha, capacity=capacity)
