"""Parameter sparsity census (paper §3.2 / Table 1 analogue).

The paper defines sparsity α as "the average ratio of activated parameters
over all parameters" per iteration. In the TF version Parallax classifies a
parameter as sparse if its gradient is an IndexedSlices (i.e. the parameter
is only read through integer gathers). Here the classification is carried by
``ParamSpec.sparse`` (declared where the embedding is built — the JAX
analogue of the auto-diff tap), and α is *estimated* from the workload:

  α ≈ E[#unique ids per replica-step] / vocab_rows

with the expected-unique count under a uniform-draw upper bound
``V·(1 - (1-1/V)^T)`` (exact for uniform ids; an upper bound on duplicates
for any distribution, i.e. a conservative capacity).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax

from repro.models.layers import ParamSpec
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig


def expected_unique(tokens: int, vocab: int) -> float:
    """E[#unique] for `tokens` uniform draws from `vocab` rows."""
    if tokens <= 0 or vocab <= 0:
        return 0.0
    return vocab * (1.0 - math.exp(tokens * math.log1p(-1.0 / vocab)))


@dataclass
class Census:
    dense_params: int
    sparse_params: int
    alpha: float               # per-replica activated fraction of sparse rows
    local_tokens: int
    capacity: int              # static sparse-exchange buffer rows


def run_census(specs: Any, model_cfg: ModelConfig, shape_cfg: ShapeConfig,
               run_cfg: RunConfig, replicas: int) -> Census:
    dense = sparse = 0
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec)):
        n = math.prod(s.shape)
        if s.sparse:
            sparse += n
        else:
            dense += n
    if shape_cfg.kind == "train":
        local_tokens = shape_cfg.tokens // max(replicas, 1)
    elif shape_cfg.kind == "prefill":
        local_tokens = shape_cfg.tokens // max(replicas, 1)
    else:  # decode: one token per sequence per step
        local_tokens = max(shape_cfg.global_batch // max(replicas, 1), 1)
    vocab = model_cfg.vocab_size
    if run_cfg.sparsity_alpha is not None:
        alpha = run_cfg.sparsity_alpha
        uniq = alpha * vocab
    else:
        uniq = expected_unique(local_tokens, vocab)
        alpha = uniq / vocab if vocab else 0.0
    if run_cfg.capacity_mode == "exact":
        capacity = min(local_tokens, vocab)
    else:
        capacity = min(int(math.ceil(uniq * run_cfg.capacity_factor)), local_tokens, vocab)
    capacity = max(capacity, 8)
    return Census(dense, sparse, alpha, local_tokens, capacity)
