"""Parameter sparsity census (paper §3.2 / Table 1 analogue).

The paper defines sparsity α as "the average ratio of activated parameters
over all parameters" per iteration. In the TF version Parallax classifies a
parameter as sparse if its gradient is an IndexedSlices (i.e. the parameter
is only read through integer gathers). Here the classification is carried by
``ParamSpec.sparse`` (declared where the embedding is built — the JAX
analogue of the auto-diff tap), and α is *estimated* from the workload:

  α ≈ E[#unique ids per replica-step] / vocab_rows

with the expected-unique count under a uniform-draw upper bound
``V·(1 - (1-1/V)^T)`` (exact for uniform ids; an upper bound on duplicates
for any distribution, i.e. a conservative capacity).

Planning-time estimates are only the opening bid: the paper profiles actual
sparsity during early iterations and re-optimizes the transfer plan. The
runtime half of that loop lives here too — ``SparsityProfile`` maintains a
host-side EMA of the in-graph unique-row counts the embedding exchange emits
every step (``*_unique`` metrics), and ``observed_census`` folds the profile
back into a ``Census`` the planner can re-run on (transform.analyze(census=)).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Optional

import jax
import numpy as np

from repro.models.layers import ParamSpec
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.utils.tree import path_name


def expected_unique(tokens: int, vocab: int) -> float:
    """E[#unique] for `tokens` uniform draws from `vocab` rows."""
    if tokens <= 0 or vocab <= 0:
        return 0.0
    return vocab * (1.0 - math.exp(tokens * math.log1p(-1.0 / vocab)))


def zipf_row_probs(vocab: int, a: float, folds: int = 8) -> np.ndarray:
    """P(id == i) when ids are drawn as ``(zipf(a) - 1) % vocab`` (the
    synthetic-corpus scheme in data/pipeline.py).

    Unbounded Zipf ranks fold onto [0, vocab); the first ``folds`` wraps are
    summed exactly and the remaining tail mass (which varies slowly over any
    vocab-sized window at large rank) is spread uniformly.
    """
    if a <= 1.0:
        raise ValueError("zipf exponent must be > 1")
    n = vocab * folds
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** -a
    # zeta(a) ~ partial sum + Euler-Maclaurin tail of the unbounded series
    tail = n ** (1.0 - a) / (a - 1.0) + 0.5 * n ** -a
    z = w.sum() + tail
    p = w.reshape(folds, vocab).sum(axis=0) / z
    return p + (tail / z) / vocab


def expected_unique_zipf(tokens: int, vocab: int, a: float = 1.3) -> float:
    """E[#unique] for `tokens` draws from the folded-Zipf(a) id distribution.

    E[U] = sum_i 1 - (1 - p_i)^T — the skew-aware counterpart of
    ``expected_unique`` (which systematically over-estimates for Zipf ids).
    """
    if tokens <= 0 or vocab <= 0:
        return 0.0
    p = np.minimum(zipf_row_probs(vocab, a), 1.0 - 1e-12)
    return float(np.sum(-np.expm1(tokens * np.log1p(-p))))


@dataclass
class TableCensus:
    """Per-sparse-table workload record — the planner's unit of decision.

    One entry per sparse parameter (embedding table): its row count, the
    tokens that touch it per replica-step, the expected/observed unique rows,
    and the exchange-buffer capacity derived from them. ``dropped`` carries
    the observed overflow EMA (rows silently zeroed per step under the live
    capacity); ``grown`` marks a capacity raised by the overflow-growth rule.
    """
    name: str
    rows: int                  # table rows (padded vocab)
    tokens: int                # per-replica tokens touching the table / step
    unique: float              # expected (or observed-EMA) unique rows / step
    alpha: float               # unique / rows
    capacity: int
    dropped: float = 0.0
    grown: bool = False


@dataclass
class Census:
    dense_params: int
    sparse_params: int
    alpha: float               # per-replica activated fraction of sparse rows
    local_tokens: int
    capacity: int              # binding (largest) sparse-exchange capacity
    tables: dict = field(default_factory=dict)   # name -> TableCensus
    wire_dtypes: dict = field(default_factory=dict)  # param name -> dtype str
                               # (profiled hints; see wire_dtype_hints)

    def alpha_for(self, name: str) -> float:
        t = self.tables.get(name)
        return t.alpha if t is not None else self.alpha

    def capacity_for(self, name: str) -> int:
        t = self.tables.get(name)
        return t.capacity if t is not None else self.capacity


def _per_table(run_cfg: RunConfig, name: str, rows: int, tokens: int):
    """(unique, alpha) for one table under its declared workload model:
    per-table declarations (alpha, then zipf) beat the global knobs
    (sparsity_alpha, then zipf_a, then the uniform bound)."""
    t_alpha = dict(run_cfg.table_alpha).get(name)
    if t_alpha is not None:
        return t_alpha * rows, t_alpha
    t_zipf = dict(run_cfg.table_zipf).get(name)
    if t_zipf is None:
        if run_cfg.sparsity_alpha is not None:
            return run_cfg.sparsity_alpha * rows, run_cfg.sparsity_alpha
        t_zipf = run_cfg.zipf_a
    if t_zipf is not None and rows:
        uniq = expected_unique_zipf(tokens, rows, t_zipf)
    else:
        uniq = expected_unique(tokens, rows)
    return uniq, (uniq / rows if rows else 0.0)


def _capacity(run_cfg: RunConfig, uniq: float, tokens: int, rows: int) -> int:
    if run_cfg.capacity_mode == "exact":
        cap = min(tokens, rows)
    else:
        cap = min(int(math.ceil(uniq * run_cfg.capacity_factor)), tokens, rows)
    return max(cap, 8)


def run_census(specs: Any, model_cfg: ModelConfig, shape_cfg: ShapeConfig,
               run_cfg: RunConfig, replicas: int) -> Census:
    dense = sparse = 0
    tables: dict[str, TableCensus] = {}
    leaves, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    if shape_cfg.kind in ("train", "prefill"):
        local_tokens = shape_cfg.tokens // max(replicas, 1)
    else:  # decode: one token per sequence per step
        local_tokens = max(shape_cfg.global_batch // max(replicas, 1), 1)
    for path, s in leaves:
        n = math.prod(s.shape)
        if s.sparse:
            sparse += n
            name = path_name(path)    # the shared dotted-name scheme: keys
            rows = s.shape[0]         # here must match ParamPlan.name
            uniq_t, alpha_t = _per_table(run_cfg, name, rows, local_tokens)
            tables[name] = TableCensus(
                name=name, rows=rows, tokens=local_tokens, unique=uniq_t,
                alpha=alpha_t,
                capacity=_capacity(run_cfg, uniq_t, local_tokens, rows))
        else:
            dense += n
    # legacy binding aggregates (kept bit-compatible with the scalar-era
    # planner): alpha from the unpadded vocab under the *global* knobs,
    # capacity = the worst table's
    vocab = model_cfg.vocab_size
    if run_cfg.sparsity_alpha is not None:
        alpha = run_cfg.sparsity_alpha
        uniq = alpha * vocab
    else:
        if run_cfg.zipf_a is not None and vocab:
            uniq = expected_unique_zipf(local_tokens, vocab, run_cfg.zipf_a)
        else:
            uniq = expected_unique(local_tokens, vocab)
        alpha = uniq / vocab if vocab else 0.0
    capacity = _capacity(run_cfg, uniq, local_tokens, vocab)
    if tables:
        capacity = max(capacity, max(t.capacity for t in tables.values()))
    return Census(dense, sparse, alpha, local_tokens, capacity, tables=tables)


# ---------------------------------------------------------------------------
# runtime profile: observed sparsity (the paper's early-iteration profiling)
# ---------------------------------------------------------------------------

# metric suffixes the profile EMAs: the sparse census (unique rows,
# overflow) and the dense-gradient magnitude census (per-bucket |g|inf/rms)
_PROFILE_SUFFIXES = ("_unique", "_dropped", "_gmax", "_grms")


@dataclass
class SparsityProfile:
    """Host-side EMA of the in-graph workload census, one entry per metric.

    The jitted step emits ``{table}_unique`` / ``{table}_dropped`` scalars
    per sparse table (core/embedding.py's dedupe census) and — under the
    bucketed exchange — ``gbucket{i}_gmax`` / ``gbucket{i}_grms`` dense-
    gradient magnitude scalars (core/buckets.py); ``update`` folds each
    host-materialized metrics dict into per-metric EMAs. ``observed_census``
    turns the profile into a Census the planner re-runs on.
    """
    decay: float = 0.9
    ema: dict = field(default_factory=dict)     # metric name -> EMA count
    last: dict = field(default_factory=dict)    # metric name -> last count
    steps: int = 0                              # steps with census data

    def update(self, metrics: dict) -> None:
        seen = False
        for k, v in metrics.items():
            if not k.endswith(_PROFILE_SUFFIXES):
                continue
            try:
                v = float(v)
            except (TypeError, ValueError):
                continue
            seen = seen or k.endswith("_unique")
            self.last[k] = v
            prev = self.ema.get(k)
            self.ema[k] = v if prev is None else \
                self.decay * prev + (1.0 - self.decay) * v
        if seen:
            self.steps += 1

    def ready(self, min_steps: int = 1) -> bool:
        return bool(self.ema) and self.steps >= min_steps

    @property
    def observed_unique(self) -> float:
        """Per-replica unique rows per step (max over sparse params — the
        capacity-binding table)."""
        return max((v for k, v in self.ema.items() if k.endswith("_unique")),
                   default=0.0)

    def unique_for(self, table: str) -> Optional[float]:
        return self.ema.get(f"{table}_unique")

    def dropped_for(self, table: str) -> float:
        return self.ema.get(f"{table}_dropped", 0.0)

    def dropped(self, tables=None) -> dict:
        """Per-table overflow EMA (rows silently zeroed per step) — the
        signal the monitor surfaces and the growth rule acts on. ``tables``
        (any container of table names) restricts the sweep to real sparse
        tables: other subsystems also emit ``*_dropped`` scalars (e.g. the
        MoE router's ``moe_dropped``) that are not buffer overflow."""
        out = {k[:-len("_dropped")]: v for k, v in self.ema.items()
               if k.endswith("_dropped")}
        if tables is not None:
            out = {k: v for k, v in out.items() if k in tables}
        return out

    def alpha(self, vocab: int) -> float:
        return self.observed_unique / vocab if vocab else 0.0

    def reset_grad_census(self) -> None:
        """Drop the per-bucket magnitude EMAs. Bucket metrics are keyed by
        *index*; after a replan regroups the buckets, index i names a
        different member set, and blending old-layout samples into its EMA
        would mis-attribute magnitudes across parameters."""
        for d in (self.ema, self.last):
            for k in [k for k in d if k.startswith("gbucket")]:
                del d[k]


def observed_census(profile: SparsityProfile, base: Census,
                    vocab: int, run_cfg: RunConfig,
                    live: Optional[dict] = None) -> Census:
    """Fold a runtime profile into a planning Census.

    Per-table: each table whose ``{name}_unique`` EMA has data gets its own
    measured α and capacity; a table whose ``{name}_dropped`` EMA stays above
    ``run_cfg.overflow_tolerance`` gets *grown* capacity — measured demand
    times ``capacity_factor * capacity_growth`` headroom (overflow means the
    live buffer is provably too small; the plain re-fit alone could sit
    inside the replan drift deadband forever). Totals and local_tokens stay
    structural (they don't drift at runtime).

    ``live`` ({table: (capacity, grown)} from the running plan — the
    trainer passes it) makes growth *sticky*: once the overflow stops, the
    dropped EMA decays below tolerance, and a bare re-fit would shrink the
    buffer by exactly ``capacity_growth`` — tripping the drift rule and
    re-introducing the overflow in an endless grow/shrink/recompile cycle.
    A previously-grown table therefore keeps growth-headroom sizing
    (``ceil(unique · factor · growth)``) — once a buffer has overflowed it
    stays provisioned with headroom, still tracking the demand EMA downward.
    """
    if not profile.ema or vocab <= 0:
        return base
    uniq = min(profile.observed_unique, vocab, base.local_tokens)
    alpha = uniq / vocab
    if run_cfg.capacity_mode == "exact":
        capacity = base.capacity      # exact mode sizes buffers per call-site
    else:
        capacity = min(int(math.ceil(uniq * run_cfg.capacity_factor)),
                       base.local_tokens, vocab)
    capacity = max(capacity, 8)
    tables = {}
    for name, t in base.tables.items():
        obs = profile.unique_for(name)
        if obs is None or run_cfg.capacity_mode == "exact":
            tables[name] = t
            continue
        # clip observed demand at rows only: a table on the dense/allreduce
        # path dedupes *global* ids, so its true unique count legitimately
        # exceeds the per-replica token estimate (lookup() re-clips the
        # buffer to its call-site token count anyway)
        uniq_t = min(obs, t.rows)
        cap_fit = max(min(int(math.ceil(uniq_t * run_cfg.capacity_factor)),
                          t.rows), 8)
        headroom = min(int(math.ceil(uniq_t * run_cfg.capacity_factor *
                                     run_cfg.capacity_growth)), t.rows)
        dropped_t = profile.dropped_for(name)
        live_cap, live_grown = (live or {}).get(name, (0, False))
        if dropped_t > run_cfg.overflow_tolerance:
            cap_t, grown = max(cap_fit, headroom), True
        elif live_grown:
            # sticky growth (see docstring): hold headroom sizing, tracking
            # the demand EMA downward, never snapping back to the bare fit
            cap_t = max(cap_fit, min(max(live_cap, cap_fit), headroom))
            grown = cap_t > cap_fit
        else:
            cap_t, grown = cap_fit, False
        tables[name] = replace(t, unique=uniq_t,
                               alpha=uniq_t / t.rows if t.rows else 0.0,
                               capacity=cap_t, dropped=dropped_t, grown=grown)
    if tables:
        capacity = max(capacity, max(t.capacity for t in tables.values()))
    return replace(base, alpha=alpha, capacity=capacity, tables=tables)


def wire_dtype_hints(profile: SparsityProfile, bucket_plan: Any,
                     param_names: list, *, outlier_ratio: float,
                     default: str = "bfloat16",
                     sparse_tables: Any = ()) -> dict:
    """Profiled per-parameter wire-dtype selection from the gradient
    magnitude census.

    Each bucket's ``gbucket{i}_gmax`` / ``gbucket{i}_grms`` EMAs summarize
    the magnitudes its member gradients ride the wire at. A bucket whose
    peak-to-rms ratio exceeds ``outlier_ratio`` is outlier-prone: bf16's
    ~8-bit mantissa quantizes the small-magnitude bulk relative to the
    outliers, so its members keep float32 on the wire; everybody else rides
    ``default``. Returns {param name -> dtype str} for Census.wire_dtypes.

    ``sparse_tables`` extends the same rule to sparse row-buffer pushes:
    a table that kept its own exchange emits ``{table}_gmax`` /
    ``{table}_grms`` scalars (core/buckets.py measures the densified
    post-exchange grad over the rows the push touched), so an
    outlier-prone table pins its row buffer to float32 too — without this
    the sparse push could never earn a pin.
    """
    hints: dict[str, str] = {}

    def judge(key_prefix: str):
        gmax = profile.ema.get(f"{key_prefix}_gmax")
        grms = profile.ema.get(f"{key_prefix}_grms")
        if gmax is None or grms is None:
            return None
        return "float32" if gmax > outlier_ratio * max(grms, 1e-30) \
            else default

    if bucket_plan is not None:
        for i, b in enumerate(bucket_plan.buckets):
            choice = judge(f"gbucket{i}")
            if choice is None:
                continue
            for j in b.idx:
                hints[param_names[j]] = choice
    for name in sparse_tables:
        choice = judge(name)
        if choice is not None:
            hints[name] = choice
    return hints
