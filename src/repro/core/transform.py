"""Automatic graph transformation (paper §5) — the Parallax API.

Planning is a pipeline of pure stages so it can be re-entered at runtime
with *observed* (not estimated) workload parameters:

``estimate_census``  workload-model census (uniform/Zipf analytic α).
``choose_methods``   census -> Plan via the Table-3 cost model (incl. ZeRO
                     escalation under the per-chip memory budget).
``analyze``          the one-shot composition of the two (census optional —
                     pass an observed census to replan without rebuilding
                     the model).
``build_step``       the shared state/sharding/jit assembly used by both
                     ``get_runner`` and ``runtime.trainer.Trainer``.
``make_train_step`` / ``make_decode_step``
              build the distributed jit-ready step functions with
              in/out shardings derived from the plan. The correctness
              contract (paper §3.1): the distributed step computes exactly
              what the single-device step computes at equal global batch —
              asserted by tests/test_transform.py.
``get_runner`` the user-facing two-line API (paper Table 2 analogue);
              ``Runner.replan(census)`` hot-swaps the jitted step onto a
              plan recomputed from a measured census (paper §5's profile →
              re-optimize loop).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.compat import Mesh, NamedSharding, P
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core import buckets, cost_model, sparsity
from repro.core.plan import (MeshRules, ParamPlan, Plan, add_fsdp,
                             default_rules, per_device_bytes, plan_diff,
                             plan_leaves, _pspec_shards)
from repro.core.runtime import Runtime
from repro.models.layers import ParamSpec
from repro.models.model import Model, build_model
from repro.optim.optimizer import (Optimizer, TrainState, fuse_state,
                                   is_fused, make_optimizer, unfuse_state)
from repro.utils.tree import named_leaves, path_name as tree_path_name
from repro.utils.roofline import HW


def _mesh_dims(mesh: Optional[Mesh], rules: MeshRules) -> cost_model.MeshDims:
    if mesh is None:
        return cost_model.MeshDims()
    get = lambda a: mesh.shape.get(a, 1) if hasattr(mesh.shape, "get") else \
        (mesh.shape[a] if a in mesh.axis_names else 1)
    return cost_model.MeshDims(
        model=get("model") if "model" in mesh.axis_names else 1,
        data=get("data") if "data" in mesh.axis_names else 1,
        pod=get("pod") if "pod" in mesh.axis_names else 1,
        hosts=cost_model.mesh_hosts(mesh),
    )


def estimate_census(model: Model, rt: Runtime) -> sparsity.Census:
    """Stage 1: the build-time workload-model census (estimated α)."""
    dims = _mesh_dims(rt.mesh, rt.rules)
    return sparsity.run_census(model.specs(), rt.model_cfg, rt.shape_cfg,
                               rt.run_cfg, dims.replicas)


def analyze(model: Model, rt: Runtime,
            memory_budget: float = 0.9 * HW.hbm_bytes,
            census: Optional[sparsity.Census] = None,
            stale_tables: tuple = ()) -> Plan:
    """Census + cost model -> Plan (the paper's analysis phase).

    Pass ``census`` (e.g. an observed one from a SparsityProfile) to replan
    from measured sparsity; by default the workload-model estimate is used.
    ``stale_tables`` names sparse tables running the bounded-staleness push
    (the jitter fallback) — stamped onto the plan so the train step builds
    the stale update rule for exactly those tables.
    """
    if census is None:
        census = estimate_census(model, rt)
    return choose_methods(model, rt, census, memory_budget,
                          stale_tables=stale_tables)


def choose_methods(model: Model, rt: Runtime, census: sparsity.Census,
                   memory_budget: float = 0.9 * HW.hbm_bytes,
                   stale_tables: tuple = ()) -> Plan:
    """Stage 2: pure census -> Plan (Table-3 argmin + memory escalation)."""
    specs = model.specs()
    dims = _mesh_dims(rt.mesh, rt.rules)
    comm_mode = rt.run_cfg.comm_mode
    hw = cost_model.resolve_hw(rt.run_cfg)

    can_shard_rows = rt.rules.axis_size("vocab") > 1
    strategy = getattr(rt, "resolved_strategy", rt.run_cfg.dense_strategy)
    table_methods: dict[str, str] = {}
    table_capacity: dict[str, int] = {}
    table_wire: dict[str, Any] = {}
    table_alpha: dict[str, float] = {}
    table_serve: dict[str, dict] = {}
    serving = rt.shape_cfg.kind == "decode"
    # bounded-staleness eligibility: only tables with their own sparse
    # exchange can defer their apply (dense-routed tables ride the
    # synchronous buckets by construction), and only when the machinery is
    # on at all (max_staleness > 0 allocates the state buffer)
    stale_requested = set(stale_tables) \
        if getattr(rt.run_cfg, "max_staleness", 0) > 0 else set()
    stale_stamped: set[str] = set()

    def _wire_for(name: str):
        """OPSW wire dtype for one parameter: the census's profiled hint
        (magnitude-census wire_dtype_hints) when present, else the global
        knob. Hints only matter when OPSW casting is on at all."""
        hint = census.wire_dtypes.get(name)
        if hint is not None and rt.run_cfg.opsw:
            return jnp.dtype(hint)
        return rt.wire_dtype

    def plan_leaf(name: str, spec: ParamSpec) -> ParamPlan:
        b = math.prod(spec.shape) * jnp.dtype(rt.param_dtype).itemsize
        # per-parameter pricing: each sparse table argmins at its *own*
        # activated fraction, so a Zipf vocab table and a near-dense
        # secondary table legitimately land on different methods
        alpha = census.alpha_for(name) if spec.sparse else census.alpha
        method, costs = cost_model.choose_method(
            b=b, sparse=spec.sparse, alpha=alpha, dims=dims,
            comm_mode=comm_mode, can_shard_rows=can_shard_rows, hw=hw)
        pspec = rt.rules.pspec(spec.axes, spec.shape)
        capacity = 0
        wire = _wire_for(name)
        if spec.sparse:
            capacity = census.capacity_for(name)
            if method in ("allreduce", "dense") and rt.mesh is not None \
                    and rt.run_cfg.capacity_mode == "capped":
                # near-dense tables routed to the dense path dedupe once over
                # the *global* batch (core/embedding.py lookup sizes its
                # buffer by ids.size there), so the per-replica Zipf estimate
                # misprices them — often undersized by ~N_replicas. Size
                # exactly: a global dedupe can never exceed global tokens or
                # the table's rows, and at that bound it never drops.
                capacity = min(rt.shape_cfg.tokens, spec.shape[0])
            table_methods[name] = method if rt.mesh is not None else "dense"
            table_capacity[name] = capacity
            table_wire[name] = wire
            table_alpha[name] = float(alpha)
            if serving:
                # serve-mesh pricing at decode batch shapes: the per-step
                # pull wire and per-token exchange seconds this table costs
                # the engine under its chosen method (one token per
                # sequence per decode step)
                table_serve[name] = cost_model.serve_table_pricing(
                    b=b, alpha=float(alpha), method=table_methods[name],
                    dims=dims, batch_tokens=rt.shape_cfg.global_batch,
                    hw=hw)
            if method in ("mpi_gatherv", "allreduce"):
                # table replicated (paper's MPI baseline / dense-AR pick)
                pspec = P(*([None] * len(spec.shape)))
        stale = bool(spec.sparse and name in stale_requested
                     and method in ("ps", "ps_gather", "mpi_gatherv"))
        if stale:
            stale_stamped.add(name)
        if method == "fsdp" and rt.mesh is not None:
            pspec = add_fsdp(pspec, spec.shape, rt.mesh, strategy)
        opt_pspec = pspec
        if rt.run_cfg.zero_stage >= 1 and rt.mesh is not None and not spec.sparse:
            opt_pspec = add_fsdp(pspec, spec.shape, rt.mesh, strategy)
        return ParamPlan(name=name, method=method, pspec=pspec,
                         opt_pspec=opt_pspec, wire_dtype=wire,
                         sparse=spec.sparse, bytes=int(b), capacity=capacity,
                         stale=stale, est_cost=costs)

    plans = jax.tree_util.tree_map_with_path(
        lambda path, s: plan_leaf(tree_path_name(path), s),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))

    # the "embed" table binds the legacy scalar; any sparse table otherwise
    embed_method = table_methods.get(
        "embed", next(iter(table_methods.values()), "dense"))
    plan = Plan(model_cfg=rt.model_cfg, run_cfg=rt.run_cfg,
                shape_cfg=rt.shape_cfg, mesh=rt.mesh, rules=rt.rules,
                params=plans, alpha=census.alpha, capacity=census.capacity,
                zero_stage=rt.run_cfg.zero_stage, embed_method=embed_method,
                table_methods=table_methods, table_capacity=table_capacity,
                table_wire=table_wire, table_alpha=table_alpha,
                table_serve=table_serve,
                grown_tables=tuple(sorted(
                    n for n, t in census.tables.items() if t.grown)),
                stale_tables=tuple(sorted(stale_stamped)))

    # ---- memory escalation: replicate -> ZeRO-1 -> ZeRO-3 (auto-PS) ----
    if rt.mesh is not None:
        for stage in (rt.run_cfg.zero_stage, 1, 3):
            bytes_est = per_device_bytes(specs, rt.rules, plan.params)
            if bytes_est <= memory_budget:
                break
            plan = _escalate(plan, specs, rt, stage if stage else 1)
        plan.zero_stage = max(plan.zero_stage, 0)
    # bucket the dense exchange (after escalation: fsdp flips veto it);
    # replans re-enter here, so the assignment always tracks the live plan
    buckets.plan_buckets(plan, rt)
    return plan


def _escalate(plan: Plan, specs, rt: Runtime, stage: int) -> Plan:
    """Raise the ZeRO stage: shard optimizer state (1) then params (3)."""
    strategy = getattr(rt, "resolved_strategy", rt.run_cfg.dense_strategy)

    def esc(spec: ParamSpec, p: ParamPlan) -> ParamPlan:
        if spec.sparse:
            return p
        new = p
        opt = add_fsdp(p.pspec, spec.shape, rt.mesh, strategy)
        new = replace(new, opt_pspec=opt)
        if stage >= 3 and p.method == "allreduce":
            new = replace(new, method="fsdp",
                          pspec=add_fsdp(p.pspec, spec.shape, rt.mesh, strategy),
                          opt_pspec=add_fsdp(p.pspec, spec.shape, rt.mesh,
                                             strategy))
        return new

    new_params = jax.tree.map(
        esc, specs, plan.params,
        is_leaf=lambda x: isinstance(x, (ParamSpec, ParamPlan)))
    plan.params = new_params
    plan.zero_stage = stage
    return plan


# ---------------------------------------------------------------------------
# shardings for state / batch
# ---------------------------------------------------------------------------

def _ns(mesh, pspec):
    return NamedSharding(mesh, pspec)


def param_shardings(plan: Plan):
    if plan.mesh is None:
        return None
    return jax.tree.map(lambda p: _ns(plan.mesh, p.pspec), plan.params,
                        is_leaf=lambda x: isinstance(x, ParamPlan))


def opt_shardings(plan: Plan):
    if plan.mesh is None:
        return None
    return jax.tree.map(lambda p: _ns(plan.mesh, p.opt_pspec), plan.params,
                        is_leaf=lambda x: isinstance(x, ParamPlan))


def state_shardings(plan: Plan, state_like: TrainState):
    """TrainState shardings (moments follow opt_pspec; ema follows param).

    Fused bucket-apply states (optim/optimizer.py ``fuse_state``) hold each
    moment as {"bucket": [flat f32 buffers], "leaf": per-param tree with
    None at bucketed positions}: the buffers are post-psum replicated values
    (fused apply needs zero_stage 0), so they shard as P(); the surviving
    unbucketed leaves keep their planned pspecs, and the None placeholders
    mirror over to the sharding tree (empty subtrees carry no sharding).
    """
    if plan.mesh is None:
        return None
    ps = param_shardings(plan)
    os = opt_shardings(plan)
    rep = _ns(plan.mesh, P())

    def moment(live, per_leaf):
        if live is None:
            return None
        if isinstance(live, dict) and set(live) == {"bucket", "leaf"}:
            shl, shdef = jax.tree_util.tree_flatten(per_leaf)
            for b in plan.bucket_plan.buckets:
                for i in b.idx:
                    shl[i] = None
            leaf = jax.tree_util.tree_unflatten(shdef, shl)
            return {"bucket": [rep] * len(live["bucket"]), "leaf": leaf}
        return per_leaf

    def stale_sh(stale_like):
        # staleness buffers: each table's "g" mirrors the table's param
        # sharding (it is a gradient-shaped buffer), "age" is a replicated
        # scalar — post-exchange grads are replica-identical, so the buffer
        # never needs its own collective
        if stale_like is None:
            return None
        by_name = {p.name: p.pspec for p in plan_leaves(plan.params)}
        return {n: {"g": _ns(plan.mesh, by_name[n]), "age": rep}
                for n in stale_like}

    return TrainState(
        step=rep,
        params=ps,
        m=moment(state_like.m, os),
        v=moment(state_like.v, os),
        ema=moment(state_like.ema, ps),
        stale=stale_sh(getattr(state_like, "stale", None)),
    )


def batch_shardings(plan: Plan, batch_specs: dict):
    if plan.mesh is None:
        return None
    ba = plan.rules.rules.get("batch")
    out = {}
    for k, v in batch_specs.items():
        spec = [ba] + [None] * (len(v.shape) - 1) if len(v.shape) else []
        out[k] = _ns(plan.mesh, P(*spec))
    return out


# ---------------------------------------------------------------------------
# bounded-staleness buffers (the jitter fallback's train-state leg)
# ---------------------------------------------------------------------------

def stale_buffer_tables(plan: Plan, rt: Runtime) -> tuple:
    """Tables that carry a staleness buffer in the train state: every
    sparse table with its own sparse exchange, whenever the machinery is on
    (``max_staleness > 0``). Deliberately independent of which tables are
    currently *flipped* stale — the buffer pytree stays structurally
    constant across sync<->stale flips, so checkpoints, sharding templates,
    and donation never churn with the jitter state."""
    if getattr(rt.run_cfg, "max_staleness", 0) <= 0:
        return ()
    return tuple(sorted(
        p.name for p in plan_leaves(plan.params)
        if p.sparse and p.method in ("ps", "ps_gather", "mpi_gatherv")))


def ensure_stale_buffers(state: TrainState, plan: Plan,
                         rt: Runtime) -> TrainState:
    """Attach (or drop) the staleness buffer pytree for a plan: zero f32
    grad buffers + int32 ages for every eligible table. Existing buffers
    whose shapes still match carry across (a replan/remesh mid-stale-window
    must not silently discard a buffered gradient); shape changes and
    de-listed tables re-zero."""
    names = stale_buffer_tables(plan, rt)
    old = getattr(state, "stale", None)
    if not names:
        return state._replace(stale=None) if old is not None else state
    by_idx = {p.name: i for i, p in enumerate(plan_leaves(plan.params))}
    pleaves = jax.tree_util.tree_leaves(state.params)
    old = old or {}
    new = {}
    for n in names:
        shape = tuple(pleaves[by_idx[n]].shape)
        o = old.get(n)
        if o is not None and tuple(np.shape(o["g"])) == shape:
            new[n] = o
        else:
            new[n] = {"g": jnp.zeros(shape, jnp.float32),
                      "age": jnp.zeros((), jnp.int32)}
    return state._replace(stale=new)


def _make_staleness_rule(plan: Plan, rt: Runtime) -> Callable:
    """The per-table gradient rewrite between exchange and optimizer:

      stale table:  apply the *buffered* (previous step's) exchanged
                    gradient, buffer the fresh one — the exchange itself
                    still runs every step, so every replica buffers the
                    same aggregate and the state stays replica-consistent;
      sync table:   apply fresh + buffered, zero the buffer — ordinary
                    steps add an exact zero, and the first step after a
                    stale->sync flip automatically drains the last buffered
                    gradient (no separate drain step to schedule).

    Emits ``staleness_age`` (max applied age over stale tables) and
    ``staleness_violation`` (sum of relu(age - max_staleness)) — the
    in-graph bound the acceptance contract asserts on."""
    stale_set = frozenset(getattr(plan, "stale_tables", ()))
    smax = int(getattr(rt.run_cfg, "max_staleness", 0))
    sparse_idx = {p.name: i for i, p in enumerate(plan_leaves(plan.params))
                  if p.sparse}

    def apply_rule(stale, grads, metrics):
        if stale is None:
            return None, grads, metrics
        gleaves, gtree = jax.tree_util.tree_flatten(grads)
        new_stale, ages = {}, []
        for name, buf in stale.items():
            i = sparse_idx[name]
            g = gleaves[i]
            if name in stale_set:
                age = buf["age"] + 1
                ages.append(age)
                gleaves[i] = buf["g"].astype(g.dtype)
                new_stale[name] = {"g": g.astype(jnp.float32),
                                   "age": jnp.zeros((), jnp.int32)}
            else:
                gleaves[i] = (g.astype(jnp.float32)
                              + buf["g"]).astype(g.dtype)
                new_stale[name] = {"g": jnp.zeros_like(buf["g"]),
                                   "age": jnp.zeros((), jnp.int32)}
        if ages:
            age_max = ages[0]
            for a in ages[1:]:
                age_max = jnp.maximum(age_max, a)
            metrics["staleness_age"] = age_max
            metrics["staleness_violation"] = sum(
                jnp.maximum(a - smax, 0) for a in ages)
        return (new_stale,
                jax.tree_util.tree_unflatten(gtree, gleaves), metrics)

    return apply_rule


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(model: Model, optimizer: Optimizer, rt: Runtime,
                    plan: Plan) -> Callable:
    """(state, batch) -> (state, metrics); grads flow through the plan.

    With a bucket plan, loss+grad run inside core/buckets.py's manual
    exchange region: dense gradients arrive pre-aggregated over a few fused
    collectives (already at the wire dtype — the OPSW cast lives in the
    exchange), and the optimizer consumes them per-tensor as always — or,
    when the plan stamps ``fused_apply``, bucket-natively: the exchange also
    hands back the post-psum flat buffers and ``optimizer.update_fused``
    applies straight from them against the fused state layout.
    """
    stale_rule = _make_staleness_rule(plan, rt)
    heartbeat = bool(getattr(rt.run_cfg, "heartbeat", False))
    if plan.bucket_plan is not None:
        if getattr(plan, "fused_apply", False) \
                and optimizer.update_fused is None:
            plan.fused_apply = False      # e.g. sgd: per-param only
        value_and_grad = buckets.make_bucketed_value_and_grad(model, rt, plan)
        if plan.fused_apply:
            bp = plan.bucket_plan

            def train_step_fused(state: TrainState, batch: dict):
                (loss, metrics), grads, bufs = value_and_grad(
                    state.params, batch)
                metrics = dict(metrics)
                new_stale, grads, metrics = stale_rule(
                    getattr(state, "stale", None), grads, metrics)
                new_state, opt_metrics = optimizer.update_fused(
                    state, grads, bufs, bp)
                new_state = new_state._replace(stale=new_stale)
                metrics.update(opt_metrics)
                metrics["loss"] = loss
                return new_state, metrics

            return train_step_fused
    else:
        def value_and_grad(params, batch):
            out, grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
            # OPSW: dense grads ride collectives at each parameter's planned
            # wire dtype (profiled per-bucket magnitude census can pin
            # outlier-prone parameters to f32). In global semantics the
            # aggregation psum is XLA-inserted at the dtype the gradient
            # tensors carry — so cast before the constraint boundary.
            if rt.run_cfg.opsw:
                grads = jax.tree.map(
                    lambda g, p: g.astype(p.wire_dtype)
                    if g.dtype == jnp.float32 else g, grads, plan.params)
            return out, grads

    unbucketed_hb = heartbeat and plan.bucket_plan is None

    def train_step(state: TrainState, batch: dict):
        hb = None
        if unbucketed_hb:
            # no manual region to one-hot-encode in: the global-semantics
            # heartbeat vector is already per-slot, echo it as metrics
            batch = dict(batch)
            hb = batch.pop("_heartbeat", None)
        (loss, metrics), grads = value_and_grad(state.params, batch)
        metrics = dict(metrics)
        new_stale, grads, metrics = stale_rule(
            getattr(state, "stale", None), grads, metrics)
        new_state, opt_metrics = optimizer.update(state, grads)
        new_state = new_state._replace(stale=new_stale)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        if hb is not None:
            for j in range(hb.shape[0]):
                metrics[f"heartbeat{j}"] = hb[j]
        return new_state, metrics

    return train_step


def make_decode_step(model: Model, rt: Runtime, plan: Plan) -> Callable:
    def decode_step(params, cache, tokens, cache_len):
        logits, new_cache = model.decode_fn(params, cache, tokens, cache_len)
        return logits, new_cache
    return decode_step


def make_prefill_step(model: Model, rt: Runtime, plan: Plan) -> Callable:
    def prefill_step(params, batch):
        logits, cache, _ = model.prefill_fn(params, batch)
        return logits, cache
    return prefill_step


# ---------------------------------------------------------------------------
# serving steps (runtime/server.py) — batched prefill + slot-paged decode
# ---------------------------------------------------------------------------

def sample_tokens(logits, *, greedy: bool, temperature: float, key):
    """Device-side sampling: (B, V) logits -> (B,) int32 token ids.

    Greedy argmax or temperature-scaled categorical — inside the jitted
    step, so the decode loop never round-trips logits through the host.
    """
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = max(float(temperature), 1e-4)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / t, axis=-1).astype(jnp.int32)


def make_serve_prefill_step(model: Model, rt: Runtime, plan: Plan, *,
                            greedy: bool = True, temperature: float = 1.0
                            ) -> Callable:
    """Batched prefill for one admitted request: a single dispatch that

      1. runs the full forward over the (bucket-padded) prompt, collecting
         every layer's K/V (``model.prefill_cache_fn``),
      2. inserts those rows into the live decode cache at the request's slot
         (rows past the true length carry pad K/V — the per-slot length
         masks them out of every later attention),
      3. samples the first generated token from the last prompt position
         (device-side — the request's TTFT token), and
      4. sets the slot's length and pending-token state.

    jit this once per power-of-two prompt-length bucket: the padded token
    shape is the only shape that varies, so two prompts in the same bucket
    share one executable.
    """
    if model.prefill_cache_fn is None:
        raise ValueError(
            f"family {model.cfg.family!r} has no positional KV cache; "
            "batched prefill is undefined under padding (use the decode "
            "loop for recurrent families)")

    def prefill_step(params, cache, lens, tok, tokens, length, slot, key):
        # tokens (1, Lb) pad-right; length, slot scalars; cache the live
        # (n_layers, B, S, KV, hd) decode cache; lens (B,); tok (B, 1)
        if rt.mesh is not None:
            # batch-sharded lookups (ps shard_map) need the batch divisible
            # by the data axis: run the forward at the full decode width —
            # every row computes the same prompt, row 0 is consumed below
            tokens = jnp.broadcast_to(
                tokens, (lens.shape[0],) + tokens.shape[1:])
        logits, kv = model.prefill_cache_fn(params, tokens)
        logits = logits[:1]
        kv = jax.tree.map(
            lambda p: jax.lax.slice_in_dim(p, 0, 1, axis=1), kv)
        last = jax.lax.dynamic_slice_in_dim(
            logits, length - 1, 1, axis=1)[:, 0, :]          # (1, Vp)
        nxt = sample_tokens(last, greedy=greedy,
                            temperature=temperature, key=key)  # (1,)

        def insert(c, p):
            start = (jnp.zeros_like(slot), slot) + \
                (jnp.zeros_like(slot),) * (c.ndim - 2)
            return jax.lax.dynamic_update_slice(c, p.astype(c.dtype), start)

        new_cache = jax.tree.map(insert, cache, kv)
        new_lens = jax.lax.dynamic_update_slice(
            lens, length[None].astype(lens.dtype), (slot,))
        new_tok = jax.lax.dynamic_update_slice(
            tok, nxt[:, None], (slot, jnp.zeros_like(slot)))
        return new_cache, new_lens, new_tok, nxt

    return prefill_step


def make_serve_decode_step(model: Model, rt: Runtime, plan: Plan, *,
                           max_seq: int, greedy: bool = True,
                           temperature: float = 1.0) -> Callable:
    """One slot-paged decode step over the whole batch.

    Per-slot state lives on device: ``lens`` (B,) is each slot's position
    (threaded into ``model.decode_fn`` — per-row KV write + per-slot
    attention masking), ``tok`` (B,1) is each slot's pending token (fed
    straight from the previous step's device-side sample — no host argmax
    round-trip). ``active`` is the host's (B,) occupancy mask: inactive
    slots neither advance their length nor replace their token, so a
    completed-but-not-yet-reused slot idles in place until the next prefill
    overwrites it. Returns sampled tokens with inactive slots as -1 (the
    detokenizer's cross-slot sanity marker).
    """
    def decode_step(params, cache, lens, tok, active, key):
        logits, new_cache = model.decode_fn(params, cache, tok, lens)
        nxt = sample_tokens(logits[:, -1, :], greedy=greedy,
                            temperature=temperature, key=key)   # (B,)
        act = active & (lens > 0)
        new_tok = jnp.where(act[:, None], nxt[:, None], tok)
        new_lens = jnp.where(act, jnp.minimum(lens + 1, max_seq), lens)
        out_tok = jnp.where(act, nxt, -1)
        return new_cache, new_lens, new_tok, out_tok

    return decode_step


# ---------------------------------------------------------------------------
# step assembly (shared by get_runner / Trainer._build / replan)
# ---------------------------------------------------------------------------

def build_step(model: Model, optimizer: Optimizer, rt: Runtime, plan: Plan,
               state: Optional[TrainState] = None, *, seed: int = 0
               ) -> tuple[Callable, TrainState, Any]:
    """Assemble (jitted train step, state, shardings) for a plan.

    ``state=None``: fresh init from ``seed``. An existing ``state`` (device
    or host arrays — e.g. the elastic remesh/replan paths) is the sharding
    template itself (no throwaway init) and is device_put onto the plan's
    shardings — a no-op when the placement is already current, a reshard
    otherwise. Incoming state must be in the canonical per-param layout
    (callers unfuse before handing it over); when the plan stamps
    ``fused_apply`` the optimizer memory is re-laid out per bucket here.
    """
    if getattr(rt.run_cfg, "kernel_autotune", False):
        from repro.kernels import autotune
        autotune.ensure_for_plan(plan, rt, model.specs())
    step_fn = make_train_step(model, optimizer, rt, plan)
    if state is None:
        state = optimizer.init(model.init(jax.random.key(seed)))
    # staleness buffers live on the canonical per-param state: attach/carry/
    # drop them for THIS plan before any fused re-layout
    state = ensure_stale_buffers(state, plan, rt)
    if getattr(plan, "fused_apply", False):
        state = fuse_state(state, plan.bucket_plan)
    state_like = state
    if plan.mesh is not None:
        # every sharding below names the mesh explicitly, so the pjit path
        # needs no ambient mesh; on explicit-sharding JAX use_mesh gives
        # callers who didn't wrap the builder the set_mesh placement
        # semantics, and on older JAX it is a no-op context.
        with compat.use_mesh(plan.mesh):
            shardings = state_shardings(plan, state_like)
            state = jax.device_put(state, shardings)
            bs = batch_shardings(plan, model.input_specs())
            if getattr(rt.run_cfg, "heartbeat", False) and bs is not None:
                ba = plan.rules.rules.get("batch")
                bs["_heartbeat"] = _ns(plan.mesh, P(ba))
            step = jax.jit(step_fn, in_shardings=(shardings, bs),
                           out_shardings=(shardings, None), donate_argnums=0)
            if getattr(rt.run_cfg, "verify_contract", False):
                # debug gate: every build — fresh, replan, or remesh —
                # must compile to the plan's collective contract before a
                # single step runs (analysis/contract.py). The compile is
                # cached, so the first step reuses it.
                from repro.analysis.contract import verify_step_contract
                verify_step_contract(
                    plan, step.lower(state, _abstract_batch(model, rt))
                    .compile().as_text())
    else:
        shardings = None
        step = jax.jit(step_fn, donate_argnums=0)
    return step, state, shardings


def _abstract_batch(model: Model, rt: Runtime) -> dict:
    """Global-shape ShapeDtypeStructs for lowering a step without data."""
    specs = dict(model.input_specs())
    if getattr(rt.run_cfg, "heartbeat", False):
        specs["_heartbeat"] = jax.ShapeDtypeStruct((rt.replicas,),
                                                   jnp.float32)
    return specs


def apply_replan(model: Model, optimizer: Optimizer, rt: Runtime,
                 new_plan: Plan, state: TrainState, diff: dict
                 ) -> tuple[Callable, TrainState, Any]:
    """Hot-swap to ``new_plan``: rebuild the jitted step, reshard state.

    The one shared swap sequence under Runner.replan and
    Trainer.maybe_replan: state moves device-to-device when pspecs are
    unchanged and through a host round-trip when they moved (the
    version-portable elastic path). Marks ``diff['rebuilt']``.
    """
    old_plan = rt.plan
    if is_fused(state):
        # migrate fused optimizer memory through the canonical per-param
        # layout: the OLD plan's bucket layout unfuses it, the new plan's
        # (possibly regrouped) layout re-fuses inside build_step
        state = unfuse_state(
            state, old_plan.bucket_plan if old_plan is not None else None)
    rt.plan = new_plan            # model fns read the plan at trace time
    if diff["pspecs_changed"] and new_plan.mesh is not None:
        state = jax.tree.map(
            lambda a: None if a is None else np.asarray(jax.device_get(a)),
            state)
    step, state, shardings = build_step(model, optimizer, rt, new_plan,
                                        state)
    diff["rebuilt"] = True
    return step, state, shardings


# ---------------------------------------------------------------------------
# the two-line user API (paper Table 2)
# ---------------------------------------------------------------------------

@dataclass
class Runner:
    model: Model
    optimizer: Optimizer
    plan: Plan
    rt: Runtime
    train_step: Callable          # jitted
    state: TrainState
    shardings: Any = None         # TrainState of NamedShardings (None off-mesh)

    def run(self, batch) -> dict:
        self.state, metrics = self.train_step(self.state, batch)
        return metrics

    def replan(self, census: sparsity.Census, *, force: bool = False,
               capacity_drift: float = 1.5) -> dict:
        """Hot-swap the plan/step from a (typically observed) census.

        Recomputes the Plan through the same pure stages as build time. If
        nothing material changed (no method flip, no pspec change, capacity
        within ``capacity_drift``x) the live step is kept untouched unless
        ``force``. State reshards in place: device-to-device when only the
        jitted step changes, through a host round-trip when pspecs moved
        (the version-portable elastic path). Returns the plan diff.
        """
        new_plan = analyze(self.model, self.rt, census=census,
                           stale_tables=getattr(self.plan, "stale_tables",
                                                ()))
        diff = plan_diff(self.plan, new_plan, capacity_drift)
        if not (diff["changed"] or force):
            return diff
        self.plan = new_plan
        self.train_step, self.state, self.shardings = apply_replan(
            self.model, self.optimizer, self.rt, new_plan, self.state, diff)
        return diff

    def check_contract(self, *, strict_dtype: bool = False) -> list:
        """On-demand plan-contract check of the live step: lower/compile
        against abstract inputs and diff the collectives against the
        current plan (analysis/contract.py). Returns findings (empty =
        the compiled step implements the plan)."""
        from repro.analysis.contract import check_contract
        if self.plan.mesh is None:
            return []          # off-mesh: no collectives to contract
        with compat.use_mesh(self.plan.mesh):
            txt = self.train_step.lower(
                self.state, _abstract_batch(self.model, self.rt)) \
                .compile().as_text()
        return check_contract(self.plan, txt, strict_dtype=strict_dtype)


def get_runner(model_cfg: ModelConfig, shape_cfg: ShapeConfig,
               run_cfg: RunConfig = RunConfig(),
               mesh: Optional[Mesh] = None, seed: int = 0) -> Runner:
    """Transform a single-device model into a distributed runner."""
    rt = Runtime(model_cfg, run_cfg, shape_cfg, mesh=mesh)
    model = build_model(model_cfg, rt)
    plan = analyze(model, rt)
    rt.plan = plan
    optimizer = make_optimizer(rt)
    step, state, shardings = build_step(model, optimizer, rt, plan, seed=seed)
    return Runner(model=model, optimizer=optimizer, plan=plan, rt=rt,
                  train_step=step, state=state, shardings=shardings)
