"""Table 3 of the paper, generalized (DESIGN.md §2) — now latency-aware.

Per-chip wire bytes per training step for one parameter of size ``b`` bytes:

  dense:
    allreduce (MPI/ring):  2 (N-1)/N · b          [paper Table 3, dense-MPI]
    fsdp  (PS-for-dense):  2 b                    [pull b (all-gather) + push
                                                   b (reduce-scatter); paper
                                                   Table 3, dense-PS]
  sparse (α = touched fraction per replica-step):
    ps (row-sharded):      pull 2α b (M-1)/M  +  push 2 b_shard (D-1)/D
                           where b_shard = b/M   [shard psum over data]
    ps_gather push:        pull 2α b + push D α b [sparse all-gather over data]
    mpi_gatherv:           2 (N-1) α b            [paper Table 3, sparse-MPI]

N = total replicas (data·pod), M = model-axis size, D = data(+pod) size.

Bytes alone mispredict small parameters: each collective also pays a fixed
per-message launch latency (the α term in Shi et al.'s α + β·b model,
arXiv:1711.05979), so the planner's argmin runs over *seconds*:

  t(method) = messages(method) · HW.link_latency + wire_bytes / HW.link_bw

``method_messages`` counts the collective launches each method issues per
step, and ``exchange_seconds`` is the shared α + β·b evaluator — the same
model core/buckets.py uses to score fusing n dense all-reduces into k
bucketed ones. RunConfig.comm_mode can still force the paper's baselines
(ps / mpi).

Hierarchical topology (Shi et al. §IV, arXiv:1711.05979): real meshes have
two link tiers — fast intra-host ICI/NVLink (α₁, β₁ = ``Hardware.
link_latency``/``link_bw``) and a slower inter-host fabric (α₂, β₂ =
``inter_latency``/``inter_bw``). When ``MeshDims.hosts > 1`` and the inter
constants are set, collectives that span hosts are priced at the inter tier
(the slowest link governs a flat ring), and a dense all-reduce may instead
ride a *two-level* schedule — intra-host reduce-scatter, inter-host
all-reduce of the 1/L shard, intra-host all-gather:

  t(two_level) = 2α₁ + α₂ + 2·(L−1)/L·b/β₁ + 2·(H−1)/H·(b/L)/β₂

with H hosts and L local replicas per host — only b/L bytes ever cross the
slow tier. ``choose_dense_schedule`` is the argmin the bucket planner uses;
single-host (or inter constants unset) reduces every formula here exactly
to the flat model, so the hierarchy is strictly additive.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, replace
from typing import Optional

from repro.utils.roofline import HW, Hardware

# Hardware fields a fitted hw_profile.json (tools/profile_collectives.py
# fit) may override; anything else in the file is ignored.
_PROFILE_FIELDS = ("name", "link_bw", "link_latency", "inter_bw",
                   "inter_latency")
_profile_cache: dict = {}


def load_hw_profile(path: str, hw: Optional[Hardware] = None) -> Hardware:
    """Overlay a fitted α/β profile onto the hardware model. The file is a
    flat JSON object; only ``_PROFILE_FIELDS`` keys apply (extra keys — the
    fitter records its raw measurements — pass through untouched)."""
    hw = hw or HW
    key = (os.path.abspath(path), os.path.getmtime(path), hw)
    if key in _profile_cache:
        return _profile_cache[key]
    with open(path) as f:
        prof = json.load(f)
    fields = {k: (str(v) if k == "name" else float(v))
              for k, v in prof.items()
              if k in _PROFILE_FIELDS and v is not None}
    hw = replace(hw, **fields)
    _profile_cache[key] = hw
    return hw


def resolve_hw(run_cfg=None, hw: Optional[Hardware] = None) -> Hardware:
    """The hardware model the planner prices against: the roofline HW,
    overlaid with RunConfig.hw_profile (a fitted α₁β₁/α₂β₂ profile from
    tools/profile_collectives.py) when set, then RunConfig.link_latency
    (when set) overriding the intra α term — the config path for pinning
    the pure-byte Table-3 argmin (link_latency=0) without mutating module
    state."""
    hw = hw or HW
    prof = getattr(run_cfg, "hw_profile", None) if run_cfg is not None else None
    if prof:
        hw = load_hw_profile(prof, hw)
    ll = getattr(run_cfg, "link_latency", None) if run_cfg is not None else None
    if ll is not None:
        hw = replace(hw, link_latency=float(ll))
    return hw


def mesh_hosts(mesh) -> int:
    """Host-group count among a mesh's devices — the H of the two-level
    schedule. Real multi-host: the spread of ``device.process_index``.
    Single-process simulation: the "pod" axis models the inter-host tier
    (launch/mesh.make_production_mesh places it outermost), so its size
    stands in for H when every device reports one process."""
    if mesh is None:
        return 1
    procs = 1
    try:
        devs = mesh.devices.flat
        procs = len({getattr(d, "process_index", 0) for d in devs})
    except AttributeError:
        pass                    # fake meshes in unit tests: no device array
    if procs > 1:
        return procs
    if "pod" in getattr(mesh, "axis_names", ()):
        return max(int(dict(mesh.shape)["pod"]), 1)
    return 1


@dataclass(frozen=True)
class MeshDims:
    model: int = 1
    data: int = 1
    pod: int = 1
    hosts: int = 1                      # H: host groups among the replicas

    @property
    def replicas(self) -> int:          # N in the paper
        return self.data * self.pod

    @property
    def chips(self) -> int:
        return self.model * self.data * self.pod

    @property
    def local_replicas(self) -> int:
        """L: replicas per host (the intra-tier group of the two-level
        schedule). Hosts that don't divide the replicas cleanly fall back
        to 1 — the pricing then degrades to all-inter, never crashes."""
        h = max(self.hosts, 1)
        n = self.replicas
        return n // h if h > 1 and n % h == 0 else (n if h <= 1 else 1)


def dense_allreduce_bytes(b: float, dims: MeshDims) -> float:
    n = dims.replicas
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * b


def dense_fsdp_bytes(b: float, dims: MeshDims) -> float:
    n = dims.replicas
    if n <= 1:
        return 0.0
    # all-gather params (fwd+bwd counted once: XLA rematerializes the gather
    # in bwd under remat; we count the roofline-honest 2x) + reduce-scatter;
    # ring AG+RS == AR volume, ≈ 2b for large N
    return 2.0 * (n - 1) / n * b


def sparse_ps_bytes(b: float, alpha: float, dims: MeshDims) -> float:
    m, d = dims.model, dims.replicas
    pull = 2.0 * alpha * b * (m - 1) / m if m > 1 else 0.0
    push = 2.0 * (b / max(m, 1)) * (d - 1) / d if d > 1 else 0.0
    return pull + push


def sparse_ps_gather_bytes(b: float, alpha: float, dims: MeshDims) -> float:
    m, d = dims.model, dims.replicas
    pull = 2.0 * alpha * b * (m - 1) / m if m > 1 else 0.0
    push = d * alpha * b if d > 1 else 0.0
    return pull + push


def sparse_mpi_bytes(b: float, alpha: float, dims: MeshDims) -> float:
    n = dims.replicas
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) * alpha * b


def method_bytes(b: float, alpha: float, dims: MeshDims) -> dict:
    return {
        "allreduce": dense_allreduce_bytes(b, dims),
        "fsdp": dense_fsdp_bytes(b, dims),
        "ps": sparse_ps_bytes(b, alpha, dims),
        "ps_gather": sparse_ps_gather_bytes(b, alpha, dims),
        "mpi_gatherv": sparse_mpi_bytes(b, alpha, dims),
    }


def method_messages(method: str, dims: MeshDims) -> int:
    """Collective launches per step for one parameter under ``method``."""
    m, d = dims.model, dims.replicas
    if method == "allreduce":
        return 1 if d > 1 else 0
    if method == "fsdp":
        return 2 if d > 1 else 0                    # all-gather + reduce-scatter
    if method == "ps":                              # pull psum + push shard psum
        return (1 if m > 1 else 0) + (1 if d > 1 else 0)
    if method == "ps_gather":                       # pull psum + (ids, rows) AG
        return (1 if m > 1 else 0) + (2 if d > 1 else 0)
    if method == "mpi_gatherv":                     # (ids, rows) all-gather
        return 2 if d > 1 else 0
    raise ValueError(f"unknown method {method!r}")


def _tier_constants(hw: Hardware, tier: str) -> tuple[float, float]:
    """(α, β) for a link tier. The inter tier only exists when both inter
    constants are set; otherwise every tier prices at the intra link — the
    exact single-tier reduction the flat model had."""
    if tier == "inter" and hw.hierarchical:
        return hw.inter_latency, hw.inter_bw
    return hw.link_latency, hw.link_bw


def span_tier(dims: MeshDims, hw: Hardware = HW) -> str:
    """The tier a replica-spanning collective runs at: a flat ring that
    crosses hosts is governed by its slowest link (inter); single-host
    meshes never leave the intra fabric."""
    return "inter" if dims.hosts > 1 and hw.hierarchical else "intra"


def exchange_seconds(wire_bytes: float, messages: float,
                     hw: Hardware = HW, tier: str = "intra") -> float:
    """The α + β·b transfer model: messages·α + bytes/bandwidth, at the
    given link tier."""
    alpha, beta = _tier_constants(hw, tier)
    return messages * alpha + wire_bytes / beta


def dense_schedule_seconds(b: float, dims: MeshDims,
                           hw: Hardware = HW) -> dict:
    """Execution-schedule candidates for ONE dense all-reduce of ``b``
    bytes: the flat ring (priced at the tier it spans) and — on multi-host
    meshes with fitted inter constants — the two-level
    reduce-scatter → inter all-reduce → all-gather schedule, which moves
    only b/L bytes across the slow tier (module docstring formula)."""
    n = dims.replicas
    out = {"ring": exchange_seconds(dense_allreduce_bytes(b, dims),
                                    1 if n > 1 else 0, hw,
                                    tier=span_tier(dims, hw))}
    h, loc = dims.hosts, dims.local_replicas
    if hw.hierarchical and h > 1 and loc > 1:
        intra_bytes = 2.0 * (loc - 1) / loc * b
        inter_bytes = 2.0 * (h - 1) / h * (b / loc)
        out["two_level"] = (2.0 * hw.link_latency + hw.inter_latency
                            + intra_bytes / hw.link_bw
                            + inter_bytes / hw.inter_bw)
    return out


def choose_dense_schedule(b: float, dims: MeshDims,
                          hw: Hardware = HW) -> tuple[str, dict]:
    """Pick the execution schedule for one dense all-reduce (the bucket
    planner's per-bucket argmin). Returns (schedule, seconds-by-schedule)."""
    secs = dense_schedule_seconds(b, dims, hw)
    return min(secs, key=secs.get), secs


def method_seconds(*, b: float, alpha: float, dims: MeshDims,
                   hw: Hardware = HW) -> dict:
    """Per-method step seconds for one parameter (the planner's argmin).

    On a multi-host mesh with inter constants every method's collectives
    span hosts, so messages and bytes price at the inter tier; the dense
    all-reduce additionally gets the best of its execution schedules (a
    two-level schedule can undercut the flat inter-tier ring). Single-host
    (or no inter constants) reduces exactly to the flat α + β·b model."""
    bts = method_bytes(b, alpha, dims)
    tier = span_tier(dims, hw)
    secs = {k: exchange_seconds(v, method_messages(k, dims), hw, tier=tier)
            for k, v in bts.items()}
    if tier == "inter":
        secs["allreduce"] = min(
            dense_schedule_seconds(b, dims, hw).values())
    return secs


def choose_method(*, b: float, sparse: bool, alpha: float, dims: MeshDims,
                  comm_mode: str = "hybrid", memory_forced_fsdp: bool = False,
                  can_shard_rows: bool = True,
                  hw: Optional[Hardware] = None) -> tuple[str, dict]:
    """Pick the exchange method for one parameter; returns (method, costs).

    ``costs`` keys are per-chip wire bytes (Table 3); the argmin itself runs
    over ``method_seconds`` so a small sparse parameter whose gatherv bytes
    undercut a dense all-reduce can still lose on message count.

    can_shard_rows: False when no mesh axis can row-shard the table (e.g.
    the dp dense strategy uses every axis for batch) — the PS family is then
    infeasible and the sparse param competes as dense allreduce vs gatherv.
    """
    hw = hw or HW
    costs = method_bytes(b, alpha, dims)
    secs = method_seconds(b=b, alpha=alpha, dims=dims, hw=hw)
    if not sparse:
        if comm_mode == "ps" or memory_forced_fsdp:
            return "fsdp", costs
        return "allreduce", costs
    # sparse parameter
    if comm_mode == "mpi":
        return "mpi_gatherv", costs
    if comm_mode in ("ps", "hybrid"):
        cands = ["mpi_gatherv", "allreduce"] if comm_mode == "hybrid" else []
        if can_shard_rows:
            cands += ["ps", "ps_gather"]
        if not cands:
            cands = ["mpi_gatherv"]
        best = min(cands, key=lambda k: secs[k])
        return best, costs
    raise ValueError(f"unknown comm_mode {comm_mode!r}")


def serve_pull_bytes(b: float, alpha: float, method: str,
                     dims: MeshDims) -> float:
    """Per-decode-step wire bytes for one sparse table's serve-time pull.

    Inference has no push leg: a row-sharded table (ps / ps_gather) pays the
    deduped row-buffer psum over the model axis every decode step (2αb of
    the *step's* activated fraction — α here must come from a decode-shape
    census, where the per-replica token count is the decode batch, not
    B·S); a replicated table (allreduce / mpi_gatherv / dense) gathers
    locally and moves nothing. The trade a serve mesh actually makes is
    wire-per-step vs M× table HBM — the memory-escalation pass arbitrates
    the latter, this prices the former.
    """
    m = dims.model
    if method in ("ps", "ps_gather") and m > 1:
        return 2.0 * alpha * b * (m - 1) / m
    return 0.0


def serve_pull_messages(method: str, dims: MeshDims) -> int:
    return 1 if method in ("ps", "ps_gather") and dims.model > 1 else 0


def serve_pull_seconds(*, b: float, alpha: float, method: str,
                       dims: MeshDims, hw: Optional[Hardware] = None) -> float:
    """α + β·b seconds one decode step spends pulling this table."""
    hw = hw or HW
    return exchange_seconds(serve_pull_bytes(b, alpha, method, dims),
                            serve_pull_messages(method, dims), hw,
                            tier=span_tier(dims, hw))


def serve_table_pricing(*, b: float, alpha: float, method: str,
                        dims: MeshDims, batch_tokens: int,
                        hw: Optional[Hardware] = None) -> dict:
    """Serve-mesh pricing for one table at decode batch shapes: the wire
    bytes and seconds one decode step pays for the pull, and the per-token
    exchange seconds at this batch (one token per sequence per step).
    Stamped into ``Plan.table_serve`` when the planner runs at a decode
    ShapeConfig and surfaced via ``Plan.tables()``."""
    hw = hw or HW
    pull_b = serve_pull_bytes(b, alpha, method, dims)
    pull_s = serve_pull_seconds(b=b, alpha=alpha, method=method, dims=dims,
                                hw=hw)
    return {"pull_bytes": pull_b, "pull_s": pull_s,
            "s_per_token": pull_s / max(int(batch_tokens), 1)}


def stale_push_seconds(*, b: float, alpha: float, method: str,
                       dims: MeshDims, hw: Optional[Hardware] = None) -> dict:
    """Price one sparse table's push under the bounded-staleness fallback.

    The stale mode changes *scheduling*, not volume: the row-buffer
    exchange still runs every step (replica consistency — every replica
    must buffer the same aggregate), but the applied gradient no longer
    gates this step's optimizer update, so the exchange overlaps the next
    step's forward instead of sitting on the critical path. Returned:

      ``sync_s``      the synchronous critical-path cost (method_seconds)
      ``stale_s``     the wire seconds still paid, off the critical path
      ``critical_s``  what remains ON the path in stale mode (0.0 — the
                      whole exchange is deferrable once nothing waits on it)

    The trainer logs this alongside a stale flip so the jitter fallback's
    expected win is visible before the throughput confirms it."""
    hw = hw or HW
    sync = method_seconds(b=b, alpha=alpha, dims=dims, hw=hw)[method]
    return {"sync_s": sync, "stale_s": sync, "critical_s": 0.0}


def pick_dense_strategy(cfg, shape, dims: MeshDims, hbm_bytes: float = 16e9,
                        param_dtype_bytes: int = 2) -> str:
    """Choose tp(+SP) vs dp(ZeRO-3 over every axis) for dense params.

    Per-chip wire napkin (per layer):
      tp+sp: ~12 seq-scattered activation units = 12·T_repl·D·w·(m-1)/m
      dp:    ~3 passes x full layer params      = 3·P_L·w
    MoE and decode need the model axis (EP / cache sharding) -> tp.
    """
    if cfg.n_experts or shape.kind == "decode" or dims.model <= 1:
        return "tp"
    chips = dims.chips
    if shape.global_batch % chips != 0 and \
            shape.global_batch % (dims.data * dims.model) != 0:
        return "tp"
    t_repl = shape.tokens / max(dims.replicas, 1)
    m = dims.model
    tp_unit = t_repl * cfg.d_model * param_dtype_bytes * (m - 1) / m
    layers = cfg.n_layers + (cfg.enc_layers if cfg.is_encdec else 0)
    p_layer = max((cfg.param_count() - cfg.vocab_size * cfg.d_model *
                   (1 if cfg.tie_embeddings else 2)) / max(layers, 1), 1)
    tp_coll = 12 * tp_unit
    dp_coll = 3 * p_layer * param_dtype_bytes
    return "dp" if dp_coll < tp_coll else "tp"
