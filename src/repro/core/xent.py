"""Vocab-sharded softmax cross-entropy.

Logits live sharded over the ``model`` axis (the output head is row-sharded
like the PS embedding); the loss never materializes a replicated (B,S,V)
tensor. Only scalars-per-token cross shards (psum of max/denominator/target
logit) — this is the paper's OPAU placement discipline applied to the loss:
shared ops see partial reductions, not gathered tensors.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import Mesh, P, shard_map


def _xent_local(logits, labels, *, model_axis: str, vocab: int, shards: int):
    """Per-device body: logits (B,S,Vloc) f32, labels (B,S) global ids."""
    vloc = logits.shape[-1]
    m = jax.lax.axis_index(model_axis) if shards > 1 else 0
    col0 = m * vloc
    cols = col0 + jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    logits = jnp.where(cols < vocab, logits, -jnp.inf)

    mx = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    if shards > 1:
        mx = jax.lax.pmax(mx, model_axis)
    se = jnp.sum(jnp.exp(logits - mx[..., None]), axis=-1)
    if shards > 1:
        se = jax.lax.psum(se, model_axis)
    lse = jnp.log(se) + mx

    local_lab = labels - col0
    owned = (local_lab >= 0) & (local_lab < vloc)
    tgt = jnp.take_along_axis(
        logits, jnp.clip(local_lab, 0, vloc - 1)[..., None], axis=-1)[..., 0]
    tgt = jnp.where(owned, tgt, 0.0)
    if shards > 1:
        tgt = jax.lax.psum(tgt, model_axis)
    return lse - tgt


def sharded_xent(logits: jax.Array, labels: jax.Array, *,
                 mesh: Optional[Mesh], model_axis: str, batch_axes: tuple,
                 vocab: int) -> jax.Array:
    """Per-token loss (B,S). logits (B,S,Vp) vocab-sharded over model."""
    logits = logits.astype(jnp.float32)
    if mesh is None or model_axis not in mesh.axis_names \
            or mesh.shape[model_axis] == 1 or model_axis in (batch_axes or ()):
        # vocab not sharded (dp strategy: the model axis carries batch) —
        # plain local xent; GSPMD shards it over the batch dims
        return _xent_local(logits, labels, model_axis="", vocab=vocab, shards=1)
    shards = mesh.shape[model_axis]
    fn = shard_map(
        lambda lg, lb: _xent_local(lg, lb, model_axis=model_axis,
                                   vocab=vocab, shards=shards),
        mesh=mesh,
        in_specs=(P(batch_axes or None, None, model_axis), P(batch_axes or None, None)),
        out_specs=P(batch_axes or None, None),
        check_vma=False,
    )
    return fn(logits, labels)
