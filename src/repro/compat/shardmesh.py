"""Version-portable mesh/sharding constructors.

Every mesh, sharding-context, or shard_map construction in this repo goes
through here instead of calling ``jax.*`` directly, so the same source runs
on JAX 0.4.x (check_rep / no AxisType) and on 0.6/0.7+ (check_vma /
AxisType / set_mesh). Branches are driven by the probes in
``repro.compat.version`` — monkeypatch those to exercise a fallback path on
any installed JAX.
"""
from __future__ import annotations

import contextlib
import enum
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.compat import version

P = PartitionSpec

if version.has_axis_types():
    from jax.sharding import AxisType
else:
    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType on JAX < 0.6. Only carries
        identity: pre-explicit-sharding JAX treats every axis as Auto, so
        the values are accepted (and Auto is a no-op) but never forwarded."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              axis_types: Optional[tuple] = None,
              devices: Optional[Sequence] = None) -> Mesh:
    """jax.make_mesh that works on every supported JAX.

    ``axis_types`` defaults to all-Auto; on JAX without AxisType the
    argument is dropped (Auto is that JAX's only behavior). Requesting
    Explicit axes on a JAX that cannot honor them is an error, not a
    silent downgrade."""
    shape, axes = tuple(shape), tuple(axes)
    if version.has_axis_types():
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axes)
        return jax.make_mesh(
            shape, axes, axis_types=tuple(axis_types),
            **({"devices": devices} if devices is not None else {}))
    if axis_types is not None and any(
            getattr(t, "name", str(t)) == "Explicit" for t in axis_types):
        raise NotImplementedError(
            f"explicit sharding axes requested on JAX {jax.__version__} "
            "(no jax.sharding.AxisType); gate on "
            "repro.compat.has_explicit_sharding()")
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(
            shape, axes,
            **({"devices": devices} if devices is not None else {}))
    from jax.experimental import mesh_utils
    devs = mesh_utils.create_device_mesh(shape, devices=devices)
    return Mesh(devs, axes)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """``with jax.set_mesh(mesh)`` where it exists, else a no-op context.

    Pre-explicit-sharding JAX needs no ambient mesh: this repo passes the
    mesh explicitly everywhere (NamedSharding in_shardings, shard_map
    ``mesh=``), so the fallback yields without touching global state."""
    if version.has_set_mesh():
        with jax.set_mesh(mesh):
            yield mesh
    elif version.has_use_mesh():
        with jax.sharding.use_mesh(mesh):
            yield mesh
    else:
        yield mesh


def shard_map(f, *, mesh: Mesh, in_specs: Any, out_specs: Any,
              check_vma: bool = True):
    """jax.shard_map portable over the check_vma -> check_rep rename and
    the experimental -> top-level move."""
    if version.has_top_level_shard_map():
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError:
            # 0.5/0.6 window: top-level name, pre-rename kwarg
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def named_sharding(mesh: Mesh, spec: PartitionSpec) -> NamedSharding:
    return NamedSharding(mesh, spec)


def cost_analysis(compiled) -> dict:
    """Compiled.cost_analysis() normalized: JAX 0.4.x returns a one-element
    list of dicts (per program), newer JAX returns the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
