"""repro.compat — version-portable sharding/mesh layer.

The single import point for anything whose spelling changed across JAX
generations. Library code, launchers, benchmarks, and the test subprocess
snippets all use these names; ``jax.sharding.AxisType`` / ``jax.set_mesh`` /
``jax.shard_map`` must never be imported directly outside this package
(enforced by tests/test_compat.py).

    from repro.compat import make_mesh, use_mesh, shard_map
    mesh = make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        ...

Capability probes (``has_explicit_sharding()`` etc.) let call sites choose
between explicit-sharding and shard_map/pjit code paths at runtime.
"""
from repro.compat.version import (MIN_SUPPORTED, capabilities,
                                  has_axis_types, has_explicit_sharding,
                                  has_set_mesh, has_top_level_shard_map,
                                  has_use_mesh, jax_version_tuple, supported)
from repro.compat.shardmesh import (AxisType, Mesh, NamedSharding, P,
                                    PartitionSpec, cost_analysis, make_mesh,
                                    named_sharding, shard_map, use_mesh)

__all__ = [
    "MIN_SUPPORTED", "capabilities", "has_axis_types",
    "has_explicit_sharding", "has_set_mesh", "has_top_level_shard_map",
    "has_use_mesh", "jax_version_tuple", "supported",
    "AxisType", "Mesh", "NamedSharding", "P", "PartitionSpec",
    "cost_analysis", "make_mesh", "named_sharding", "shard_map", "use_mesh",
]
