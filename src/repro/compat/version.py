"""JAX capability probes for the version-portable sharding layer.

Everything here is a FUNCTION (re-evaluated per call, monkeypatch-friendly)
so tests can force the fallback paths without installing another JAX.

Supported range: JAX 0.4.30 – current. Two API generations matter:

  * 0.4.x          ``jax.make_mesh(shape, names)`` (no ``axis_types``),
                   ``jax.experimental.shard_map.shard_map(check_rep=...)``,
                   no ``jax.set_mesh`` / ``jax.sharding.AxisType``.
  * 0.6/0.7+       ``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``,
                   top-level ``jax.shard_map(check_vma=...)``, explicit
                   sharding mode via ``AxisType.Explicit``.

Callers must branch on the probes below, never on version literals.
"""
from __future__ import annotations

import jax

MIN_SUPPORTED = (0, 4, 30)


def jax_version_tuple() -> tuple:
    """(major, minor, patch) ints; dev/rc suffixes stripped."""
    parts = []
    for tok in jax.__version__.split(".")[:3]:
        digits = ""
        for ch in tok:
            if not ch.isdigit():
                break
            digits += ch
        if not digits:
            break
        parts.append(int(digits))
    while len(parts) < 3:
        parts.append(0)
    return tuple(parts)


def has_axis_types() -> bool:
    """jax.sharding.AxisType + make_mesh(axis_types=...) exist."""
    return hasattr(jax.sharding, "AxisType")


def has_set_mesh() -> bool:
    """jax.set_mesh context manager exists."""
    return hasattr(jax, "set_mesh")


def has_use_mesh() -> bool:
    """jax.sharding.use_mesh (the pre-set_mesh spelling) exists."""
    return hasattr(jax.sharding, "use_mesh")


def has_top_level_shard_map() -> bool:
    """jax.shard_map (check_vma generation) exists."""
    return hasattr(jax, "shard_map")


def has_explicit_sharding() -> bool:
    """True when the explicit-sharding programming model (AxisType +
    set_mesh) is available; consumers then may use sharding-in-types code
    paths instead of shard_map/pjit."""
    return has_axis_types() and (has_set_mesh() or has_use_mesh())


def supported() -> bool:
    return jax_version_tuple() >= MIN_SUPPORTED


def capabilities() -> dict:
    """One-stop capability report (tools/check_env.py, debugging)."""
    return {
        "jax_version": jax.__version__,
        "jax_version_tuple": list(jax_version_tuple()),
        "supported": supported(),
        "axis_types": has_axis_types(),
        "set_mesh": has_set_mesh(),
        "use_mesh": has_use_mesh(),
        "top_level_shard_map": has_top_level_shard_map(),
        "explicit_sharding": has_explicit_sharding(),
    }
