"""Pallas naming drift.

``pltpu.CompilerParams`` (new JAX) was ``pltpu.TPUCompilerParams`` on 0.4.x;
the constructor signature (dimension_semantics, vmem_limit_bytes, ...) is the
same. Kernels import the alias from here instead of pltpu directly.
"""
from __future__ import annotations

from jax.experimental import pallas as pl  # noqa: F401  (re-export)
from jax.experimental.pallas import tpu as pltpu  # noqa: F401  (re-export)

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
