"""Post-SPMD HLO analysis for the roofline (§Roofline).

``compiled.as_text()`` shows *per-partition* shapes, so byte/FLOP counts here
are per-chip. XLA's ``cost_analysis()`` counts while-loop bodies ONCE
(verified: a 6-iteration scan reports 1x body FLOPs), which would undercount
scan-over-layers models by ~n_layers. This parser instead:

  1. splits the module into computation blocks,
  2. builds the call graph (while body/condition via
     ``backend_config={"known_trip_count":{"n":...}}``, fusion/call via
     ``calls=``, reduce via ``to_apply=``),
  3. propagates trip-count multipliers from ENTRY,
  4. sums collective output bytes and dot FLOPs × multiplier.

The collective term uses ring-cost scaling per op kind (all-reduce moves
2(N-1)/N × bytes; gather/scatter/a2a (N-1)/N; permute 1) with N from the
op's replica_groups.
"""
from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def _result_type(kind: str, type_str: str) -> str:
    """The *result* part of a collective's type string.

    Async ``-start`` collectives are typed as a tuple aliasing the operand
    with the result — e.g. ``all-gather-start`` prints
    ``(f32[4,8], f32[32,8])`` = (operand, result). Summing every shape in
    that tuple double-counts the wire traffic; the result half alone is
    what the op moves. Sync collectives (and ``-start`` ops whose tuple is
    a fused multi-operand result) pass through unchanged."""
    if not kind.endswith("-start") or not type_str.startswith("("):
        return type_str
    shapes = _SHAPE_RE.findall(type_str)
    if len(shapes) >= 2 and len(shapes) % 2 == 0:
        half = shapes[len(shapes) // 2:]
        return ", ".join(f"{d}[{dims}]" for d, dims in half)
    return type_str


def _shape_elems(type_str: str) -> int:
    n = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        k = 1
        if dims:
            for d in dims.split(","):
                k *= int(d)
        n += k
    return n


def _shape_dtype(type_str: str) -> str | None:
    """Dtype of the largest shape in a (possibly tuple) type string."""
    best, best_n = None, -1
    for dtype, dims in _SHAPE_RE.findall(type_str):
        k = 1
        if dims:
            for d in dims.split(","):
                k *= int(d)
        if k > best_n:
            best, best_n = dtype, k
    return best


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    kind: str
    type_str: str
    line: str


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    # edges: (callee_name, multiplier)
    edges: list[tuple[str, float]] = field(default_factory=list)
    fused_callees: set = field(default_factory=set)


@dataclass
class HloSummary:
    collective_bytes: float = 0.0          # per-chip, ring-cost scaled
    collective_raw_bytes: float = 0.0      # per-chip, unscaled operand sums
    collective_by_kind: dict = field(default_factory=dict)
    collective_count: dict = field(default_factory=dict)
    dot_flops: float = 0.0                 # per-chip, trip-count corrected
    hbm_bytes: float = 0.0                 # per-chip traffic estimate: 2x the
                                           # materialized (post-fusion) buffer
                                           # writes x trip multipliers + params

    def to_dict(self) -> dict:
        return {
            "collective_bytes": self.collective_bytes,
            "collective_raw_bytes": self.collective_raw_bytes,
            "collective_by_kind": self.collective_by_kind,
            "collective_count": self.collective_count,
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
        }


_BLOCK_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([a-z0-9\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"?(\d+)"?')
_CALLED = re.compile(r"(?:body|condition|calls|to_apply)=\{?%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{?[0-9,{} ]*\}\}?)")


def _ring_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0  # permute / broadcast


def _replica_groups(line: str) -> list[list[int]]:
    """All replica groups on an op line, e.g. ``{{0,1},{2,3}}`` ->
    [[0, 1], [2, 3]]. Handles the single-group ``{0,1,2}`` spelling and
    unequal groups like ``{{0},{1,2,3}}``."""
    m = _GROUPS_RE.search(line)
    if not m:
        return []
    body = m.group(1).replace(" ", "").strip("{}")
    groups = []
    for part in body.split("},{"):
        part = part.strip("{} ")
        if part:
            groups.append([int(x) for x in part.split(",") if x.strip()])
    return [g for g in groups if g]


def _group_size(line: str) -> int:
    groups = _replica_groups(line)
    if groups:
        # ring cost is set by the largest group the op participates in
        return max(len(g) for g in groups)
    # replica_groups=[4,2]<=[8] style (iota tile assignment)
    m2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m2:
        return int(m2.group(2))
    return 2


def parse_module(text: str) -> tuple[dict, str, dict]:
    """-> (computations, entry_name, name->type symbol table)."""
    comps: dict[str, Computation] = {}
    symbols: dict[str, str] = {}
    cur: Computation | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            m = _BLOCK_START.match(line)
            if m:
                cur = Computation(m.group(1))
                if raw.startswith("ENTRY") or line.startswith("ENTRY"):
                    entry = cur.name
                # params in header: name: type
                for pm in re.finditer(r"([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\][^\s,)]*)", line):
                    symbols[pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, kind = m.groups()
        symbols[name] = type_str
        cur.ops.append(Op(name, kind, type_str, line))
        if kind in ("while",):
            trip = 1.0
            tm = _TRIP_RE.search(line)
            if tm:
                trip = float(tm.group(1))
            for cm in re.finditer(r"body=\{?%?([\w.\-]+)", line):
                cur.edges.append((cm.group(1), trip))
            for cm in re.finditer(r"condition=\{?%?([\w.\-]+)", line):
                cur.edges.append((cm.group(1), trip))
        else:
            for cm in _CALLED.finditer(line):
                cur.edges.append((cm.group(1), 1.0))
            if kind == "fusion":
                for cm in re.finditer(r"calls=\{?%?([\w.\-]+)", line):
                    cur.fused_callees.add(cm.group(1))
    if cur is not None:
        comps[cur.name] = cur
    if entry is None and comps:
        # ENTRY block header sometimes lacks the keyword in our regex; the
        # last computation in an HLO dump is the entry
        entry = list(comps)[-1]
    return comps, entry, symbols


def _multipliers(comps: dict, entry: str) -> tuple[dict, set]:
    """-> ({name: multiplier}, {names reachable only inside fusions})."""
    mult: dict[str, float] = {}
    top_level: set[str] = set()

    def visit(name: str, m: float, fused: bool):
        if name not in comps:
            return
        first = name not in mult
        mult[name] = mult.get(name, 0.0) + m
        if not fused:
            top_level.add(name)
        if not first and (fused or name in top_level):
            return  # avoid exponential revisits; multipliers already summed
        comp = comps[name]
        for callee, k in comp.edges:
            visit(callee, m * k, fused or callee in comp.fused_callees)

    visit(entry, 1.0, False)
    fusion_internal = set(mult) - top_level
    return mult, fusion_internal


def _dot_flops(op: Op, symbols: dict) -> float:
    out_dims = _shape_dims(op.type_str)
    out = math.prod(out_dims) if out_dims else 0
    # Operands start at "<kind>(" — NOT at the first occurrence of the kind
    # substring: the op's own name usually contains it ("%dot.0 = ... dot("),
    # which previously captured the lhs *type* token instead of its name and
    # silently dropped the contraction factor. Optimized dumps also inline
    # the operand type ("dot(f32[64,32]{1,0} %gte.4, ...)"); prefer it.
    lhs_m = re.search(
        r"\s" + re.escape(op.kind)
        + r"\((?:([a-z0-9]+\[[0-9,]*\])(?:\{[^}]*\})?\s+)?%?([\w.\-]+)",
        op.line)
    contracting = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not lhs_m or not contracting:
        return 2.0 * out
    lhs_type = lhs_m.group(1) or symbols.get(lhs_m.group(2))
    if lhs_type is None:
        return 2.0 * out
    lhs_dims = _shape_dims(lhs_type)
    k = 1
    cd = contracting.group(1)
    if cd:
        for d in cd.split(","):
            i = int(d)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * out * k


_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "copy", "reshape", "after-all", "partition-id",
               "replica-id", "iota"}


def analyze_hlo(text: str, param_bytes: float = 0.0,
                f32_collective_scale: float = 1.0) -> HloSummary:
    """f32_collective_scale: the CPU backend upcasts bf16 arithmetic to f32,
    so collectives that would ride the wire in bf16 on TPU appear as f32 in
    the dry-run HLO. Pass 0.5 (when the wire dtype is bf16/OPSW) to count
    them at their TPU width. Intentionally-f32 collectives (scalar norms,
    opsw=off ablations) are either negligible or accounted consistently
    because the ablation compares like against like."""
    comps, entry, symbols = parse_module(text)
    mult, fusion_internal = _multipliers(comps, entry)
    s = HloSummary()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        materialized = cname not in fusion_internal
        for op in comp.ops:
            kind = op.kind
            base = None
            for c in _COLLECTIVE_KINDS:
                if kind == c or kind == c + "-start":
                    base = c
                    break
            if base is not None:
                nbytes = _shape_bytes(_result_type(kind, op.type_str))
                if "f32[" in op.type_str:
                    nbytes *= f32_collective_scale
                n = _group_size(op.line)
                s.collective_raw_bytes += m * nbytes
                s.collective_bytes += m * nbytes * _ring_factor(base, n)
                s.collective_by_kind[base] = \
                    s.collective_by_kind.get(base, 0.0) + m * nbytes
                s.collective_count[base] = \
                    s.collective_count.get(base, 0) + m
            elif kind in ("dot", "dot-general"):
                s.dot_flops += m * _dot_flops(op, symbols)
            if materialized and kind not in _NO_TRAFFIC:
                s.hbm_bytes += 2.0 * m * _shape_bytes(op.type_str)
    s.hbm_bytes += param_bytes
    return s


# scheduled-module helpers --------------------------------------------------

def _dot_bearing(comps: dict) -> set:
    """Names of computations that (transitively) contain a dot — needed to
    recognize matmul work after the backend fuses it away from a top-level
    dot op (CPU lowers most dots into fusions / library custom-calls)."""
    bearing: set[str] = set()
    changed = True
    while changed:
        changed = False
        for name, comp in comps.items():
            if name in bearing:
                continue
            has = any(op.kind in ("dot", "dot-general") for op in comp.ops)
            if not has:
                has = any(callee in bearing for callee, _ in comp.edges)
            if has:
                bearing.add(name)
                changed = True
    return bearing


_MATMUL_CALL = re.compile(
    r'custom_call_target="[^"]*(?:matmul|gemm|dot)[^"]*"', re.I)
_CALLS_RE = re.compile(r"calls=\{?%?([\w.\-]+)")


def is_scheduled(text: str) -> bool:
    return "is_scheduled=true" in text


def scheduled_events(text: str) -> list[dict]:
    """Execution-order event stream of the ENTRY computation of a
    *scheduled* HLO dump — once the module header says
    ``is_scheduled=true``, ``compiled.as_text()`` prints ops in schedule
    order, so text position IS execution position. Each event:
    ``{pos, name, kind, collective: base-kind-or-None, bytes, elems,
    dtype, grad_math}`` — ``bytes``/``elems``/``dtype`` describe the
    collective's *result* (``-start`` operand aliases excluded), so they
    match plan-side wire sizes directly.

    ``grad_math`` catches matmul work however the backend lowered it: raw
    dot/dot-general ops, fusions and while loops whose called computations
    (transitively) contain a dot, and matmul/gemm library custom-calls —
    scan-over-layers models run all their layer matmuls inside dot-bearing
    while bodies, which appear as ONE event each. The overlap regression
    (tests/test_perf_paths.py) uses this to assert the first bucket's
    all-reduce is scheduled before the last backward-bearing loop."""
    comps, entry, _ = parse_module(text)
    events: list[dict] = []
    if entry not in comps:
        return events
    bearing = _dot_bearing(comps)
    for pos, op in enumerate(comps[entry].ops):
        coll = None
        for c in _COLLECTIVE_KINDS:
            if op.kind == c or op.kind == c + "-start":
                coll = c
                break
        grad_math = op.kind in ("dot", "dot-general")
        if not grad_math and op.kind in ("fusion", "while", "call"):
            grad_math = any(cm.group(1) in bearing
                            for cm in _CALLED.finditer(op.line))
        if not grad_math and op.kind == "custom-call":
            grad_math = bool(_MATMUL_CALL.search(op.line))
        rtype = _result_type(op.kind, op.type_str) if coll else ""
        events.append({"pos": pos, "name": op.name, "kind": op.kind,
                       "collective": coll,
                       "bytes": _shape_bytes(rtype) if coll else 0,
                       "elems": _shape_elems(rtype) if coll else 0,
                       "dtype": _shape_dtype(rtype) if coll else None,
                       "grad_math": grad_math})
    return events


def dot_bearing_events(text: str, *, collective: str = "all-reduce",
                       min_bytes: int = 0) -> dict:
    """Scheduling summary shared by the overlap tests and the contract
    checker: positions of the chosen collective kind (result payload >
    ``min_bytes``) and of the dot-bearing while loops in the ENTRY
    schedule. ``first_collective``/``last_loop`` are ``None`` when the
    respective set is empty; comparing them answers "did the exchange
    start before the backward drained?" without each caller re-deriving
    grad-math detection."""
    ev = scheduled_events(text)
    colls = [e["pos"] for e in ev
             if e["collective"] == collective and e["bytes"] > min_bytes]
    loops = [e["pos"] for e in ev if e["kind"] == "while" and e["grad_math"]]
    return {
        "scheduled": is_scheduled(text),
        "events": ev,
        "collectives": colls,
        "loops": loops,
        "first_collective": min(colls) if colls else None,
        "last_loop": max(loops) if loops else None,
    }


# backwards-compatible helpers --------------------------------------------

def parse_collectives(hlo_text: str) -> HloSummary:
    return analyze_hlo(hlo_text)


def collective_bytes_by_kind(hlo_text: str) -> dict[str, float]:
    return analyze_hlo(hlo_text).collective_by_kind
