"""Roofline math for the dry-run analysis (§Roofline).

Hardware model: TPU v5e-like chip.
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link (we use per-chip aggregate = 1 link
                      as the conservative spec-mandated constant)
  ICI link latency  : ~1 us per collective launch (the α in the α + β·b
                      transfer model; core/cost_model.py charges it per
                      message so many small collectives cost more than one
                      fused one — the term the gradient bucketing removes)

Conventions (documented because the spec formula mixes global/per-chip):
  * ``cost_analysis()`` on the compiled (post-SPMD) module reports *per-chip*
    FLOPs and bytes. We multiply by chip count to get the global numbers the
    spec formula expects; the resulting *term* is then per-step seconds on the
    critical path of one chip, identical either way.
  * collective_bytes from the HLO parser is per-chip; the collective term is
    per_chip_collective_bytes / link_bw.
"""
from __future__ import annotations

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    link_bw: float = 50e9           # bytes/s per chip (ICI, intra-host β₁)
    hbm_bytes: float = 16e9         # HBM capacity per chip
    vmem_bytes: float = 16e6        # on-chip vector memory per core
    link_latency: float = 1e-6      # s per collective message (intra α₁)
    # inter-host tier (DCN): None = single-tier fabric — every collective is
    # priced at (link_latency, link_bw) and the cost model reduces exactly to
    # the flat α + β·b of the paper era. Set both (e.g. from a fitted
    # hw_profile.json, tools/profile_collectives.py) to let the planner price
    # two-level reduce-scatter→all-gather schedules on multi-host meshes.
    inter_bw: float | None = None       # bytes/s per chip across hosts (β₂)
    inter_latency: float | None = None  # s per cross-host message (α₂)

    @property
    def hierarchical(self) -> bool:
        return self.inter_bw is not None and self.inter_latency is not None


HW = Hardware()

# TPU vector-lane width: Pallas blocks tile the last dim in multiples of this
LANE = 128


def kernel_tile_candidates(e: int, itemsize: int, hw: Hardware = HW,
                           lane: int = LANE) -> list[int]:
    """Feature-tile (block_e) candidates for the embedding kernels.

    Multiples of the lane width that divide E exactly (anything else pads or
    misaligns) and whose double-buffered block fits comfortably in VMEM.
    0 — the fixed full-row block — is always a candidate, so a measured
    argmin over this list can never lose to the untuned default.
    """
    cands = [0]
    for be in range(lane, e, lane):
        if e % be == 0 and 2 * be * itemsize <= hw.vmem_bytes:
            cands.append(be)
    return cands


def embed_tile_seconds(n: int, e: int, block_e: int, itemsize: int,
                       hw: Hardware = HW, step_overhead: float = 2e-7
                       ) -> float:
    """Roofline estimate for one embed gather/scatter sweep: the row bytes
    always cross HBM once; tiling only adds grid steps (each with a fixed
    issue/DMA-setup overhead) while shrinking the per-step VMEM block. The
    autotuner uses this to *rank* candidates before measuring — the measured
    argmin decides, the model just prunes the sweep."""
    be = block_e if block_e and block_e < e and e % block_e == 0 else e
    steps = n * (e // be)
    return n * e * itemsize / hw.hbm_bw + steps * step_overhead


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    per_chip_flops: float
    per_chip_hbm_bytes: float
    per_chip_collective_bytes: float
    model_flops_global: float       # 6*N*D (dense) or 6*N_active*D (MoE)
    per_chip_peak_memory: float     # from memory_analysis()
    collective_breakdown: dict | None = None

    @property
    def compute_s(self) -> float:
        return self.per_chip_flops / HW.peak_flops

    @property
    def memory_s(self) -> float:
        return self.per_chip_hbm_bytes / HW.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.per_chip_collective_bytes / HW.link_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global). Catches remat/redundancy waste."""
        hlo_global = self.per_chip_flops * self.chips
        if hlo_global <= 0:
            return 0.0
        return self.model_flops_global / hlo_global

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / critical-path bound — the score metric.

        = (MODEL_FLOPS / (chips*peak)) / max(compute_s, memory_s, coll_s)
        """
        ideal = self.model_flops_global / (self.chips * HW.peak_flops)
        b = self.bound_s
        return ideal / b if b > 0 else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            useful_flops_fraction=self.useful_flops_fraction,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def roofline_from_analysis(
    *,
    arch: str,
    shape: str,
    mesh: str,
    chips: int,
    cost: dict,
    collective_bytes: int,
    model_flops_global: float,
    peak_memory: float,
    collective_breakdown: dict | None = None,
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh,
        chips=chips,
        per_chip_flops=flops,
        per_chip_hbm_bytes=hbm,
        per_chip_collective_bytes=float(collective_bytes),
        model_flops_global=model_flops_global,
        per_chip_peak_memory=float(peak_memory),
        collective_breakdown=collective_breakdown,
    )
