"""Analytic per-chip HBM traffic model for the §Roofline memory term.

The dry-run's HLO byte-proxy overcounts on CPU: the chunked-attention /
linear-recurrence inner buffers that a TPU Pallas kernel keeps in VMEM are
materialized (and counted) on the CPU backend, and every bf16 op is widened
to f32. This module instead computes the traffic a tuned TPU implementation
would see, with the standard streaming assumptions:

  * each projection matmul streams operands+outputs once per pass
    (1 fwd pass; bwd does dgrad+wgrad = 2 passes; remat adds 1 recompute),
  * flash attention streams Q,K,V,O once per pass; score/softmax buffers
    stay in VMEM,
  * optimizer: params read+write, grads read, m/v (f32) read+write,
  * decode: params + KV cache stream once; activations negligible.

Both the analytic number and the raw HLO proxy are recorded; the roofline
memory term uses the analytic one (EXPERIMENTS.md §Dry-run caveats).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class Traffic:
    params_opt: float
    activations: float
    attention: float
    kv_cache: float
    embed_head: float

    @property
    def total(self) -> float:
        return (self.params_opt + self.activations + self.attention
                + self.kv_cache + self.embed_head)

    def to_dict(self):
        return {"params_opt": self.params_opt,
                "activations": self.activations,
                "attention": self.attention, "kv_cache": self.kv_cache,
                "embed_head": self.embed_head, "total": self.total}


def _proj_traffic(t_tokens, d_in, d_out, passes, dtype=2):
    """One projection: activations in/out + weights per pass."""
    return passes * dtype * (t_tokens * d_in + d_in * d_out + t_tokens * d_out)


def estimate_traffic(cfg: ModelConfig, shape: ShapeConfig, *, chips: int,
                     model_shards: int, remat: str = "full",
                     param_count: int | None = None,
                     zero_stage: int = 0) -> Traffic:
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    # tokens processed per chip this step
    if decode:
        tokens_chip = max(shape.global_batch, 1) / chips * model_shards
        # (model shards each process the replicated decode tokens)
        tokens_chip = max(shape.global_batch / (chips / model_shards), 1)
    else:
        tokens_chip = shape.tokens / (chips / model_shards)

    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    hp = cfg.n_heads or (d // max(hd, 1))
    kv = cfg.n_kv_heads or hp
    L = cfg.n_layers + (cfg.enc_layers if cfg.is_encdec else 0)
    ms = model_shards

    p_total = param_count if param_count is not None else cfg.param_count()
    p_chip = p_total / (ms if zero_stage < 3 else chips)

    passes = (3.0 if train else 1.0)
    if train and remat in ("block", "full"):
        passes += 1.0

    # per-layer projections, model-sharded where the plan shards them
    proj = 0.0
    if cfg.n_heads:
        proj += _proj_traffic(tokens_chip, d, hp * hd / ms, passes)
        proj += 2 * _proj_traffic(tokens_chip, d, kv * hd, passes)
        proj += _proj_traffic(tokens_chip, hp * hd / ms, d, passes)
    if cfg.n_experts:
        active = cfg.experts_per_token
        proj += 3 * _proj_traffic(tokens_chip * active, d, f / ms, passes)
        # expert weights resident: all local experts stream once per pass
        e_loc = max(cfg.n_experts / ms, 1)
        proj += passes * 2 * (e_loc * 3 * d * f) if cfg.n_experts >= ms else \
            passes * 2 * (cfg.n_experts * 3 * d * f / ms)
        if cfg.shared_expert:
            proj += 3 * _proj_traffic(tokens_chip, d, f / ms, passes)
    else:
        proj += 3 * _proj_traffic(tokens_chip, d, f / ms, passes)
    if cfg.family == "hybrid":
        proj += 4 * _proj_traffic(tokens_chip, d, d / ms, passes)
    if cfg.family == "ssm":
        proj = 6 * _proj_traffic(tokens_chip, d, d / ms, passes) \
            + 2 * _proj_traffic(tokens_chip, d, f / ms, passes)
    if cfg.is_encdec:
        proj += 2 * _proj_traffic(tokens_chip, d, (hp * hd + kv * hd) / ms,
                                  passes)  # cross attention
    activations = proj * L
    # residual stream + norms: ~6 streams of (T, D) per layer
    activations += L * 6 * passes * 2 * tokens_chip * d / ms

    # flash attention streams Q,K,V,O once per pass
    attention = 0.0
    if cfg.n_heads and not decode:
        attention = L * passes * 2 * tokens_chip * (hp / ms + 3 * kv) * hd

    kv_cache = 0.0
    if decode and cfg.n_heads:
        cache_tokens = shape.global_batch * shape.seq_len
        kv_cache = 2 * 2 * cache_tokens * kv * hd * cfg.n_layers / chips

    # params+optimizer traffic
    if train:
        params_opt = p_chip * (2 * passes + 2 + 2 + 16)
        # ^ bf16 reads per pass + grad rw + param write + m/v f32 rw
    else:
        params_opt = p_chip * 2

    # embedding rows + logits head
    vp = cfg.vocab_size
    if decode:
        embed_head = 2 * tokens_chip * (d + vp / ms)
    else:
        embed_head = passes * 2 * tokens_chip * (d + vp / ms)

    return Traffic(params_opt=params_opt, activations=activations,
                   attention=attention, kv_cache=kv_cache,
                   embed_head=embed_head)
