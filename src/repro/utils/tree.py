"""Pytree helpers used across the framework."""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import numpy as np


def path_name(path) -> str:
    """Render a jax tree path as a dotted parameter name — THE name under
    which a parameter is known everywhere (ParamPlan.name, Census.tables
    keys, RunConfig.table_zipf/table_alpha, census metric prefixes)."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def tree_map_with_path_names(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where fn receives (dotted_name, leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(path_name(path), leaf), tree
    )


def named_leaves(tree: Any) -> list[tuple[str, Any]]:
    """[(dotted_name, leaf)] for every leaf of the tree."""
    out: list[tuple[str, Any]] = []
    jax.tree_util.tree_map_with_path(
        lambda path, leaf: out.append((path_name(path), leaf)), tree
    )
    return out


def leaf_count(leaf) -> int:
    shape = getattr(leaf, "shape", None)
    if shape is None:
        return 1
    return int(math.prod(shape)) if shape else 1


def leaf_bytes(leaf) -> int:
    dtype = getattr(leaf, "dtype", None)
    itemsize = np.dtype(dtype).itemsize if dtype is not None else 4
    return leaf_count(leaf) * itemsize


def tree_count(tree: Any) -> int:
    """Total number of scalar elements in the tree."""
    return sum(leaf_count(l) for l in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes of the tree at its leaf dtypes."""
    return sum(leaf_bytes(l) for l in jax.tree_util.tree_leaves(tree))
