from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_map_with_path_names,
    named_leaves,
)
from repro.utils.hlo import parse_collectives, collective_bytes_by_kind
from repro.utils.roofline import RooflineTerms, roofline_from_analysis, HW
