"""Batched serving loop: continuous-batching-lite over the decode step.

Requests enter a queue; the server packs up to ``max_batch`` sequences into
the fixed decode batch (padding unused slots), prefills new arrivals, and
steps the shared KV cache. Slot lifecycle (free -> prefilling -> decoding ->
done) is host-side; device work is exactly the two jitted functions from
core/transform.py (prefill_step, decode_step), so the same plan/shardings
as the dry-run serve cells apply.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.runtime import Runtime
from repro.core.transform import analyze, make_decode_step
from repro.models.model import build_model


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


@dataclass
class ServerConfig:
    max_batch: int = 8
    max_seq: int = 256
    greedy: bool = True


class Server:
    def __init__(self, model_cfg: ModelConfig, run_cfg: RunConfig,
                 scfg: ServerConfig, mesh=None, params=None, seed: int = 0):
        shape = ShapeConfig("serve", scfg.max_seq, scfg.max_batch, "decode")
        self.rt = Runtime(model_cfg, run_cfg, shape, mesh=mesh)
        self.model = build_model(model_cfg, self.rt)
        self.plan = analyze(self.model, self.rt)
        self.rt.plan = self.plan
        self.scfg = scfg
        self.params = params if params is not None else \
            self.model.init(jax.random.key(seed))
        self.cache = self.model.init_cache(scfg.max_batch, scfg.max_seq)
        self.decode_step = jax.jit(
            make_decode_step(self.model, self.rt, self.plan))
        # slot bookkeeping
        self.slot_req: list[Optional[Request]] = [None] * scfg.max_batch
        self.slot_pos = np.zeros(scfg.max_batch, np.int32)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self._tokens = np.zeros((scfg.max_batch, 1), np.int32)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.scfg.max_batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                # teacher-forced prefill: feed prompt tokens one by one
                # through the decode step (cache fills as a side effect).
                # Other active slots' pending tokens must survive the
                # prefill (they are zeroed per step so only this slot
                # writes meaningful cache rows) and be restored before the
                # next shared decode step.
                pending = self._tokens.copy()
                for t in req.prompt[:-1]:
                    self._tokens[:] = 0
                    self._tokens[i, 0] = t
                    self._step_device()
                    self.slot_pos[i] += 1
                self._tokens[:] = pending
                self._tokens[i, 0] = req.prompt[-1]

    def _step_device(self):
        # single shared cache_len: homogeneous-position batch (decode_32k
        # cell semantics); per-slot positions tracked host-side.
        # _tokens must be COPIED: jnp.asarray can alias a numpy buffer
        # zero-copy on CPU, and the slot loop mutates _tokens in place while
        # the async dispatch may still read it (slots then see each other's
        # tokens, nondeterministically).
        logits, self.cache = self.decode_step(
            self.params, self.cache, jnp.asarray(self._tokens.copy()),
            jnp.asarray(int(self.slot_pos.max())))
        return logits

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One decode iteration over all active slots; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        logits = self._step_device()
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            self.slot_pos[i] += 1
            self._tokens[i, 0] = tok
            if len(req.out_tokens) >= req.max_new_tokens or \
                    self.slot_pos[i] >= self.scfg.max_seq - 1:
                req.done = True
                self.completed.append(req)
                self.slot_req[i] = None
                self.slot_pos[i] = 0
                self._tokens[i, 0] = 0
        return len(active)

    def run_until_drained(self, max_iters: int = 10_000) -> list[Request]:
        it = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and it < max_iters:
            self.step()
            it += 1
        return self.completed
