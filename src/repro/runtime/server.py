"""Serving engine: batched prefill + persistent slot-paged decode.

The engine (``Server``) runs continuous batching along the lines of
MaxText/JetStream's offline inference engine:

  admission   one ``prefill_step`` dispatch per request — the full forward
              over the bucket-padded prompt collects every layer's K/V and
              inserts the rows into the live decode cache at the request's
              slot, samples the first token on device, and sets the slot's
              length. jit-cached per power-of-two prompt-length bucket, so
              admission costs one dispatch instead of prompt_len.
  decode      one jitted step over the whole batch with *per-slot* device
              state: a (B,) length vector (each slot masks exactly its own
              valid cache prefix — a reused slot never attends over a
              previous request's stale rows), a (B,1) pending-token buffer
              fed straight from the previous step's device-side sample
              (greedy or temperature, ``ServerConfig.greedy``), and a
              host-provided occupancy mask.
  host work   a staging thread pads/buckets queued prompts off the critical
              path; a detokenize thread materializes sampled tokens,
              records TTFT/per-token latency, and flags completions — the
              decode loop itself never blocks on device->host copies.

Slot lifecycle: free -> prefilling (one dispatch) -> decoding -> done
(detok thread flags it) -> freed (next ``step()`` reuses it). A completed
slot keeps idling in the batch until reused: the active mask freezes its
length and token, and the next prefill overwrites its rows.

The serve path is sparse-planned: the engine runs ``analyze()`` at the
decode ShapeConfig, so every embedding table gets its own method/capacity/
wire dtype at serve batch sizes (a skewed vocab table rides the ps_gather
pull while a near-dense table is replicated for the dense local gather),
and ``Plan.tables()`` carries the serve-mesh pricing (per-token exchange
seconds at decode batch shapes, cost_model.serve_table_pricing).

``ToyServer`` is the pre-engine loop (teacher-forced token-at-a-time
prefill through the shared decode step, one shared cache_len, host-side
argmax) — kept as the benchmark baseline and for recurrent families whose
carry cannot be bucket-prefilled exactly under padding.
"""
from __future__ import annotations

import contextlib
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.runtime import Runtime
from repro.core.transform import (analyze, make_decode_step,
                                  make_serve_decode_step,
                                  make_serve_prefill_step)
from repro.models.model import build_model

MIN_BUCKET = 8


def bucket_len(prompt_len: int, max_seq: int, lo: int = MIN_BUCKET) -> int:
    """Power-of-two prompt-length bucket (capped at the cache length)."""
    b = lo
    while b < prompt_len:
        b *= 2
    return min(b, max_seq)


def prefill_buckets(max_seq: int, lo: int = MIN_BUCKET) -> list[int]:
    """Every bucket a ``max_seq`` engine can trace (check_env reporting)."""
    out, b = [], lo
    while b < max_seq:
        out.append(b)
        b *= 2
    return out + [max_seq]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False
    # ---- timing (seconds, time.perf_counter clock) ----
    t_submit: float = 0.0
    t_first: float = 0.0          # first generated token materialized (TTFT)
    token_times: list = field(default_factory=list)

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit if self.t_first else float("inf")


@dataclass
class ServerConfig:
    max_batch: int = 8
    max_seq: int = 256
    greedy: bool = True           # device-side argmax; False -> temperature
    temperature: float = 1.0      # categorical sampling when greedy=False


class Server:
    """The rebuilt engine: batched prefill, slot-paged decode, threaded
    admission/detokenization. Requires a family with a positional KV cache
    (``model.prefill_cache_fn``); recurrent families use ``ToyServer``."""

    def __init__(self, model_cfg: ModelConfig, run_cfg: RunConfig,
                 scfg: ServerConfig, mesh=None, params=None, seed: int = 0):
        shape = ShapeConfig("serve", scfg.max_seq, scfg.max_batch, "decode")
        self.rt = Runtime(model_cfg, run_cfg, shape, mesh=mesh)
        self.model = build_model(model_cfg, self.rt)
        if self.model.prefill_cache_fn is None:
            raise ValueError(
                f"family {model_cfg.family!r} cannot be bucket-prefilled "
                "exactly (recurrent carry under padding) — use ToyServer")
        self.plan = analyze(self.model, self.rt)
        self.rt.plan = self.plan
        self.scfg = scfg
        self.params = params if params is not None else \
            self.model.init(jax.random.key(seed))

        b, s = scfg.max_batch, scfg.max_seq
        self.cache = self.model.init_cache(b, s)
        self.lens = jnp.zeros((b,), jnp.int32)      # per-slot positions
        self.tok = jnp.zeros((b, 1), jnp.int32)     # per-slot pending token
        self._base_key = jax.random.key(seed + 1)
        self._dispatches = 0

        self.stats = {"prefill_calls": 0, "prefill_traces": 0,
                      "decode_steps": 0, "decode_traces": 0,
                      "buckets": set(), "cross_slot_mismatches": 0}
        self._mesh_ctx = (lambda: compat.use_mesh(mesh)) if mesh is not None \
            else contextlib.nullcontext

        prefill = make_serve_prefill_step(
            self.model, self.rt, self.plan, greedy=scfg.greedy,
            temperature=scfg.temperature)
        decode = make_serve_decode_step(
            self.model, self.rt, self.plan, max_seq=s, greedy=scfg.greedy,
            temperature=scfg.temperature)

        def counted_prefill(*args):
            self.stats["prefill_traces"] += 1     # trace-time side effect:
            return prefill(*args)                 # fires once per bucket

        def counted_decode(*args):
            self.stats["decode_traces"] += 1
            return decode(*args)

        # one jit each: the executable cache keys on the padded token shape,
        # so every power-of-two bucket traces exactly once
        self._prefill = jax.jit(counted_prefill, donate_argnums=(1, 2, 3))
        self._decode = jax.jit(counted_decode, donate_argnums=(1, 2, 3))

        # ---- slot bookkeeping (host) ----
        self.slot_req: list[Optional[Request]] = [None] * b
        self.completed: list[Request] = []

        # ---- threads: admission staging + detokenize/completion ----
        self.queue: deque[Request] = deque()      # O(1) popleft
        self._qcv = threading.Condition()
        self._staged: deque[tuple] = deque()      # (req, padded, plen)
        self._staging = 0                         # popped but not yet staged
        self._pending = 0                         # submitted, not completed
        self._freed: deque[int] = deque()         # slots to recycle
        self._detok_q: deque[tuple] = deque()
        self._detok_cv = threading.Condition()
        self._inflight = 0
        self._stop = False
        self._thread_err: list[BaseException] = []
        self._admitter = threading.Thread(target=self._admit_worker,
                                          daemon=True)
        self._detok = threading.Thread(target=self._detok_worker, daemon=True)
        self._admitter.start()
        self._detok.start()

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        if len(req.prompt) >= self.scfg.max_seq:
            raise ValueError(f"prompt ({len(req.prompt)}) must leave room "
                             f"for generation (max_seq {self.scfg.max_seq})")
        req.t_submit = time.perf_counter()
        with self._qcv:
            self._pending += 1
            self.queue.append(req)
            self._qcv.notify()

    def close(self):
        self._stop = True
        with self._qcv:
            self._qcv.notify_all()
        with self._detok_cv:
            self._detok_cv.notify_all()

    # ------------------------------------------------------------------
    # admission staging thread: pad + bucket prompts off the decode path
    def _admit_worker(self):
        try:
            while not self._stop:
                with self._qcv:
                    while not self.queue and not self._stop:
                        self._qcv.wait(0.1)
                    if self._stop:
                        return
                    req = self.queue.popleft()
                    self._staging += 1
                plen = len(req.prompt)
                lb = bucket_len(plen, self.scfg.max_seq)
                padded = np.zeros((1, lb), np.int32)
                padded[0, :plen] = req.prompt
                self._staged.append((req, padded, np.int32(plen)))
                with self._qcv:
                    self._staging -= 1
        except BaseException as e:            # surface in the serve loop
            self._thread_err.append(e)

    # detokenize thread: the only place device results are materialized
    def _detok_worker(self):
        try:
            while True:
                with self._detok_cv:
                    while not self._detok_q and not self._stop:
                        self._detok_cv.wait(0.1)
                    if self._detok_q:
                        item = self._detok_q.popleft()
                    elif self._stop:
                        return
                    else:
                        continue
                arr, mapping = item
                vals = np.asarray(arr)        # blocks HERE, not in step()
                now = time.perf_counter()
                for idx, slot, req in mapping:
                    if req.done:
                        continue              # slot kept decoding past done
                    tok = int(vals[idx])
                    if tok < 0:
                        # the decode step stamps -1 on inactive slots; one
                        # in an active mapping means slot state leaked
                        self.stats["cross_slot_mismatches"] += 1
                        continue
                    req.out_tokens.append(tok)
                    req.token_times.append(now)
                    if not req.t_first:
                        req.t_first = now
                    plen = len(req.prompt)
                    if len(req.out_tokens) >= req.max_new_tokens or \
                            plen + len(req.out_tokens) >= self.scfg.max_seq:
                        req.done = True
                        self.completed.append(req)
                        self._freed.append(slot)
                        with self._qcv:
                            self._pending -= 1
                with self._detok_cv:
                    self._inflight -= 1
                    self._detok_cv.notify_all()
        except BaseException as e:
            self._thread_err.append(e)

    def _push_detok(self, arr, mapping):
        with self._detok_cv:
            self._detok_q.append((arr, mapping))
            self._inflight += 1
            self._detok_cv.notify()

    def _check_threads(self):
        if self._thread_err:
            raise RuntimeError("server worker thread died") \
                from self._thread_err[0]

    # ------------------------------------------------------------------
    def _next_key(self):
        if self.scfg.greedy:
            return self._base_key              # unused inside the step
        self._dispatches += 1
        return jax.random.fold_in(self._base_key, self._dispatches)

    def _admit(self) -> int:
        """Dispatch one prefill per staged request into free slots."""
        n = 0
        for i in range(self.scfg.max_batch):
            if self.slot_req[i] is not None or not self._staged:
                continue
            req, padded, plen = self._staged.popleft()
            self.slot_req[i] = req
            self.stats["prefill_calls"] += 1
            self.stats["buckets"].add(padded.shape[1])
            with self._mesh_ctx():
                self.cache, self.lens, self.tok, first = self._prefill(
                    self.params, self.cache, self.lens, self.tok,
                    jnp.asarray(padded), plen, np.int32(i),
                    self._next_key())
            self._push_detok(first, [(0, i, req)])
            n += 1
        return n

    def step(self) -> int:
        """One engine iteration: recycle slots, admit, one decode dispatch.
        Returns the number of active slots."""
        self._check_threads()
        while self._freed:
            self.slot_req[self._freed.popleft()] = None
        self._admit()
        active_idx = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active_idx:
            return 0
        active = np.zeros(self.scfg.max_batch, bool)
        active[active_idx] = True
        with self._mesh_ctx():
            self.cache, self.lens, self.tok, out = self._decode(
                self.params, self.cache, self.lens, self.tok,
                jnp.asarray(active), self._next_key())
        self.stats["decode_steps"] += 1
        self._push_detok(
            out, [(i, i, self.slot_req[i]) for i in active_idx])
        # bound the dispatch run-ahead so a lagging detokenizer can't let
        # the loop burn steps decoding slots that already completed
        with self._detok_cv:
            while self._inflight > 2 * self.scfg.max_batch:
                self._detok_cv.wait(0.05)
        return len(active_idx)

    def run_until_drained(self, max_iters: int = 10_000) -> list[Request]:
        it = 0
        while self._pending > 0 and it < max_iters:
            if self.step() == 0:
                # nothing on device: staging or detok is catching up
                time.sleep(0.0002)
                self._check_threads()
            it += 1
        # let in-flight detok finish so timings/completions are final
        with self._detok_cv:
            while self._inflight > 0 and not self._thread_err:
                self._detok_cv.wait(0.1)
        self._check_threads()
        while self._freed:
            self.slot_req[self._freed.popleft()] = None
        return self.completed


# ---------------------------------------------------------------------------
# the pre-engine loop, kept as baseline + recurrent-family fallback
# ---------------------------------------------------------------------------

class ToyServer:
    """Teacher-forced token-at-a-time prefill through the shared decode
    step, one shared cache_len, host-side argmax — the loop the engine
    replaced. Admission costs O(prompt_len) blocking dispatches that stall
    every active slot, and the shared ``cache_len`` makes every slot attend
    over ``slot_pos.max()`` positions; benchmarks/serve_bench.py measures
    the contrast."""

    def __init__(self, model_cfg: ModelConfig, run_cfg: RunConfig,
                 scfg: ServerConfig, mesh=None, params=None, seed: int = 0):
        shape = ShapeConfig("serve", scfg.max_seq, scfg.max_batch, "decode")
        self.rt = Runtime(model_cfg, run_cfg, shape, mesh=mesh)
        self.model = build_model(model_cfg, self.rt)
        self.plan = analyze(self.model, self.rt)
        self.rt.plan = self.plan
        self.scfg = scfg
        self.params = params if params is not None else \
            self.model.init(jax.random.key(seed))
        self.cache = self.model.init_cache(scfg.max_batch, scfg.max_seq)
        self.decode_step = jax.jit(
            make_decode_step(self.model, self.rt, self.plan))
        self.slot_req: list[Optional[Request]] = [None] * scfg.max_batch
        self.slot_pos = np.zeros(scfg.max_batch, np.int32)
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []
        self._tokens = np.zeros((scfg.max_batch, 1), np.int32)
        self.stats = {"prefill_calls": 0, "decode_steps": 0}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _admit(self):
        for i in range(self.scfg.max_batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[i] = req
                self.stats["prefill_calls"] += 1
                # teacher-forced prefill: feed prompt tokens one by one
                # through the decode step (cache fills as a side effect).
                # Other active slots' pending tokens must survive the
                # prefill (they are zeroed per step so only this slot
                # writes meaningful cache rows) and be restored before the
                # next shared decode step.
                pending = self._tokens.copy()
                for t in req.prompt[:-1]:
                    self._tokens[:] = 0
                    self._tokens[i, 0] = t
                    self._step_device()
                    self.slot_pos[i] += 1
                self._tokens[:] = pending
                self._tokens[i, 0] = req.prompt[-1]

    def _step_device(self):
        # single shared cache_len: homogeneous-position batch (decode_32k
        # cell semantics); per-slot positions tracked host-side.
        # _tokens must be COPIED: jnp.asarray can alias a numpy buffer
        # zero-copy on CPU, and the slot loop mutates _tokens in place while
        # the async dispatch may still read it (slots then see each other's
        # tokens, nondeterministically).
        logits, self.cache = self.decode_step(
            self.params, self.cache, jnp.asarray(self._tokens.copy()),
            jnp.asarray(int(self.slot_pos.max())))
        return logits

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One decode iteration over all active slots; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        logits = self._step_device()
        self.stats["decode_steps"] += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        now = time.perf_counter()
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            req.token_times.append(now)
            if not req.t_first:
                req.t_first = now
            self.slot_pos[i] += 1
            self._tokens[i, 0] = tok
            if len(req.out_tokens) >= req.max_new_tokens or \
                    self.slot_pos[i] >= self.scfg.max_seq - 1:
                req.done = True
                self.completed.append(req)
                self.slot_req[i] = None
                self.slot_pos[i] = 0
                self._tokens[i, 0] = 0
        return len(active)

    def run_until_drained(self, max_iters: int = 10_000) -> list[Request]:
        it = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and it < max_iters:
            self.step()
            it += 1
        return self.completed
