from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.monitor import StepMonitor
from repro.runtime.server import Server, ServerConfig
