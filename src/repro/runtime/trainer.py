"""Fault-tolerant training driver.

Responsibilities beyond the jitted step:
  * deterministic resume — the data pipeline is step-addressed, so restoring
    (state, step) from a checkpoint reproduces the exact remaining stream;
  * checkpoint/restart — async sharded checkpoints every N steps; on any
    step failure the driver restores the last committed checkpoint and
    continues (bounded retries);
  * elastic re-mesh — ``Trainer.remesh(new_mesh)`` rebuilds the plan/step on
    a different mesh and reshards the live state through the elastic
    checkpoint path (the node-failure story: drop the bad host's slice,
    re-mesh, resume);
  * automatic straggler response — with ``remesh_on_straggle`` the monitor's
    sustained-outlier escalation drives the loop itself: commit a
    checkpoint, shrink the data axis by one slice
    (``launch/mesh.shrink_mesh``), re-run ``analyze()`` so every method /
    capacity / bucket is re-priced for the smaller world (the cost model's
    α·messages term changes with N), and resume on the live state with
    trajectory continuity. ``remesh_cooldown`` steps must pass before the
    monitor may escalate again, and ``min_data_parallel`` floors the
    shrink. With ``RunConfig.heartbeat`` the eviction is *attributed*: each
    data slice's step-time scalar rides the fused metrics psum, the monitor
    EMAs them per slot, and the shrink drops the named slice (by process
    index on a real multi-host mesh) instead of the last by convention;
  * mesh re-growth — ``Trainer.readmit()`` re-inserts the evicted slice at
    its original grid position (``launch/mesh.grow_mesh``) through the same
    checkpoint → ``analyze()`` → rebuild path, arming a probation window:
    if the re-admitted slice re-straggles, it is re-evicted immediately,
    bypassing the full escalation and the cooldown;
  * bounded-staleness sparse fallback — with ``stale_on_jitter`` and
    ``RunConfig.max_staleness > 0``, sustained jitter *below* the eviction
    threshold flips the sparse tables to stale pushes (the step applies the
    previous step's exchanged gradient; dense buckets stay synchronous) and
    flips back, with an automatic drain, once the jitter drains;
  * adaptive replanning — with ``replan_every > 0`` the driver feeds the
    in-graph sparsity census (``embed_unique`` metrics) into a
    ``SparsityProfile`` EMA and periodically re-runs the planner on the
    *observed* census (paper §5's profile → re-optimize loop). When the
    cost model flips a method or the capacity drifts past
    ``replan_drift``x, the jitted step is rebuilt and the live state
    reshards in place — device-side when pspecs are unchanged, through the
    remesh host path otherwise;
  * straggler detection via runtime/monitor.py.
"""
from __future__ import annotations

import dataclasses
import logging
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step,
                                   restore_checkpoint)
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.plan import plan_diff, plan_leaves
from repro.core.runtime import Runtime
from repro.core.sparsity import (SparsityProfile, observed_census,
                                 wire_dtype_hints)
from repro.core.transform import (analyze, apply_replan, build_step,
                                  estimate_census, stale_buffer_tables,
                                  state_shardings)
from repro.data.pipeline import Dataset
from repro.launch.mesh import grow_mesh, shrink_mesh
from repro.models.model import build_model
from repro.optim.optimizer import (fuse_state, is_fused, make_optimizer,
                                   unfuse_state)
from repro.runtime.monitor import StepMonitor
from repro.utils.roofline import HW

log = logging.getLogger("repro.trainer")


def _bucket_signature(plan) -> tuple:
    """The identity of a plan's bucket layout: per-bucket member indices and
    wire dtype, in order. Index-keyed gbucket EMAs are only comparable
    between plans with equal signatures."""
    if plan.bucket_plan is None:
        return ()
    return tuple((b.idx, b.key[1]) for b in plan.bucket_plan.buckets)


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    max_retries: int = 3
    log_every: int = 10
    metrics_host_every: int = 1
    # ---- profile -> replan loop (0 disables) ----
    replan_every: int = 0          # consider replanning every N steps
    replan_warmup: int = 2         # min profiled steps before first replan
    replan_drift: float = 1.5      # capacity drift factor that triggers it
    profile_decay: float = 0.9     # EMA decay of the sparsity profile
    # ---- elastic straggler response (auto-remesh) ----
    remesh_on_straggle: bool = False  # act on the monitor's escalation
    remesh_cooldown: int = 50      # steps before the monitor may re-escalate
    min_data_parallel: int = 1     # never shrink the data axis below this
    # ---- straggler attribution + probationary re-admission ----
    attribution: bool = True       # evict the heartbeat-attributed slice
                                   # (falls back to last-slice convention)
    probation_steps: int = 100     # probation window after readmit()
    probation_sustained: int = 2   # outlier heartbeats on probation that
                                   # re-evict without a full escalation
    # ---- bounded-staleness sparse fallback (jitter below eviction) ----
    stale_on_jitter: bool = False  # flip sparse tables to stale pushes on
                                   # sustained jitter (needs
                                   # RunConfig.max_staleness > 0)


class Trainer:
    def __init__(self, model_cfg: ModelConfig, shape_cfg: ShapeConfig,
                 run_cfg: RunConfig, tcfg: TrainerConfig,
                 dataset: Dataset, mesh=None):
        self.model_cfg, self.shape_cfg = model_cfg, shape_cfg
        self.run_cfg, self.tcfg = run_cfg, tcfg
        self.dataset = dataset
        self.monitor = StepMonitor(cooldown=tcfg.remesh_cooldown)
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir, tcfg.keep_ckpts) \
            if tcfg.ckpt_dir else None
        self.step = 0
        self.profile = SparsityProfile(decay=tcfg.profile_decay)
        # per-slot heartbeat override hook: (step, n_slots) -> float[n] step
        # seconds. Single-controller default writes the measured step time
        # into every slot; multi-host shims (and the chaos bench) use this
        # to carry genuinely per-host timings.
        self.heartbeat_fn: Optional[Callable] = None
        self._evicted: list = []       # LIFO of evicted slices (readmit)
        self._stale_tables: tuple = ()  # live bounded-staleness table set
        log.debug("jax %s compat=%s", jax.__version__, compat.capabilities())
        self._build(mesh)

    # ------------------------------------------------------------------
    def _build(self, mesh, state=None, carry_plan=None):
        """(Re)build plan + jitted step; ``state`` (host or device arrays)
        is resharded onto the new plan instead of re-initializing.

        ``carry_plan`` (the plan live before an elastic rebuild) carries
        the *observed* workload knowledge across the mesh change: the new
        plan is derived from the profile's observed census with sticky
        growth against the old plan, not from the bare build-time estimate
        — otherwise a remesh silently reverts overflow-grown capacities
        and profiled method/wire choices (the same bug class the restore
        path fixes via the manifest plan record)."""
        self.mesh = mesh
        self.rt = Runtime(self.model_cfg, self.run_cfg, self.shape_cfg,
                          mesh=mesh)
        self.model = build_model(self.model_cfg, self.rt)
        census = None
        if carry_plan is not None and self.profile.ready():
            census = self._observed_census(carry_plan)
        self.plan = analyze(self.model, self.rt, census=census,
                            stale_tables=self._stale_tables)
        self.rt.plan = self.plan
        self.optimizer = make_optimizer(self.rt)
        self.train_step, self.state, self.shardings = build_step(
            self.model, self.optimizer, self.rt, self.plan, state,
            seed=self.run_cfg.seed)
        self._note_plan_costs()

    def _note_plan_costs(self):
        self.monitor.note_exchange(
            self.plan.bucket_plan.stats() if self.plan.bucket_plan else None)
        self.monitor.note_apply(self._apply_seconds_estimate())

    def _apply_seconds_estimate(self) -> Optional[float]:
        """Analytic optimizer-apply cost for the live plan: HBM bytes the
        update moves over the hardware model's bandwidth. Params are read
        and written, each f32 moment (and the EMA) is read and written,
        gradients are read once; the per-param path under a bucket plan
        additionally pays the unflatten->reflatten round trip over the
        fused gradient buffers that the bucket-native apply skips."""
        leaves = plan_leaves(self.plan.params)
        if not leaves:
            return None
        itemsize = jnp.dtype(self.rt.param_dtype).itemsize
        pbytes = sum(p.bytes for p in leaves)
        f32b = sum(p.bytes // itemsize for p in leaves) * 4
        n_moments = {"adamw": 2, "momentum": 1}.get(
            self.run_cfg.optimizer, 0)
        total = 3 * pbytes + 2 * n_moments * f32b
        if self.run_cfg.ema_decay:
            total += 2 * f32b
        bp = self.plan.bucket_plan
        if bp is not None and not getattr(self.plan, "fused_apply", False):
            total += 2 * bp.wire_bytes
        hw = bp.hw if bp is not None and bp.hw is not None else HW
        return total / hw.hbm_bw

    def _canonical_state(self):
        """The live state in the canonical per-param layout. Checkpoints,
        restore templates, and the remesh host round-trip never see the
        fused bucket layout — it is a per-plan memory layout, rebuilt by
        build_step, not portable state."""
        if is_fused(self.state):
            return unfuse_state(self.state, self.plan.bucket_plan)
        return self.state

    # ------------------------------------------------------------------
    def _wire_pins(self, plan) -> dict:
        """Dense parameters whose planned wire dtype differs from the
        global knob — i.e. the profiled wire_dtype_auto pins. Part of the
        manifest plan record: Plan.tables() only covers sparse tables, so
        without this a restored run would silently revert an outlier-prone
        bucket's f32 pin to the bf16 default."""
        base = jnp.dtype(self.rt.wire_dtype)
        return {p.name: jnp.dtype(p.wire_dtype).name
                for p in plan_leaves(plan.params)
                if not p.sparse and jnp.dtype(p.wire_dtype) != base}

    def _ckpt_extra(self) -> dict:
        """Manifest ``extra`` for every checkpoint this trainer writes: the
        dataset cursor plus the live plan's per-table summary — capacities,
        methods, wire dtypes, and the priced α — and the dense wire-dtype
        pins, so a restore can rebuild the *saved* plan instead of silently
        re-deriving the build-time estimate (which loses overflow-grown
        capacities and profiled method/wire flips, corrupting the resumed
        trajectory)."""
        extra = {"dataset_step": self.step, "plan": self.plan.tables()}
        pins = self._wire_pins(self.plan)
        if pins:
            extra["wire_pins"] = pins
        if self.mesh is not None:
            extra["mesh"] = dict(self.mesh.shape)
        return extra

    def maybe_restore(self):
        if self.ckpt is None:
            return
        last = latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return
        # checkpoints hold the canonical per-param layout: restore into a
        # canonical template (with matching shardings), re-fuse afterwards
        template = self._canonical_state()
        shardings = state_shardings(self.plan, template) \
            if self.mesh is not None else None
        self.state, self.step, extra = restore_checkpoint(
            self.tcfg.ckpt_dir, template, shardings=shardings)
        saved = (extra or {}).get("plan")
        pins = (extra or {}).get("wire_pins", {})
        if (saved and saved != self.plan.tables()) or \
                pins != self._wire_pins(self.plan):
            self._adopt_saved_plan(saved or {}, pins)
        elif getattr(self.plan, "fused_apply", False):
            self.state = fuse_state(self.state, self.plan.bucket_plan)
            if self.shardings is not None:
                self.state = jax.device_put(self.state, self.shardings)
        # recovery latency must not read as a straggler, and the in-flight
        # timing sample (if any) now spans a restore, not a step
        self.monitor.note_recovery()
        log.info("restored checkpoint at step %d", self.step)

    def _adopt_saved_plan(self, saved: dict, wire_pins: Optional[dict] = None):
        """Re-analyze + rebuild the jitted step against a checkpoint's plan
        record. The saved per-table α reproduces the Table-3 method argmin,
        the saved capacities/grown flags override the build-time census,
        and ``wire_pins`` re-applies profiled dense wire-dtype choices —
        without this, restoring a checkpoint written after a
        capacity-growth replan rebuilds the *estimate's* smaller buffers
        and the restored run immediately re-overflows (and a method or
        wire flip recorded at save time would silently revert)."""
        census = estimate_census(self.model, self.rt)
        if wire_pins:
            census.wire_dtypes.update(wire_pins)
        for name, ent in saved.items():
            t = census.tables.get(name)
            if t is None:
                continue
            alpha = ent.get("alpha")
            census.tables[name] = dataclasses.replace(
                t,
                alpha=float(alpha) if alpha is not None else t.alpha,
                capacity=int(ent.get("capacity", t.capacity)),
                grown=bool(ent.get("grown", False)))
            if ent.get("wire_dtype"):
                census.wire_dtypes[name] = ent["wire_dtype"]
        if census.tables:
            census.capacity = max(
                census.capacity,
                max(t.capacity for t in census.tables.values()))
        # the checkpoint's stale flags are the authority on which tables run
        # the bounded-staleness push — a run saved mid-stale-window resumes
        # stale (and vice versa), instead of silently flipping on restore
        self._stale_tables = tuple(sorted(
            n for n, e in saved.items() if e.get("stale")))
        self.monitor._stale_on = bool(self._stale_tables)  # no flip counted
        new_plan = analyze(self.model, self.rt, census=census,
                           stale_tables=self._stale_tables)
        diff = plan_diff(self.plan, new_plan)
        log.info("restore adopted the checkpoint's plan record: "
                 "capacities %s -> %s, flips=%s", diff["table_capacity"][0],
                 diff["table_capacity"][1], diff["flips"])
        self.plan = new_plan
        self.train_step, self.state, self.shardings = apply_replan(
            self.model, self.optimizer, self.rt, new_plan, self.state, diff)
        self._note_plan_costs()

    def _observed_census(self, live_plan):
        """The census the replan loop runs on: the profile's per-table
        observed uniques/overflow folded over the build-time estimate, with
        sticky growth against ``live_plan`` and (under wire_dtype_auto)
        per-parameter wire hints from the magnitude census. Shared by
        ``maybe_replan`` and the elastic rebuild — reads ``self.model`` /
        ``self.rt``, so on a remesh it prices against the *new* world."""
        base = estimate_census(self.model, self.rt)
        live = {n: (live_plan.table_capacity.get(n, 0),
                    n in live_plan.grown_tables)
                for n in live_plan.table_methods}
        census = observed_census(self.profile, base,
                                 self.model_cfg.vocab_size, self.run_cfg,
                                 live=live)
        if self.run_cfg.wire_dtype_auto and live_plan.bucket_plan is not None:
            names = [p.name for p in plan_leaves(live_plan.params)]
            census.wire_dtypes = wire_dtype_hints(
                self.profile, live_plan.bucket_plan, names,
                outlier_ratio=self.run_cfg.wire_outlier_ratio,
                default=self.run_cfg.wire_dtype,
                # tables that kept their own sparse exchange emit a
                # name-keyed row-buffer census instead of riding a bucket
                sparse_tables=[n for n, m in live_plan.table_methods.items()
                               if m != "allreduce"])
        return census

    def remesh(self, new_mesh):
        """Elastic re-mesh: reshard live state onto a new mesh (e.g. after
        dropping a failed host slice). The rebuild derives shardings from
        the restored values themselves — no throwaway ``model.init`` — and
        carries the observed census across the mesh change (grown
        capacities and profiled choices survive; only the world-size terms
        re-price)."""
        host_state = jax.tree.map(
            lambda a: None if a is None else np.asarray(jax.device_get(a)),
            self._canonical_state())
        old_sig = _bucket_signature(self.plan)
        self._build(new_mesh, state=host_state, carry_plan=self.plan)
        if _bucket_signature(self.plan) != old_sig:
            # bucket magnitude EMAs are index-keyed; a regrouped layout on
            # the new mesh makes the old samples mis-attributed
            self.profile.reset_grad_census()

    def _auto_remesh(self) -> Optional[dict]:
        """Act on the monitor's straggler escalation: commit a checkpoint,
        evict the slow data slice, and resume on the live state.

        With heartbeat attribution (``RunConfig.heartbeat`` +
        ``TrainerConfig.attribution``) the monitor *names* the slow slice —
        per-host step scalars ride the fused metrics psum and the per-slot
        EMAs single out the outlier — so the eviction drops that slice; on
        a genuinely multi-process mesh the attributed slice resolves to its
        owning process and the shrink goes through
        ``shrink_mesh(drop_process_index=...)``. Without attribution the
        last data slice is dropped by convention. The evicted slice
        (devices + grid position) is recorded so ``readmit()`` can grow the
        mesh back at the same position. The rebuild re-runs ``analyze()``
        against the smaller world, so methods, capacities, and buckets are
        re-priced at the new N (a ps↔allreduce flip across the remesh is
        legitimate and handled). Returns the plan diff across the remesh,
        or None when the mesh cannot shrink.
        """
        if self.mesh is None or "data" not in self.mesh.axis_names:
            self.monitor.note_recovery()
            return None
        devs = np.asarray(self.mesh.devices)
        ax = self.mesh.axis_names.index("data")
        slot = self.monitor.straggler_slice() if self.tcfg.attribution \
            else None
        if slot is not None and tuple(self.rt.batch_axes) == ("data",) \
                and 0 <= int(slot) < devs.shape[ax]:
            drop = int(slot)      # heartbeat slots ARE data grid indices
        else:
            slot = None
            drop = devs.shape[ax] - 1      # by-convention fallback
        kw = {"drop_axis_index": drop}
        if slot is not None and \
                len({getattr(d, "process_index", 0) for d in devs.flat}) > 1:
            procs = {getattr(d, "process_index", 0)
                     for d in np.take(devs, drop, axis=ax).flat}
            if len(procs) == 1:
                kw = {"drop_process_index": procs.pop()}
        new_mesh = shrink_mesh(self.mesh, axis="data",
                               min_axis_size=self.tcfg.min_data_parallel,
                               **kw)
        if new_mesh is None:
            log.warning(
                "straggler escalation at step %d but the mesh cannot "
                "shrink (data axis at or below min_data_parallel=%d) — "
                "re-arming the monitor", self.step,
                self.tcfg.min_data_parallel)
            self.monitor.note_recovery()   # re-arm instead of re-firing
            self.monitor._probation_trip = None
            return None
        evicted = {"devices": np.take(devs, drop, axis=ax),
                   "index": drop, "slot": slot, "step": self.step}
        if self.ckpt is not None:
            # synchronous commit before touching placement: a crash during
            # the reshard recovers from this step, not an older one. A
            # checkpoint failure (including a stored async one re-raised by
            # the wait) must not abort the recovery itself — the live-state
            # remesh does not depend on it
            try:
                self.ckpt.save_sync(self.step, self._canonical_state(),
                                    extra=self._ckpt_extra())
            except Exception as e:
                log.exception("pre-remesh checkpoint failed; continuing "
                              "with the live-state remesh")
                self.monitor.note_ckpt_error(e)
        old_plan, old_shape = self.plan, dict(self.mesh.shape)
        self.remesh(new_mesh)
        self._evicted.append(evicted)
        diff = plan_diff(old_plan, self.plan)
        self.monitor.note_remesh()
        log.warning(
            "auto-remesh at step %d: mesh %s -> %s (%s data slice %d), "
            "flips=%s, capacities %s -> %s", self.step, old_shape,
            dict(new_mesh.shape),
            "heartbeat-attributed" if slot is not None else "by-convention",
            drop, diff["flips"], diff["table_capacity"][0],
            diff["table_capacity"][1])
        return diff

    def readmit(self) -> Optional[dict]:
        """Re-admit the most recently evicted slice on probation.

        The grow mirrors the shrink through the same safety protocol:
        commit a checkpoint, re-insert the evicted devices at their
        original grid position (``launch/mesh.grow_mesh``), and rebuild
        plan + step through the observed-census elastic path — grown
        capacities and profiled choices survive, only the world-size terms
        re-price. The monitor's escalation window and cooldown origin reset
        (``note_regrow``) and a probation window arms on the re-admitted
        slice: if its heartbeats re-straggle for ``probation_sustained``
        beats within ``probation_steps``, the next eviction fires
        immediately — no second full escalation, no cooldown wait. Returns
        the plan diff, or None when there is nothing to re-admit (or the
        devices are no longer addressable)."""
        if not self._evicted or self.mesh is None:
            return None
        ev = self._evicted[-1]
        try:
            new_mesh = grow_mesh(self.mesh, ev["devices"],
                                 insert_axis_index=ev["index"], axis="data")
        except ValueError as e:
            log.warning("readmit at step %d impossible: %s", self.step, e)
            return None
        self._evicted.pop()
        if self.ckpt is not None:
            try:
                self.ckpt.save_sync(self.step, self._canonical_state(),
                                    extra=self._ckpt_extra())
            except Exception as e:
                log.exception("pre-readmit checkpoint failed; continuing "
                              "with the live-state re-grow")
                self.monitor.note_ckpt_error(e)
        old_plan, old_shape = self.plan, dict(self.mesh.shape)
        self.remesh(new_mesh)
        diff = plan_diff(old_plan, self.plan)
        self.monitor.note_regrow(
            slot=ev["index"], probation_steps=self.tcfg.probation_steps,
            probation_sustained=self.tcfg.probation_sustained)
        log.warning(
            "readmit at step %d: mesh %s -> %s (slice %d back on probation "
            "for %d steps), flips=%s", self.step, old_shape,
            dict(new_mesh.shape), ev["index"], self.tcfg.probation_steps,
            diff["flips"])
        return diff

    def _flip_stale(self, on: bool) -> Optional[dict]:
        """Flip the stale-eligible sparse tables to (or back from) the
        bounded-staleness push and hot-swap the jitted step. The staleness
        buffers themselves are plan-independent state (transform.py
        ``ensure_stale_buffers``): only ``Plan.stale_tables`` and the
        compiled step change, and the first synchronous step after a
        flip-back drains the last buffered gradient as part of its own
        update. Returns the plan diff, or None when nothing flips."""
        target = stale_buffer_tables(self.plan, self.rt) if on else ()
        if tuple(target) == tuple(getattr(self.plan, "stale_tables", ())):
            return None
        census = self._observed_census(self.plan) if self.profile.ready() \
            else None
        new_plan = analyze(self.model, self.rt, census=census,
                           stale_tables=tuple(target))
        diff = plan_diff(self.plan, new_plan)
        if not diff["changed"]:
            return None
        self._stale_tables = tuple(new_plan.stale_tables)
        self.plan = new_plan
        self.train_step, self.state, self.shardings = apply_replan(
            self.model, self.optimizer, self.rt, new_plan, self.state, diff)
        self.monitor.note_stale_flip(bool(new_plan.stale_tables))
        self._note_plan_costs()
        log.warning(
            "stale flip at step %d (jitter %.2f): tables %s now %s "
            "(max_staleness=%d)", self.step, self.monitor.jitter_ratio,
            list(new_plan.stale_tables) or diff["stale_flips"],
            "bounded-stale" if new_plan.stale_tables else "synchronous",
            getattr(self.run_cfg, "max_staleness", 0))
        return diff

    # ------------------------------------------------------------------
    def maybe_replan(self) -> Optional[dict]:
        """Re-run the planner on the observed census; hot-swap on change.

        Per-parameter: the census carries one record per sparse table
        (measured unique rows, overflow EMA, overflow-grown capacity) plus
        profiled wire-dtype hints from the dense-gradient magnitude census,
        so each table / bucket group can move independently. Returns the
        plan diff when a replan was evaluated, None when the profile has no
        data yet. Reuses the remesh reshard path only when pspecs actually
        moved; otherwise state stays put and just the jitted step is
        rebuilt against the new plan.
        """
        if not self.profile.ready(self.tcfg.replan_warmup):
            return None
        census = self._observed_census(self.plan)
        new_plan = analyze(self.model, self.rt, census=census,
                           stale_tables=self._stale_tables)
        diff = plan_diff(self.plan, new_plan, self.tcfg.replan_drift)
        self.monitor.note_alpha(census.alpha)
        if not diff["changed"]:
            return diff
        log.info(
            "replan at step %d: alpha %.4f -> %.4f, capacity %d -> %d "
            "(tables %s -> %s%s), flips=%s, wire_flips=%s, "
            "pspecs_changed=%s", self.step, diff["alpha"][0],
            diff["alpha"][1], diff["capacity"][0], diff["capacity"][1],
            diff["table_capacity"][0], diff["table_capacity"][1],
            ", overflow-grown" if diff["capacity_grown"] else "",
            diff["flips"], diff["wire_flips"], diff["pspecs_changed"])
        old_sig = _bucket_signature(self.plan)
        self.plan = new_plan
        self.train_step, self.state, self.shardings = apply_replan(
            self.model, self.optimizer, self.rt, new_plan, self.state, diff)
        if _bucket_signature(new_plan) != old_sig:
            # bucket metrics are index-keyed: a regrouped layout makes the
            # old per-bucket magnitude EMAs mis-attributed — start fresh
            self.profile.reset_grad_census()
        self.monitor.note_replan()
        self._note_plan_costs()
        return diff

    # ------------------------------------------------------------------
    def _heartbeat_batch(self, batch: dict) -> dict:
        """Inject the per-slot heartbeat vector the step carries through
        the fused metrics psum (one f32 scalar per data slice). Single
        controller: every slot gets this process's last measured step time,
        so attribution reads flat unless ``heartbeat_fn`` (a multi-host
        shim, or the chaos bench) supplies genuinely per-slot timings."""
        if not getattr(self.run_cfg, "heartbeat", False) \
                or self.mesh is None:
            return batch
        n = max(self.rt.replicas, 1)
        if self.heartbeat_fn is not None:
            hb = np.asarray(self.heartbeat_fn(self.step, n),
                            np.float32).reshape(n)
        else:
            t = self.monitor.times[-1] if self.monitor.times else 0.0
            hb = np.full((n,), float(t), np.float32)
        batch = dict(batch)
        batch["_heartbeat"] = hb
        return batch

    def run(self, on_metrics: Optional[Callable[[int, dict], None]] = None):
        tokens_per_step = self.shape_cfg.tokens
        retries = 0
        while self.step < self.tcfg.total_steps:
            batch = self._heartbeat_batch(self.dataset.batch(self.step))
            self.monitor.start()
            try:
                self.state, metrics = self.train_step(self.state, batch)
                if (self.step + 1) % self.tcfg.metrics_host_every == 0:
                    metrics = {k: float(v) for k, v in metrics.items()
                               if getattr(v, "ndim", 0) == 0}
                    # decode the heartbeat slots out of the fused metrics
                    # psum into the attribution state (and out of the
                    # user-visible metrics — stats carries the EMAs)
                    beats = {int(k[9:]): metrics.pop(k)
                             for k in list(metrics)
                             if k.startswith("heartbeat")
                             and k[9:].isdigit()}
                    if beats:
                        self.monitor.note_heartbeats(beats)
                    self.profile.update(metrics)
                    # overflow is visible host-side every profiled step, not
                    # just when (or if) the growth replan fires; restricted
                    # to real sparse tables (the MoE router also emits a
                    # *_dropped scalar that is not buffer overflow)
                    self.monitor.note_overflow(
                        self.profile.dropped(self.plan.table_methods))
                retries = 0
            except Exception:  # failure path: restore + retry
                retries += 1
                log.exception("step %d failed (retry %d/%d)",
                              self.step, retries, self.tcfg.max_retries)
                if retries > self.tcfg.max_retries or self.ckpt is None:
                    raise
                try:
                    self.ckpt.wait()
                except Exception:
                    log.exception("in-flight checkpoint also failed")
                if latest_step(self.tcfg.ckpt_dir) is None:
                    # no committed checkpoint to fall back on — and the
                    # failed call may already have consumed the donated
                    # state buffers, so retrying on self.state would feed
                    # the step poisoned memory. Rebuild from scratch.
                    log.warning("no committed checkpoint: reinitializing "
                                "state from seed %d at step 0",
                                self.run_cfg.seed)
                    self.train_step, self.state, self.shardings = build_step(
                        self.model, self.optimizer, self.rt, self.plan,
                        None, seed=self.run_cfg.seed)
                    self.step = 0
                    self.monitor.note_recovery()
                else:
                    self.maybe_restore()
                continue
            stats = self.monitor.stop(tokens=tokens_per_step)
            self.step += 1
            if self.tcfg.replan_every and \
                    self.step % self.tcfg.replan_every == 0:
                self.maybe_replan()
                # this step's stats must reflect a replan it triggered
                stats["replans"] = self.monitor.replans
                if self.monitor.observed_alpha is not None:
                    stats["observed_alpha"] = self.monitor.observed_alpha
            if self.ckpt is not None:
                # mirror the background-writer state each step (before the
                # save below can consume it): a pending failure keeps
                # re-noting until consumed; once the writer is clean again
                # and no new failure is noted, the signal self-heals
                self.monitor.note_ckpt_error(self.ckpt.error)
                self.monitor.note_ckpt_retries(self.ckpt.total_retries)
            if self.ckpt is not None and self.step % self.tcfg.ckpt_every == 0:
                # a failed *previous* background write re-raises out of
                # save()'s internal wait(); periodic checkpointing is not
                # worth aborting a healthy run — surface it and try again
                # next period (the final end-of-run save still raises)
                try:
                    self.ckpt.save(self.step, self._canonical_state(),
                                   extra=self._ckpt_extra())
                except Exception as e:
                    log.exception("checkpoint at step %d failed", self.step)
                    self.monitor.note_ckpt_error(e)
            if self.monitor.remesh_suggested and self.tcfg.remesh_on_straggle:
                if self._auto_remesh() is not None:
                    stats["remeshes"] = self.monitor.remeshes
                    if self.mesh is not None:
                        stats["mesh"] = dict(self.mesh.shape)
            elif self.monitor.straggler_suspected:
                log.warning("sustained step-time regression at step %d — "
                            "straggler suspected; consider remesh() or "
                            "remesh_on_straggle=True", self.step)
            elif self.tcfg.stale_on_jitter and \
                    getattr(self.run_cfg, "max_staleness", 0) > 0:
                # the jitter fallback sits strictly below eviction: only
                # consulted when no straggler escalation is in flight
                if self.monitor.stale_suggested:
                    flipped = self._flip_stale(True)
                elif self.monitor.stale_recovered:
                    flipped = self._flip_stale(False)
                else:
                    flipped = None
                if flipped is not None:
                    stats["stale_flips"] = self.monitor.stale_flips
                    stats["stale_mode"] = self.monitor._stale_on
            if on_metrics is not None:
                on_metrics(self.step, {**metrics, **stats})
            elif self.step % self.tcfg.log_every == 0:
                log.info("step %d loss %.4f %.0f tok/s", self.step,
                         metrics.get("loss", float("nan")),
                         stats["tokens_per_s"])
        if self.ckpt is not None:
            self.ckpt.save(self.step, self._canonical_state(),
                           extra=self._ckpt_extra())
            self.ckpt.wait()
        return self.state
