"""Fault-tolerant training driver.

Responsibilities beyond the jitted step:
  * deterministic resume — the data pipeline is step-addressed, so restoring
    (state, step) from a checkpoint reproduces the exact remaining stream;
  * checkpoint/restart — async sharded checkpoints every N steps; on any
    step failure the driver restores the last committed checkpoint and
    continues (bounded retries);
  * elastic re-mesh — ``Trainer.remesh(new_mesh)`` rebuilds the plan/step on
    a different mesh and reshards the live state through the elastic
    checkpoint path (the node-failure story: drop the bad host's slice,
    re-mesh, resume);
  * adaptive replanning — with ``replan_every > 0`` the driver feeds the
    in-graph sparsity census (``embed_unique`` metrics) into a
    ``SparsityProfile`` EMA and periodically re-runs the planner on the
    *observed* census (paper §5's profile → re-optimize loop). When the
    cost model flips a method or the capacity drifts past
    ``replan_drift``x, the jitted step is rebuilt and the live state
    reshards in place — device-side when pspecs are unchanged, through the
    remesh host path otherwise;
  * straggler detection via runtime/monitor.py.
"""
from __future__ import annotations

import logging
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro import compat
from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step,
                                   restore_checkpoint)
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.plan import plan_diff, plan_leaves
from repro.core.runtime import Runtime
from repro.core.sparsity import (SparsityProfile, observed_census,
                                 wire_dtype_hints)
from repro.core.transform import (analyze, apply_replan, build_step,
                                  estimate_census)
from repro.data.pipeline import Dataset
from repro.models.model import build_model
from repro.optim.optimizer import make_optimizer
from repro.runtime.monitor import StepMonitor

log = logging.getLogger("repro.trainer")


def _bucket_signature(plan) -> tuple:
    """The identity of a plan's bucket layout: per-bucket member indices and
    wire dtype, in order. Index-keyed gbucket EMAs are only comparable
    between plans with equal signatures."""
    if plan.bucket_plan is None:
        return ()
    return tuple((b.idx, b.key[1]) for b in plan.bucket_plan.buckets)


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    max_retries: int = 3
    log_every: int = 10
    metrics_host_every: int = 1
    # ---- profile -> replan loop (0 disables) ----
    replan_every: int = 0          # consider replanning every N steps
    replan_warmup: int = 2         # min profiled steps before first replan
    replan_drift: float = 1.5      # capacity drift factor that triggers it
    profile_decay: float = 0.9     # EMA decay of the sparsity profile


class Trainer:
    def __init__(self, model_cfg: ModelConfig, shape_cfg: ShapeConfig,
                 run_cfg: RunConfig, tcfg: TrainerConfig,
                 dataset: Dataset, mesh=None):
        self.model_cfg, self.shape_cfg = model_cfg, shape_cfg
        self.run_cfg, self.tcfg = run_cfg, tcfg
        self.dataset = dataset
        self.monitor = StepMonitor()
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir, tcfg.keep_ckpts) \
            if tcfg.ckpt_dir else None
        self.step = 0
        self.profile = SparsityProfile(decay=tcfg.profile_decay)
        log.debug("jax %s compat=%s", jax.__version__, compat.capabilities())
        self._build(mesh)

    # ------------------------------------------------------------------
    def _build(self, mesh, state=None):
        """(Re)build plan + jitted step; ``state`` (host or device arrays)
        is resharded onto the new plan instead of re-initializing."""
        self.mesh = mesh
        self.rt = Runtime(self.model_cfg, self.run_cfg, self.shape_cfg,
                          mesh=mesh)
        self.model = build_model(self.model_cfg, self.rt)
        self.plan = analyze(self.model, self.rt)
        self.rt.plan = self.plan
        self.optimizer = make_optimizer(self.rt)
        self.train_step, self.state, self.shardings = build_step(
            self.model, self.optimizer, self.rt, self.plan, state,
            seed=self.run_cfg.seed)
        self.monitor.note_exchange(
            self.plan.bucket_plan.stats() if self.plan.bucket_plan else None)

    # ------------------------------------------------------------------
    def maybe_restore(self):
        if self.ckpt is None:
            return
        last = latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return
        self.state, self.step, extra = restore_checkpoint(
            self.tcfg.ckpt_dir, self.state, shardings=self.shardings)
        log.info("restored checkpoint at step %d", self.step)

    def remesh(self, new_mesh):
        """Elastic re-mesh: reshard live state onto a new mesh (e.g. after
        dropping a failed host slice). The rebuild derives shardings from
        the restored values themselves — no throwaway ``model.init``."""
        host_state = jax.tree.map(
            lambda a: None if a is None else np.asarray(jax.device_get(a)),
            self.state)
        self._build(new_mesh, state=host_state)

    # ------------------------------------------------------------------
    def maybe_replan(self) -> Optional[dict]:
        """Re-run the planner on the observed census; hot-swap on change.

        Per-parameter: the census carries one record per sparse table
        (measured unique rows, overflow EMA, overflow-grown capacity) plus
        profiled wire-dtype hints from the dense-gradient magnitude census,
        so each table / bucket group can move independently. Returns the
        plan diff when a replan was evaluated, None when the profile has no
        data yet. Reuses the remesh reshard path only when pspecs actually
        moved; otherwise state stays put and just the jitted step is
        rebuilt against the new plan.
        """
        if not self.profile.ready(self.tcfg.replan_warmup):
            return None
        base = estimate_census(self.model, self.rt)
        live = {n: (self.plan.table_capacity.get(n, 0),
                    n in self.plan.grown_tables)
                for n in self.plan.table_methods}
        census = observed_census(self.profile, base,
                                 self.model_cfg.vocab_size, self.run_cfg,
                                 live=live)
        if self.run_cfg.wire_dtype_auto and self.plan.bucket_plan is not None:
            names = [p.name for p in plan_leaves(self.plan.params)]
            census.wire_dtypes = wire_dtype_hints(
                self.profile, self.plan.bucket_plan, names,
                outlier_ratio=self.run_cfg.wire_outlier_ratio,
                default=self.run_cfg.wire_dtype)
        new_plan = analyze(self.model, self.rt, census=census)
        diff = plan_diff(self.plan, new_plan, self.tcfg.replan_drift)
        self.monitor.note_alpha(census.alpha)
        if not diff["changed"]:
            return diff
        log.info(
            "replan at step %d: alpha %.4f -> %.4f, capacity %d -> %d "
            "(tables %s -> %s%s), flips=%s, wire_flips=%s, "
            "pspecs_changed=%s", self.step, diff["alpha"][0],
            diff["alpha"][1], diff["capacity"][0], diff["capacity"][1],
            diff["table_capacity"][0], diff["table_capacity"][1],
            ", overflow-grown" if diff["capacity_grown"] else "",
            diff["flips"], diff["wire_flips"], diff["pspecs_changed"])
        old_sig = _bucket_signature(self.plan)
        self.plan = new_plan
        self.train_step, self.state, self.shardings = apply_replan(
            self.model, self.optimizer, self.rt, new_plan, self.state, diff)
        if _bucket_signature(new_plan) != old_sig:
            # bucket metrics are index-keyed: a regrouped layout makes the
            # old per-bucket magnitude EMAs mis-attributed — start fresh
            self.profile.reset_grad_census()
        self.monitor.note_replan()
        self.monitor.note_exchange(
            new_plan.bucket_plan.stats() if new_plan.bucket_plan else None)
        return diff

    # ------------------------------------------------------------------
    def run(self, on_metrics: Optional[Callable[[int, dict], None]] = None):
        tokens_per_step = self.shape_cfg.tokens
        retries = 0
        while self.step < self.tcfg.total_steps:
            batch = self.dataset.batch(self.step)
            self.monitor.start()
            try:
                self.state, metrics = self.train_step(self.state, batch)
                if (self.step + 1) % self.tcfg.metrics_host_every == 0:
                    metrics = {k: float(v) for k, v in metrics.items()
                               if getattr(v, "ndim", 0) == 0}
                    self.profile.update(metrics)
                    # overflow is visible host-side every profiled step, not
                    # just when (or if) the growth replan fires; restricted
                    # to real sparse tables (the MoE router also emits a
                    # *_dropped scalar that is not buffer overflow)
                    self.monitor.note_overflow(
                        self.profile.dropped(self.plan.table_methods))
                retries = 0
            except Exception as e:  # failure path: restore + retry
                retries += 1
                log.exception("step %d failed (retry %d/%d)",
                              self.step, retries, self.tcfg.max_retries)
                if retries > self.tcfg.max_retries or self.ckpt is None:
                    raise
                self.ckpt.wait()
                self.maybe_restore()
                continue
            stats = self.monitor.stop(tokens=tokens_per_step)
            self.step += 1
            if self.tcfg.replan_every and \
                    self.step % self.tcfg.replan_every == 0:
                self.maybe_replan()
                # this step's stats must reflect a replan it triggered
                stats["replans"] = self.monitor.replans
                if self.monitor.observed_alpha is not None:
                    stats["observed_alpha"] = self.monitor.observed_alpha
            if self.ckpt is not None and self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step, self.state,
                               extra={"dataset_step": self.step})
            if on_metrics is not None:
                on_metrics(self.step, {**metrics, **stats})
            elif self.step % self.tcfg.log_every == 0:
                log.info("step %d loss %.4f %.0f tok/s", self.step,
                         metrics.get("loss", float("nan")),
                         stats["tokens_per_s"])
            if self.monitor.straggler_suspected:
                log.warning("sustained step-time regression at step %d — "
                            "straggler suspected; consider remesh()",
                            self.step)
        if self.ckpt is not None:
            self.ckpt.save(self.step, self.state,
                           extra={"dataset_step": self.step})
            self.ckpt.wait()
        return self.state
