"""Fault-tolerant training driver.

Responsibilities beyond the jitted step:
  * deterministic resume — the data pipeline is step-addressed, so restoring
    (state, step) from a checkpoint reproduces the exact remaining stream;
  * checkpoint/restart — async sharded checkpoints every N steps; on any
    step failure the driver restores the last committed checkpoint and
    continues (bounded retries);
  * elastic re-mesh — ``Trainer.remesh(new_mesh)`` rebuilds the plan/step on
    a different mesh and reshards the live state through the elastic
    checkpoint path (the node-failure story: drop the bad host's slice,
    re-mesh, resume);
  * straggler detection via runtime/monitor.py.
"""
from __future__ import annotations

import logging
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro import compat
from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step,
                                   restore_checkpoint)
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.runtime import Runtime
from repro.core.transform import (analyze, batch_shardings, make_train_step,
                                  state_shardings)
from repro.data.pipeline import Dataset
from repro.models.model import build_model
from repro.optim.optimizer import make_optimizer
from repro.runtime.monitor import StepMonitor

log = logging.getLogger("repro.trainer")


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    max_retries: int = 3
    log_every: int = 10
    metrics_host_every: int = 1


class Trainer:
    def __init__(self, model_cfg: ModelConfig, shape_cfg: ShapeConfig,
                 run_cfg: RunConfig, tcfg: TrainerConfig,
                 dataset: Dataset, mesh=None):
        self.model_cfg, self.shape_cfg = model_cfg, shape_cfg
        self.run_cfg, self.tcfg = run_cfg, tcfg
        self.dataset = dataset
        self.monitor = StepMonitor()
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir, tcfg.keep_ckpts) \
            if tcfg.ckpt_dir else None
        self.step = 0
        log.debug("jax %s compat=%s", jax.__version__, compat.capabilities())
        self._build(mesh)

    # ------------------------------------------------------------------
    def _build(self, mesh, state=None):
        self.mesh = mesh
        self.rt = Runtime(self.model_cfg, self.run_cfg, self.shape_cfg,
                          mesh=mesh)
        self.model = build_model(self.model_cfg, self.rt)
        self.plan = analyze(self.model, self.rt)
        self.rt.plan = self.plan
        self.optimizer = make_optimizer(self.rt)
        step_fn = make_train_step(self.model, self.optimizer, self.rt,
                                  self.plan)
        if state is None:
            params = self.model.init(jax.random.key(self.run_cfg.seed))
            state = self.optimizer.init(params)
        if mesh is not None:
            with compat.use_mesh(mesh):
                self.shardings = state_shardings(self.plan, state)
                state = jax.device_put(state, self.shardings)
                bs = batch_shardings(self.plan, self.model.input_specs())
                self.train_step = jax.jit(
                    step_fn, in_shardings=(self.shardings, bs),
                    out_shardings=(self.shardings, None), donate_argnums=0)
        else:
            self.shardings = None
            self.train_step = jax.jit(step_fn, donate_argnums=0)
        self.state = state

    # ------------------------------------------------------------------
    def maybe_restore(self):
        if self.ckpt is None:
            return
        last = latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return
        self.state, self.step, extra = restore_checkpoint(
            self.tcfg.ckpt_dir, self.state, shardings=self.shardings)
        log.info("restored checkpoint at step %d", self.step)

    def remesh(self, new_mesh):
        """Elastic re-mesh: reshard live state onto a new mesh (e.g. after
        dropping a failed host slice)."""
        host_state = jax.tree.map(
            lambda a: None if a is None else np.asarray(jax.device_get(a)),
            self.state)
        self._build(new_mesh, state=None)
        # reshard the old values onto the new mesh
        def put(old, new_sh):
            return jax.device_put(old, new_sh) if old is not None else None
        if self.shardings is not None:
            self.state = jax.tree.map(put, host_state, self.shardings)
        else:
            self.state = jax.device_put(host_state)

    # ------------------------------------------------------------------
    def run(self, on_metrics: Optional[Callable[[int, dict], None]] = None):
        tokens_per_step = self.shape_cfg.tokens
        retries = 0
        while self.step < self.tcfg.total_steps:
            batch = self.dataset.batch(self.step)
            self.monitor.start()
            try:
                self.state, metrics = self.train_step(self.state, batch)
                if (self.step + 1) % self.tcfg.metrics_host_every == 0:
                    metrics = {k: float(v) for k, v in metrics.items()
                               if getattr(v, "ndim", 0) == 0}
                retries = 0
            except Exception as e:  # failure path: restore + retry
                retries += 1
                log.exception("step %d failed (retry %d/%d)",
                              self.step, retries, self.tcfg.max_retries)
                if retries > self.tcfg.max_retries or self.ckpt is None:
                    raise
                self.ckpt.wait()
                self.maybe_restore()
                continue
            stats = self.monitor.stop(tokens=tokens_per_step)
            self.step += 1
            if self.ckpt is not None and self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step, self.state,
                               extra={"dataset_step": self.step})
            if on_metrics is not None:
                on_metrics(self.step, {**metrics, **stats})
            elif self.step % self.tcfg.log_every == 0:
                log.info("step %d loss %.4f %.0f tok/s", self.step,
                         metrics.get("loss", float("nan")),
                         stats["tokens_per_s"])
            if self.monitor.straggler_suspected:
                log.warning("sustained step-time regression at step %d — "
                            "straggler suspected; consider remesh()",
                            self.step)
        if self.ckpt is not None:
            self.ckpt.save(self.step, self.state,
                           extra={"dataset_step": self.step})
            self.ckpt.wait()
        return self.state
