"""Step-time monitoring: throughput accounting + straggler detection.

In synchronous data-parallel training a straggling host slows every step
(the collective waits). Without per-host timers (single-controller here),
stragglers manifest as step-time outliers; the monitor flags sustained
regressions so the driver loop can act (checkpoint + re-mesh without the
slow host = the elastic restart path in trainer.py).

The monitor also carries the adaptive-replanning telemetry: the trainer
reports the observed sparsity α (from the SparsityProfile EMA) and every
plan hot-swap, and both show up in the per-step stats dict.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class StepMonitor:
    window: int = 50
    straggler_factor: float = 2.0     # step > factor x median => outlier
    sustained: int = 5                # consecutive outliers => straggler
    times: collections.deque = field(default_factory=collections.deque)
    _last: float = 0.0
    _outlier_run: int = 0
    total_steps: int = 0
    total_tokens: int = 0
    observed_alpha: Optional[float] = None   # latest measured sparse α
    replans: int = 0                         # plan hot-swaps so far
    exchange: Optional[dict] = None          # bucketed-exchange accounting
                                             # (core/buckets.py stats)
    overflow: Optional[dict] = None          # per-table embed_dropped EMA
                                             # (rows silently zeroed / step)

    def start(self):
        self._last = time.perf_counter()

    def note_alpha(self, alpha: float):
        self.observed_alpha = float(alpha)

    def note_replan(self):
        self.replans += 1

    def note_overflow(self, dropped: dict):
        """Record the per-table overflow EMA ({table: dropped rows/step}) —
        visible in stats before the capacity-growth replan fires, and its
        decay back to ~0 is the growth loop's success signal."""
        self.overflow = {k: float(v) for k, v in dropped.items()} \
            if dropped else None

    def note_exchange(self, stats: Optional[dict]):
        """Record the live plan's dense-exchange shape: bucket count, fused
        wire bytes, and per-step collective launches (None = per-tensor)."""
        self.exchange = dict(stats) if stats else None

    def stop(self, tokens: int = 0) -> dict:
        dt = time.perf_counter() - self._last
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.popleft()
        self.total_steps += 1
        self.total_tokens += tokens
        med = self.median()
        is_outlier = len(self.times) >= 10 and dt > self.straggler_factor * med
        self._outlier_run = self._outlier_run + 1 if is_outlier else 0
        stats = {
            "step_time_s": dt,
            "median_s": med,
            "tokens_per_s": tokens / dt if dt > 0 else 0.0,
            "straggler_suspected": self.straggler_suspected,
            "replans": self.replans,
        }
        if self.observed_alpha is not None:
            stats["observed_alpha"] = self.observed_alpha
        if self.overflow is not None:
            # per-table {table: dropped-rows EMA}; scalar max under its own
            # key so it can't shadow the raw per-step embed_dropped metric
            stats["overflow"] = dict(self.overflow)
            stats["overflow_rows"] = max(self.overflow.values(), default=0.0)
        if self.exchange is not None:
            stats["n_collectives"] = self.exchange["n_collectives_dense"]
            stats["exchange"] = self.exchange
        return stats

    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        return s[len(s) // 2]

    @property
    def straggler_suspected(self) -> bool:
        return self._outlier_run >= self.sustained
