"""Step-time monitoring: throughput accounting + straggler escalation.

In synchronous data-parallel training a straggling host slows every step
(the collective waits). Without per-host timers (single-controller here),
stragglers manifest as step-time outliers; the monitor flags sustained
regressions and — once the run is past ``sustained`` consecutive outliers
and outside the post-remesh ``cooldown`` — *escalates* to
``remesh_suggested``, the signal the trainer's auto-remesh path acts on
(checkpoint + re-mesh without the slow slice, runtime/trainer.py).

Recovery awareness: restore/rebuild pauses (``note_recovery``) drop the
in-flight timing sample and the outlier run so recovery latency never reads
as a straggler, and a landed remesh (``note_remesh``) clears the whole
timing window — the new world size is a different step-time regime, and
comparing it against old-mesh medians would instantly re-trigger. A landed
re-growth (``note_regrow``) resets the window and the cooldown origin the
same way — a grow immediately followed by jitter must not double-escalate
off stale pre-grow medians — and additionally arms a *probation* window
for the re-admitted slice: if that slice's heartbeat re-straggles within
the window, ``remesh_suggested`` fires after ``probation_sustained``
outlier heartbeats, bypassing the full escalation run and the cooldown.

Attribution: when per-host heartbeat scalars ride the fused metrics psum
(``RunConfig.heartbeat``), the trainer decodes them host-side and feeds
``note_heartbeats``; the monitor keeps a per-slice EMA and per-slice
outlier runs, and ``straggler_slice()`` names the slow data slice so the
eviction drops *that* host instead of the last slice by convention.

Jitter fallback: the same outlier flags, kept as a windowed ratio with
enter/exit hysteresis, drive the bounded-staleness sparse fallback — a run
that is jittery (``jitter_enter`` fraction of steps are outliers) but not
*sustained* enough to evict suggests flipping sparse tables to stale
pushes (``stale_suggested``); dropping back under ``jitter_exit`` suggests
flipping back (``stale_recovered``).

The monitor also carries the adaptive-replanning telemetry: the trainer
reports the observed sparsity α (from the SparsityProfile EMA) and every
plan hot-swap, and both show up in the per-step stats dict — as does any
error the async checkpointer hit in the background (``note_ckpt_error``),
so a failing checkpoint path is visible *now*, not on the next ``wait()``.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class StepMonitor:
    window: int = 50
    straggler_factor: float = 2.0     # step > factor x median => outlier
    sustained: int = 5                # consecutive outliers => straggler
    min_samples: int = 10             # window fill before outlier detection
    cooldown: int = 0                 # steps after a remesh before the
                                      # monitor may suggest another (0 = none)
    jitter_enter: float = 0.3         # outlier fraction that suggests the
                                      # stale fallback (below eviction)
    jitter_exit: float = 0.1          # outlier fraction that suggests
                                      # flipping back to synchronous
    heartbeat_decay: float = 0.5      # per-slice heartbeat EMA decay
    times: collections.deque = field(default_factory=collections.deque)
    _last: Optional[float] = None     # start() timestamp; None = no sample
    _outlier_run: int = 0
    _outlier_flags: collections.deque = field(
        default_factory=collections.deque)   # windowed outlier bits (jitter)
    total_steps: int = 0
    total_tokens: int = 0
    observed_alpha: Optional[float] = None   # latest measured sparse α
    replans: int = 0                         # plan hot-swaps so far
    remeshes: int = 0                        # elastic mesh shrinks so far
    regrows: int = 0                         # elastic mesh re-growths so far
    stale_flips: int = 0                     # sync<->stale plan flips so far
    ckpt_retries: int = 0                    # background ckpt write retries
    heartbeats: dict = field(default_factory=dict)  # slice -> step-time EMA
    _slot_runs: dict = field(default_factory=dict)  # slice -> outlier run
    _probation: Optional[tuple] = None       # (slice, until_step, sustained)
    _probation_trip: Optional[int] = None    # slice that re-straggled on
                                             # probation (fast re-evict)
    _stale_on: bool = False                  # live plan has stale tables
    _last_remesh_step: Optional[int] = None  # total_steps at the last remesh
    ckpt_error: Optional[str] = None         # background checkpoint failure
    exchange: Optional[dict] = None          # bucketed-exchange accounting
                                             # (core/buckets.py stats)
    apply_seconds: Optional[float] = None    # analytic optimizer-apply cost
                                             # (state bytes / HBM bandwidth,
                                             # fused-apply aware)
    overflow: Optional[dict] = None          # per-table embed_dropped EMA
                                             # (rows silently zeroed / step)

    def start(self):
        self._last = time.perf_counter()

    def note_alpha(self, alpha: float):
        self.observed_alpha = float(alpha)

    def note_replan(self):
        self.replans += 1

    def note_remesh(self):
        """An elastic remesh landed: count it, arm the cooldown, and clear
        the timing window + outlier run — step times on the shrunken mesh
        are a different regime, and old-mesh medians would mis-attribute
        the first post-remesh (recompile) steps as fresh outliers."""
        self.remeshes += 1
        self._last_remesh_step = self.total_steps
        self.times.clear()
        self._outlier_run = 0
        self._outlier_flags.clear()
        self.heartbeats.clear()
        self._slot_runs.clear()
        self._probation = None
        self._probation_trip = None

    def note_regrow(self, slot: Optional[int] = None,
                    probation_steps: int = 0, probation_sustained: int = 2):
        """An elastic re-growth landed (an evicted host was re-admitted):
        count it and reset the escalation window + cooldown origin exactly
        like ``note_remesh`` — the grown world is a new step-time regime,
        and without the reset a grow immediately followed by jitter would
        double-escalate off pre-grow medians. Additionally arm a probation
        window on the re-admitted slice ``slot``: for ``probation_steps``
        steps, ``probation_sustained`` consecutive outlier heartbeats from
        that slice escalate straight to ``remesh_suggested`` — no second
        full ``sustained`` run, no cooldown wait."""
        self.regrows += 1
        self._last_remesh_step = self.total_steps
        self.times.clear()
        self._outlier_run = 0
        self._outlier_flags.clear()
        self.heartbeats.clear()
        self._slot_runs.clear()
        self._probation_trip = None
        self._probation = None
        if slot is not None and probation_steps > 0:
            self._probation = (int(slot), self.total_steps + probation_steps,
                               max(int(probation_sustained), 1))

    def note_heartbeats(self, beats: dict):
        """Fold decoded per-slice heartbeat scalars ({data-slice index ->
        step seconds}) into the attribution state: per-slice EMAs plus
        per-slice outlier runs (a slice is an outlier when its EMA exceeds
        ``straggler_factor`` x the median of the *other* slices). While a
        probation window is armed, the probationer re-straggling for
        ``probation_sustained`` beats trips the fast re-evict."""
        d = self.heartbeat_decay
        for slot, v in beats.items():
            slot = int(slot)
            old = self.heartbeats.get(slot)
            self.heartbeats[slot] = float(v) if old is None else \
                d * old + (1.0 - d) * float(v)
        if len(self.heartbeats) < 2:
            return
        for slot, ema in self.heartbeats.items():
            others = [v for s, v in self.heartbeats.items() if s != slot]
            others.sort()
            n = len(others)
            med = others[n // 2] if n % 2 else \
                0.5 * (others[n // 2 - 1] + others[n // 2])
            if med > 0 and ema > self.straggler_factor * med:
                self._slot_runs[slot] = self._slot_runs.get(slot, 0) + 1
            else:
                self._slot_runs[slot] = 0
        if self._probation is not None:
            slot, until, sustained = self._probation
            if self.total_steps > until:
                self._probation = None
            elif self._slot_runs.get(slot, 0) >= sustained:
                self._probation_trip = slot

    def straggler_slice(self) -> Optional[int]:
        """Name the slow data slice, when the heartbeats attribute one: the
        probation tripper if armed, else the slice whose outlier run meets
        ``sustained``. None = no attribution (the trainer falls back to its
        by-convention drop)."""
        if self._probation_trip is not None:
            return self._probation_trip
        best = None
        for slot, run in self._slot_runs.items():
            if run >= self.sustained and (best is None or run > best[1]):
                best = (slot, run)
        return best[0] if best else None

    def note_stale_flip(self, on: bool):
        """A sync<->stale plan flip landed (the jitter fallback): record the
        live mode and clear the jitter window so the hysteresis refills
        under the new plan before the opposite flip can fire."""
        self._stale_on = bool(on)
        self.stale_flips += 1
        self._outlier_flags.clear()

    def note_ckpt_retries(self, total: int):
        """Surface the async checkpointer's cumulative transient-write
        retry count (checkpoint/ckpt.py backoff loop) in the stats."""
        self.ckpt_retries = int(total)

    def note_recovery(self):
        """A restore/rebuild pause happened (checkpoint restore, failed-step
        retry): drop the in-flight timing sample and reset the outlier run
        so recovery latency doesn't count toward the straggler escalation."""
        self._outlier_run = 0
        self._last = None

    def note_ckpt_error(self, err: Optional[BaseException]):
        """Surface a background checkpoint failure in the per-step stats
        (previously only raised on the *next* wait(), i.e. up to ckpt_every
        steps after the bytes stopped reaching disk)."""
        self.ckpt_error = None if err is None else \
            f"{type(err).__name__}: {err}"

    def note_overflow(self, dropped: dict):
        """Record the per-table overflow EMA ({table: dropped rows/step}) —
        visible in stats before the capacity-growth replan fires, and its
        decay back to ~0 is the growth loop's success signal."""
        self.overflow = {k: float(v) for k, v in dropped.items()} \
            if dropped else None

    def note_exchange(self, stats: Optional[dict]):
        """Record the live plan's dense-exchange shape: bucket count, fused
        wire bytes, and per-step collective launches (None = per-tensor)."""
        self.exchange = dict(stats) if stats else None

    def note_apply(self, seconds: Optional[float]):
        """Record the analytic optimizer-apply cost for the live plan —
        total HBM traffic of the update (params/moments/EMA read+write,
        grads read, plus the unflatten->reflatten round trip the fused
        bucket-apply skips) over the hardware model's bandwidth."""
        self.apply_seconds = None if seconds is None else float(seconds)

    def stop(self, tokens: int = 0) -> dict:
        # a cleared _last means note_recovery dropped the in-flight sample
        # (the pause spans a restore, not a training step): keep the
        # throughput accounting but record no timing sample for it
        dt = time.perf_counter() - self._last if self._last is not None \
            else None
        self._last = None
        if dt is not None:
            self.times.append(dt)
            if len(self.times) > self.window:
                self.times.popleft()
        self.total_steps += 1
        self.total_tokens += tokens
        med = self.median()
        is_outlier = dt is not None and len(self.times) >= self.min_samples \
            and dt > self.straggler_factor * med
        self._outlier_run = self._outlier_run + 1 if is_outlier else 0
        if dt is not None and len(self.times) >= self.min_samples:
            self._outlier_flags.append(is_outlier)
            if len(self._outlier_flags) > self.window:
                self._outlier_flags.popleft()
        dt = dt or 0.0
        stats = {
            "step_time_s": dt,
            "median_s": med,
            "tokens_per_s": tokens / dt if dt > 0 else 0.0,
            "straggler_suspected": self.straggler_suspected,
            "remesh_suggested": self.remesh_suggested,
            "replans": self.replans,
            "remeshes": self.remeshes,
            "regrows": self.regrows,
        }
        if self.heartbeats:
            stats["heartbeats"] = dict(self.heartbeats)
            slot = self.straggler_slice()
            if slot is not None:
                stats["straggler_slice"] = slot
        if self._probation is not None:
            stats["probation_slice"] = self._probation[0]
        if self._outlier_flags:
            stats["jitter_ratio"] = self.jitter_ratio
        if self._stale_on or self.stale_flips:
            stats["stale_mode"] = self._stale_on
            stats["stale_flips"] = self.stale_flips
        if self.ckpt_retries:
            stats["ckpt_retries"] = self.ckpt_retries
        if self.observed_alpha is not None:
            stats["observed_alpha"] = self.observed_alpha
        if self.ckpt_error is not None:
            stats["ckpt_error"] = self.ckpt_error
        if self.overflow is not None:
            # per-table {table: dropped-rows EMA}; scalar max under its own
            # key so it can't shadow the raw per-step embed_dropped metric
            stats["overflow"] = dict(self.overflow)
            stats["overflow_rows"] = max(self.overflow.values(), default=0.0)
        if self.exchange is not None:
            stats["n_collectives"] = self.exchange["n_collectives_dense"]
            stats["exchange"] = self.exchange
            # topology-aware schedule surfacing: how many buckets ride the
            # two-level inter-host schedule, and whether the exchange is
            # overlap-issued inside the backward
            if "n_two_level" in self.exchange:
                stats["n_two_level"] = self.exchange["n_two_level"]
            if "overlap" in self.exchange:
                stats["overlap"] = self.exchange["overlap"]
            # sparse row-buffer pushes issued at gradient readiness inside
            # the backward (0 with overlap off or no gatherv tables)
            if "n_overlapped_sparse" in self.exchange:
                stats["n_overlapped_sparse"] = \
                    self.exchange["n_overlapped_sparse"]
        if self.apply_seconds is not None:
            stats["apply_seconds"] = self.apply_seconds
        return stats

    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        n = len(s)
        if n % 2:
            return s[n // 2]
        return 0.5 * (s[n // 2 - 1] + s[n // 2])

    @property
    def straggler_suspected(self) -> bool:
        return self._outlier_run >= self.sustained

    @property
    def jitter_ratio(self) -> float:
        """Fraction of recent (window-filled) steps that were outliers —
        the signal for the bounded-staleness fallback: high ratio without a
        *sustained* run means intermittent contention, not a dead host."""
        if not self._outlier_flags:
            return 0.0
        return sum(self._outlier_flags) / len(self._outlier_flags)

    @property
    def stale_suggested(self) -> bool:
        """Sustained jitter below the eviction threshold: flip sparse
        tables to bounded-stale pushes instead of evicting anyone."""
        if self._stale_on or self.straggler_suspected:
            return False
        if len(self._outlier_flags) < self.min_samples:
            return False
        return self.jitter_ratio >= self.jitter_enter

    @property
    def stale_recovered(self) -> bool:
        """The jitter drained while the stale fallback was live: flip the
        tables back to synchronous (hysteresis: exit below jitter_exit)."""
        if not self._stale_on:
            return False
        if len(self._outlier_flags) < self.min_samples:
            return False
        return self.jitter_ratio <= self.jitter_exit

    @property
    def remesh_suggested(self) -> bool:
        """Escalation: a sustained outlier run outside the remesh cooldown.
        The trainer pairs this signal with a concrete shrink proposal
        (launch/mesh.shrink_mesh) before acting. A probation trip — the
        re-admitted slice re-straggled inside its probation window —
        escalates immediately, bypassing both the full sustained run and
        the cooldown (the first escalation already vetted this host)."""
        if self._probation_trip is not None:
            return True
        attributed = any(r >= self.sustained
                         for r in self._slot_runs.values())
        if not (self.straggler_suspected or attributed):
            return False
        if self.cooldown and self._last_remesh_step is not None and \
                self.total_steps - self._last_remesh_step < self.cooldown:
            return False
        return True
