"""Step-time monitoring: throughput accounting + straggler detection.

In synchronous data-parallel training a straggling host slows every step
(the collective waits). Without per-host timers (single-controller here),
stragglers manifest as step-time outliers; the monitor flags sustained
regressions so the driver loop can act (checkpoint + re-mesh without the
slow host = the elastic restart path in trainer.py).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field


@dataclass
class StepMonitor:
    window: int = 50
    straggler_factor: float = 2.0     # step > factor x median => outlier
    sustained: int = 5                # consecutive outliers => straggler
    times: collections.deque = field(default_factory=collections.deque)
    _last: float = 0.0
    _outlier_run: int = 0
    total_steps: int = 0
    total_tokens: int = 0

    def start(self):
        self._last = time.perf_counter()

    def stop(self, tokens: int = 0) -> dict:
        dt = time.perf_counter() - self._last
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.popleft()
        self.total_steps += 1
        self.total_tokens += tokens
        med = self.median()
        is_outlier = len(self.times) >= 10 and dt > self.straggler_factor * med
        self._outlier_run = self._outlier_run + 1 if is_outlier else 0
        return {
            "step_time_s": dt,
            "median_s": med,
            "tokens_per_s": tokens / dt if dt > 0 else 0.0,
            "straggler_suspected": self.straggler_suspected,
        }

    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        return s[len(s) // 2]

    @property
    def straggler_suspected(self) -> bool:
        return self._outlier_run >= self.sustained
