"""Step-time monitoring: throughput accounting + straggler escalation.

In synchronous data-parallel training a straggling host slows every step
(the collective waits). Without per-host timers (single-controller here),
stragglers manifest as step-time outliers; the monitor flags sustained
regressions and — once the run is past ``sustained`` consecutive outliers
and outside the post-remesh ``cooldown`` — *escalates* to
``remesh_suggested``, the signal the trainer's auto-remesh path acts on
(checkpoint + re-mesh without the slow slice, runtime/trainer.py).

Recovery awareness: restore/rebuild pauses (``note_recovery``) drop the
in-flight timing sample and the outlier run so recovery latency never reads
as a straggler, and a landed remesh (``note_remesh``) clears the whole
timing window — the new world size is a different step-time regime, and
comparing it against old-mesh medians would instantly re-trigger.

The monitor also carries the adaptive-replanning telemetry: the trainer
reports the observed sparsity α (from the SparsityProfile EMA) and every
plan hot-swap, and both show up in the per-step stats dict — as does any
error the async checkpointer hit in the background (``note_ckpt_error``),
so a failing checkpoint path is visible *now*, not on the next ``wait()``.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class StepMonitor:
    window: int = 50
    straggler_factor: float = 2.0     # step > factor x median => outlier
    sustained: int = 5                # consecutive outliers => straggler
    min_samples: int = 10             # window fill before outlier detection
    cooldown: int = 0                 # steps after a remesh before the
                                      # monitor may suggest another (0 = none)
    times: collections.deque = field(default_factory=collections.deque)
    _last: Optional[float] = None     # start() timestamp; None = no sample
    _outlier_run: int = 0
    total_steps: int = 0
    total_tokens: int = 0
    observed_alpha: Optional[float] = None   # latest measured sparse α
    replans: int = 0                         # plan hot-swaps so far
    remeshes: int = 0                        # elastic mesh shrinks so far
    _last_remesh_step: Optional[int] = None  # total_steps at the last remesh
    ckpt_error: Optional[str] = None         # background checkpoint failure
    exchange: Optional[dict] = None          # bucketed-exchange accounting
                                             # (core/buckets.py stats)
    apply_seconds: Optional[float] = None    # analytic optimizer-apply cost
                                             # (state bytes / HBM bandwidth,
                                             # fused-apply aware)
    overflow: Optional[dict] = None          # per-table embed_dropped EMA
                                             # (rows silently zeroed / step)

    def start(self):
        self._last = time.perf_counter()

    def note_alpha(self, alpha: float):
        self.observed_alpha = float(alpha)

    def note_replan(self):
        self.replans += 1

    def note_remesh(self):
        """An elastic remesh landed: count it, arm the cooldown, and clear
        the timing window + outlier run — step times on the shrunken mesh
        are a different regime, and old-mesh medians would mis-attribute
        the first post-remesh (recompile) steps as fresh outliers."""
        self.remeshes += 1
        self._last_remesh_step = self.total_steps
        self.times.clear()
        self._outlier_run = 0

    def note_recovery(self):
        """A restore/rebuild pause happened (checkpoint restore, failed-step
        retry): drop the in-flight timing sample and reset the outlier run
        so recovery latency doesn't count toward the straggler escalation."""
        self._outlier_run = 0
        self._last = None

    def note_ckpt_error(self, err: Optional[BaseException]):
        """Surface a background checkpoint failure in the per-step stats
        (previously only raised on the *next* wait(), i.e. up to ckpt_every
        steps after the bytes stopped reaching disk)."""
        self.ckpt_error = None if err is None else \
            f"{type(err).__name__}: {err}"

    def note_overflow(self, dropped: dict):
        """Record the per-table overflow EMA ({table: dropped rows/step}) —
        visible in stats before the capacity-growth replan fires, and its
        decay back to ~0 is the growth loop's success signal."""
        self.overflow = {k: float(v) for k, v in dropped.items()} \
            if dropped else None

    def note_exchange(self, stats: Optional[dict]):
        """Record the live plan's dense-exchange shape: bucket count, fused
        wire bytes, and per-step collective launches (None = per-tensor)."""
        self.exchange = dict(stats) if stats else None

    def note_apply(self, seconds: Optional[float]):
        """Record the analytic optimizer-apply cost for the live plan —
        total HBM traffic of the update (params/moments/EMA read+write,
        grads read, plus the unflatten->reflatten round trip the fused
        bucket-apply skips) over the hardware model's bandwidth."""
        self.apply_seconds = None if seconds is None else float(seconds)

    def stop(self, tokens: int = 0) -> dict:
        # a cleared _last means note_recovery dropped the in-flight sample
        # (the pause spans a restore, not a training step): keep the
        # throughput accounting but record no timing sample for it
        dt = time.perf_counter() - self._last if self._last is not None \
            else None
        self._last = None
        if dt is not None:
            self.times.append(dt)
            if len(self.times) > self.window:
                self.times.popleft()
        self.total_steps += 1
        self.total_tokens += tokens
        med = self.median()
        is_outlier = dt is not None and len(self.times) >= self.min_samples \
            and dt > self.straggler_factor * med
        self._outlier_run = self._outlier_run + 1 if is_outlier else 0
        dt = dt or 0.0
        stats = {
            "step_time_s": dt,
            "median_s": med,
            "tokens_per_s": tokens / dt if dt > 0 else 0.0,
            "straggler_suspected": self.straggler_suspected,
            "remesh_suggested": self.remesh_suggested,
            "replans": self.replans,
            "remeshes": self.remeshes,
        }
        if self.observed_alpha is not None:
            stats["observed_alpha"] = self.observed_alpha
        if self.ckpt_error is not None:
            stats["ckpt_error"] = self.ckpt_error
        if self.overflow is not None:
            # per-table {table: dropped-rows EMA}; scalar max under its own
            # key so it can't shadow the raw per-step embed_dropped metric
            stats["overflow"] = dict(self.overflow)
            stats["overflow_rows"] = max(self.overflow.values(), default=0.0)
        if self.exchange is not None:
            stats["n_collectives"] = self.exchange["n_collectives_dense"]
            stats["exchange"] = self.exchange
            # topology-aware schedule surfacing: how many buckets ride the
            # two-level inter-host schedule, and whether the exchange is
            # overlap-issued inside the backward
            if "n_two_level" in self.exchange:
                stats["n_two_level"] = self.exchange["n_two_level"]
            if "overlap" in self.exchange:
                stats["overlap"] = self.exchange["overlap"]
            # sparse row-buffer pushes issued at gradient readiness inside
            # the backward (0 with overlap off or no gatherv tables)
            if "n_overlapped_sparse" in self.exchange:
                stats["n_overlapped_sparse"] = \
                    self.exchange["n_overlapped_sparse"]
        if self.apply_seconds is not None:
            stats["apply_seconds"] = self.apply_seconds
        return stats

    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        n = len(s)
        if n % 2:
            return s[n // 2]
        return 0.5 * (s[n // 2 - 1] + s[n // 2])

    @property
    def straggler_suspected(self) -> bool:
        return self._outlier_run >= self.sustained

    @property
    def remesh_suggested(self) -> bool:
        """Escalation: a sustained outlier run outside the remesh cooldown.
        The trainer pairs this signal with a concrete shrink proposal
        (launch/mesh.shrink_mesh) before acting."""
        if not self.straggler_suspected:
            return False
        if self.cooldown and self._last_remesh_step is not None and \
                self.total_steps - self._last_remesh_step < self.cooldown:
            return False
        return True
