"""Optimizers with Parallax placement discipline.

Correctness rules from the paper (§3.1, §5.3.2) enforced structurally:
  * gradient clipping happens AFTER aggregation (grads from jax.grad in
    global semantics are post-aggregation by construction); the global norm
    is computed as per-shard partial ‖g‖² + scalar psum — only scalars cross
    shards (OPAU). The OPAU=off baseline force-replicates gradients first so
    the naive placement's extra all-gathers are visible in HLO.
  * AccumParams (Adam moments, momentum) live with their parameter shard
    (same sharding as the parameter, optionally further sharded by ZeRO-1).
  * EMA shadow parameters update when their parameter updates, on the same
    shard (the paper's moving-average placement rule).

Gradients arrive pre-aggregated either way the exchange ran: per-tensor
(XLA-inserted collectives, global semantics) or bucketed (core/buckets.py
fuses the dense push into flat buffers and unflattens before handing them
here) — so the update, clipping, and the moments stay per-tensor and
placement-identical under both exchanges; nothing below may re-aggregate.

Fused bucket-apply: under the bucketed exchange the all-reduced gradient
already exists as one flat buffer per bucket, so unflattening it into
per-parameter leaves only to re-walk them leaf-by-leaf in ``update`` is a
pure memory-traffic tax. ``fuse_state``/``unfuse_state`` re-lay the m/v/EMA
state as one flat f32 buffer per bucket (params stay per-leaf — the model
needs them), and ``Optimizer.update_fused`` reads each post-psum buffer
directly against that layout: one elementwise chain per bucket instead of
one per parameter. Bit-identical to ``update`` at every dtype: the per-leaf
reference is elementwise, and every fused op applies the same cast chain to
the same linear values (the global-norm partial sums accumulate in the same
leaf order). Param-wise weight-decay masks become per-bucket segment
vectors (``_wd_segment``). core/transform.py fuses on build and the
trainer unfuses back to the canonical per-param layout for checkpoints,
replans, and remeshes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    m: Any          # first moment / momentum (None for sgd)
    v: Any          # second moment (None for sgd/momentum)
    ema: Any        # EMA shadow params (None if disabled)
    # bounded-staleness buffers for sparse tables (None unless
    # RunConfig.max_staleness > 0): {table: {"g": f32 grad buffer,
    # "age": int32 scalar}}. The buffer exists for every eligible table
    # whenever the machinery is on — sync<->stale flips change only the
    # update rule in the train step, never the state pytree. Optimizer
    # update fns construct TrainState positionally and never touch this
    # field; the staleness wrapper in core/transform.py re-attaches it.
    stale: Any = None


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], TrainState]
    update: Callable[[TrainState, Any], tuple[TrainState, dict]]
    # bucket-native apply: (state, grads, flat post-psum bucket buffers,
    # BucketPlan) -> (state, metrics); None = per-param only (sgd)
    update_fused: Optional[Callable] = None


# ---------------------------------------------------------------------------
# fused bucket-apply state layout
# ---------------------------------------------------------------------------

def is_fused(state: Optional[TrainState]) -> bool:
    """Is this state's optimizer memory in the bucket-fused layout?"""
    return (state is not None and isinstance(state.m, dict)
            and set(state.m) == {"bucket", "leaf"})


def bucket_segments(bp) -> dict:
    """leaf index -> (bucket k, offset, size) over the bucketed leaves."""
    out = {}
    for k, b in enumerate(bp.buckets):
        off = 0
        for i, sz in zip(b.idx, b.sizes):
            out[i] = (k, off, sz)
            off += sz
    return out


def _flat_with_none(tree):
    """Flatten keeping ``None`` placeholders as positional leaves — the
    fused leaf-trees hold None at bucketed positions (no buffer at all, so
    nothing to shard or donate), and indices must stay aligned with the
    params flatten order."""
    return jax.tree_util.tree_flatten(tree, is_leaf=lambda x: x is None)


def fuse_state(state: Optional[TrainState], bp) -> Optional[TrainState]:
    """Per-param -> bucket-fused optimizer-state layout: m/v/EMA become one
    flat f32 buffer per bucket ({"bucket": [...], "leaf": tree}); bucketed
    positions in the leaf tree hold ``None`` placeholders so the structure
    still mirrors params positionally (flatten with ``_flat_with_none``).
    Exact — buffers are concatenations of the per-leaf f32 values in bucket
    member order."""
    if state is None or bp is None or is_fused(state):
        return state

    def fuse(tree):
        if tree is None:
            return None
        leaves, tdef = jax.tree_util.tree_flatten(tree)
        bufs = [jnp.concatenate([leaves[i].astype(jnp.float32).reshape(-1)
                                 for i in b.idx])
                for b in bp.buckets]
        for b in bp.buckets:
            for i in b.idx:
                leaves[i] = None
        return {"bucket": bufs,
                "leaf": jax.tree_util.tree_unflatten(tdef, leaves)}

    return state._replace(m=fuse(state.m), v=fuse(state.v),
                          ema=fuse(state.ema))


def unfuse_state(state: Optional[TrainState], bp) -> Optional[TrainState]:
    """Bucket-fused -> canonical per-param layout (checkpoint/replan form).
    Exact inverse of ``fuse_state`` for the same bucket plan."""
    if state is None or bp is None or not is_fused(state):
        return state
    pleaves = jax.tree_util.tree_leaves(state.params)

    def unfuse(tree):
        if tree is None or not (isinstance(tree, dict)
                                and set(tree) == {"bucket", "leaf"}):
            return tree
        leaves, tdef = _flat_with_none(tree["leaf"])
        for k, b in enumerate(bp.buckets):
            buf, off = tree["bucket"][k], 0
            for i, sz in zip(b.idx, b.sizes):
                leaves[i] = buf[off:off + sz].reshape(pleaves[i].shape)
                off += sz
        return jax.tree_util.tree_unflatten(tdef, leaves)

    return state._replace(m=unfuse(state.m), v=unfuse(state.v),
                          ema=unfuse(state.ema))


def _wd_segment(b, weight_decay: float, mask_leaves: Optional[list]):
    """Per-bucket weight-decay segment: the param-wise mask expanded over
    the bucket's member extents (scalar when the mask is uniform/absent)."""
    if not mask_leaves:
        return weight_decay
    return jnp.concatenate([
        jnp.full((sz,), float(weight_decay) * float(mask_leaves[i]),
                 jnp.float32) for i, sz in zip(b.idx, b.sizes)])


def global_norm(grads, rt=None) -> jax.Array:
    """Post-aggregation global norm; partial-sums + scalar reduction (OPAU)."""
    leaves = jax.tree.leaves(grads)
    if rt is not None and not rt.run_cfg.opau and rt.mesh is not None:
        # naive placement baseline: replicate the aggregated grads first
        from repro.compat import NamedSharding, P
        leaves = [jax.lax.with_sharding_constraint(
            g, NamedSharding(rt.mesh, P())) for g in leaves]
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm: float, rt=None):
    norm = global_norm(grads, rt)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def _ema_update(ema, params, decay):
    if ema is None:
        return None
    return jax.tree.map(
        lambda e, p: (e.astype(jnp.float32) * decay
                      + p.astype(jnp.float32) * (1 - decay)).astype(e.dtype),
        ema, params)


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = 1.0, ema_decay: float = 0.0,
          wd_mask=None, rt=None) -> Optimizer:
    """``wd_mask``: optional params-structured tree of per-parameter floats
    multiplying ``weight_decay`` (0.0 = no decay for that leaf); the fused
    path expands it into per-bucket segment vectors."""
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params) -> TrainState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        # EMA shadow is a *copy*: astype(f32) on f32 params would alias the
        # param buffer and break donation (same buffer donated twice)
        return TrainState(
            step=jnp.zeros((), jnp.int32),
            params=params,
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
            ema=jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                             params)
            if ema_decay > 0 else None,
        )

    def update(state: TrainState, grads) -> tuple[TrainState, dict]:
        metrics = {}
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm, rt)
            metrics["grad_norm"] = gnorm
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        def upd(p, g, m, v, wdm=1.0):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            upd32 = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                upd32 = upd32 + (weight_decay * float(wdm)) \
                    * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * upd32).astype(p.dtype), m, v

        if wd_mask is not None:
            out = jax.tree.map(upd, state.params, grads, state.m, state.v,
                               wd_mask)
        else:
            out = jax.tree.map(upd, state.params, grads, state.m, state.v)
        params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        ema = _ema_update(state.ema, params, ema_decay)
        return TrainState(step, params, m, v, ema), metrics

    def update_fused(state: TrainState, grads, bufs, bp):
        """Bucket-native adamw: each all-reduced flat buffer drives one
        elementwise chain against the fused m/v/EMA buffers; only the
        unbucketed leaves (sparse tables) walk the per-leaf path. The cast
        chain per bucket (wire f32 -> param dtype -> f32, clip, moments,
        param slice-back) replays the per-param reference op for op, so the
        two paths are bit-identical."""
        metrics = {}
        pleaves, ptree = jax.tree_util.tree_flatten(state.params)
        gleaves = list(jax.tree_util.tree_leaves(grads))
        seg = bucket_segments(bp)
        mask_leaves = (jax.tree_util.tree_leaves(wd_mask)
                       if wd_mask is not None else None)
        # mirror the per-param buf -> g.dtype -> f32 chain bitwise
        gbufs = [bufs[k].astype(pleaves[b.idx[0]].dtype).astype(jnp.float32)
                 for k, b in enumerate(bp.buckets)]
        if clip_norm is not None:
            sq = []
            for i in range(len(pleaves)):
                if i in seg:
                    # reshape to the leaf's shape before reducing: the
                    # per-param reference reduces each leaf in its natural
                    # shape (the exchange slice-back reshapes first), and a
                    # flat 1-D reduction associates differently at size
                    k, off, sz = seg[i]
                    sq.append(jnp.sum(jnp.square(
                        gbufs[k][off:off + sz].reshape(pleaves[i].shape))))
                else:
                    sq.append(jnp.sum(jnp.square(
                        gleaves[i].astype(jnp.float32))))
            gnorm = jnp.sqrt(sum(sq))
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
            gbufs = [(gb * scale).astype(pleaves[b.idx[0]].dtype)
                     .astype(jnp.float32)
                     for gb, b in zip(gbufs, bp.buckets)]
            gleaves = [g if i in seg else
                       (g.astype(jnp.float32) * scale).astype(g.dtype)
                       for i, g in enumerate(gleaves)]
            metrics["grad_norm"] = gnorm
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)
        mB, vB = list(state.m["bucket"]), list(state.v["bucket"])
        emaB = list(state.ema["bucket"]) if state.ema is not None else None
        new_p = list(pleaves)
        for k, b in enumerate(bp.buckets):
            g32 = gbufs[k]
            pdt = pleaves[b.idx[0]].dtype
            m = b1 * mB[k] + (1 - b1) * g32
            v = b2 * vB[k] + (1 - b2) * jnp.square(g32)
            mB[k], vB[k] = m, v
            # the final param stage walks flat slices of the moment chains —
            # params stay per-leaf (the model needs them), so a flat param
            # buffer would only add a concat the per-param path never pays,
            # and slicing m/v (kernel outputs either way) lets each leaf's
            # tail fuse into one kernel instead of materialising a
            # bucket-wide update intermediate
            wd_seg = (_wd_segment(b, weight_decay, mask_leaves)
                      if weight_decay else None)
            off, pnew32 = 0, []
            for i, sz in zip(b.idx, b.sizes):
                p32 = pleaves[i].astype(jnp.float32).reshape(-1)
                u = (m[off:off + sz] / bc1) \
                    / (jnp.sqrt(v[off:off + sz] / bc2) + eps)
                if wd_seg is not None:
                    w = wd_seg if jnp.ndim(wd_seg) == 0 \
                        else wd_seg[off:off + sz]
                    u = u + w * p32
                pn = p32 - lr_t * u
                new_p[i] = pn.reshape(pleaves[i].shape).astype(pdt)
                if emaB is not None:
                    pnew32.append(pn)
                off += sz
            if emaB is not None:
                pn = (jnp.concatenate(pnew32) if len(pnew32) > 1
                      else pnew32[0])
                emaB[k] = (emaB[k] * ema_decay
                           + pn.astype(pdt).astype(jnp.float32)
                           * (1 - ema_decay))
        mL, mdef = _flat_with_none(state.m["leaf"])
        vL = _flat_with_none(state.v["leaf"])[0]
        emaL = (_flat_with_none(state.ema["leaf"])[0]
                if state.ema is not None else None)
        for i in range(len(pleaves)):
            if i in seg:
                continue
            wdm = mask_leaves[i] if mask_leaves else 1.0
            p, g = pleaves[i], gleaves[i]
            g32 = g.astype(jnp.float32)
            mi = b1 * mL[i] + (1 - b1) * g32
            vi = b2 * vL[i] + (1 - b2) * jnp.square(g32)
            upd32 = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            if weight_decay:
                upd32 = upd32 + (weight_decay * float(wdm)) \
                    * p.astype(jnp.float32)
            new_p[i] = (p.astype(jnp.float32) - lr_t * upd32).astype(p.dtype)
            mL[i], vL[i] = mi, vi
            if emaL is not None:
                emaL[i] = (emaL[i].astype(jnp.float32) * ema_decay
                           + new_p[i].astype(jnp.float32) * (1 - ema_decay))
        params = jax.tree_util.tree_unflatten(ptree, new_p)
        m = {"bucket": mB, "leaf": jax.tree_util.tree_unflatten(mdef, mL)}
        v = {"bucket": vB, "leaf": jax.tree_util.tree_unflatten(mdef, vL)}
        ema = ({"bucket": emaB,
                "leaf": jax.tree_util.tree_unflatten(mdef, emaL)}
               if state.ema is not None else None)
        return TrainState(step, params, m, v, ema), metrics

    return Optimizer("adamw", init, update, update_fused)


def momentum(lr: float | Callable = 1e-2, mu: float = 0.9,
             clip_norm: Optional[float] = None, ema_decay: float = 0.0,
             rt=None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params) -> TrainState:
        return TrainState(
            step=jnp.zeros((), jnp.int32), params=params,
            m=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            v=None,
            ema=jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                             params)
            if ema_decay > 0 else None)

    def update(state, grads):
        metrics = {}
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm, rt)
            metrics["grad_norm"] = gnorm
        step = state.step + 1
        lr_t = lr_fn(step)
        m = jax.tree.map(lambda mm, g: mu * mm + g.astype(jnp.float32),
                         state.m, grads)
        params = jax.tree.map(
            lambda p, mm: (p.astype(jnp.float32) - lr_t * mm).astype(p.dtype),
            state.params, m)
        ema = _ema_update(state.ema, params, ema_decay)
        return TrainState(step, params, m, None, ema), metrics

    def update_fused(state: TrainState, grads, bufs, bp):
        metrics = {}
        pleaves, ptree = jax.tree_util.tree_flatten(state.params)
        gleaves = list(jax.tree_util.tree_leaves(grads))
        seg = bucket_segments(bp)
        gbufs = [bufs[k].astype(pleaves[b.idx[0]].dtype).astype(jnp.float32)
                 for k, b in enumerate(bp.buckets)]
        if clip_norm is not None:
            sq = []
            for i in range(len(pleaves)):
                if i in seg:
                    # leaf-shaped reduction — see adamw.update_fused
                    k, off, sz = seg[i]
                    sq.append(jnp.sum(jnp.square(
                        gbufs[k][off:off + sz].reshape(pleaves[i].shape))))
                else:
                    sq.append(jnp.sum(jnp.square(
                        gleaves[i].astype(jnp.float32))))
            gnorm = jnp.sqrt(sum(sq))
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
            gbufs = [(gb * scale).astype(pleaves[b.idx[0]].dtype)
                     .astype(jnp.float32)
                     for gb, b in zip(gbufs, bp.buckets)]
            gleaves = [g if i in seg else
                       (g.astype(jnp.float32) * scale).astype(g.dtype)
                       for i, g in enumerate(gleaves)]
            metrics["grad_norm"] = gnorm
        step = state.step + 1
        lr_t = lr_fn(step)
        mB = list(state.m["bucket"])
        emaB = list(state.ema["bucket"]) if state.ema is not None else None
        new_p = list(pleaves)
        for k, b in enumerate(bp.buckets):
            pdt = pleaves[b.idx[0]].dtype
            mB[k] = mu * mB[k] + gbufs[k]
            off, pnew32 = 0, []
            for i, sz in zip(b.idx, b.sizes):
                p32 = pleaves[i].astype(jnp.float32).reshape(-1)
                pn = p32 - lr_t * mB[k][off:off + sz]
                new_p[i] = pn.reshape(pleaves[i].shape).astype(pdt)
                if emaB is not None:
                    pnew32.append(pn)
                off += sz
            if emaB is not None:
                pn = (jnp.concatenate(pnew32) if len(pnew32) > 1
                      else pnew32[0])
                emaB[k] = (emaB[k] * ema_decay
                           + pn.astype(pdt).astype(jnp.float32)
                           * (1 - ema_decay))
        mL, mdef = _flat_with_none(state.m["leaf"])
        emaL = (_flat_with_none(state.ema["leaf"])[0]
                if state.ema is not None else None)
        for i in range(len(pleaves)):
            if i in seg:
                continue
            mL[i] = mu * mL[i] + gleaves[i].astype(jnp.float32)
            new_p[i] = (pleaves[i].astype(jnp.float32)
                        - lr_t * mL[i]).astype(pleaves[i].dtype)
            if emaL is not None:
                emaL[i] = (emaL[i].astype(jnp.float32) * ema_decay
                           + new_p[i].astype(jnp.float32) * (1 - ema_decay))
        params = jax.tree_util.tree_unflatten(ptree, new_p)
        m = {"bucket": mB, "leaf": jax.tree_util.tree_unflatten(mdef, mL)}
        ema = ({"bucket": emaB,
                "leaf": jax.tree_util.tree_unflatten(mdef, emaL)}
               if state.ema is not None else None)
        return TrainState(step, params, m, None, ema), metrics

    return Optimizer("momentum", init, update, update_fused)


def sgd(lr: float | Callable = 1e-2, clip_norm: Optional[float] = None,
        rt=None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params) -> TrainState:
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          m=None, v=None, ema=None)

    def update(state, grads):
        metrics = {}
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm, rt)
            metrics["grad_norm"] = gnorm
        step = state.step + 1
        lr_t = lr_fn(step)
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr_t * g.astype(jnp.float32)).astype(p.dtype),
            state.params, grads)
        return TrainState(step, params, None, None, None), metrics

    return Optimizer("sgd", init, update)


def make_optimizer(rt) -> Optimizer:
    rc = rt.run_cfg
    if rc.optimizer == "adamw":
        return adamw(rc.learning_rate, weight_decay=rc.weight_decay,
                     clip_norm=rc.clip_norm, ema_decay=rc.ema_decay, rt=rt)
    if rc.optimizer == "momentum":
        return momentum(rc.learning_rate, clip_norm=rc.clip_norm,
                        ema_decay=rc.ema_decay, rt=rt)
    if rc.optimizer == "sgd":
        return sgd(rc.learning_rate, clip_norm=rc.clip_norm, rt=rt)
    raise ValueError(f"unknown optimizer {rc.optimizer!r}")
