"""Optimizers with Parallax placement discipline.

Correctness rules from the paper (§3.1, §5.3.2) enforced structurally:
  * gradient clipping happens AFTER aggregation (grads from jax.grad in
    global semantics are post-aggregation by construction); the global norm
    is computed as per-shard partial ‖g‖² + scalar psum — only scalars cross
    shards (OPAU). The OPAU=off baseline force-replicates gradients first so
    the naive placement's extra all-gathers are visible in HLO.
  * AccumParams (Adam moments, momentum) live with their parameter shard
    (same sharding as the parameter, optionally further sharded by ZeRO-1).
  * EMA shadow parameters update when their parameter updates, on the same
    shard (the paper's moving-average placement rule).

Gradients arrive pre-aggregated either way the exchange ran: per-tensor
(XLA-inserted collectives, global semantics) or bucketed (core/buckets.py
fuses the dense push into flat buffers and unflattens before handing them
here) — so the update, clipping, and the moments stay per-tensor and
placement-identical under both exchanges; nothing below may re-aggregate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    m: Any          # first moment / momentum (None for sgd)
    v: Any          # second moment (None for sgd/momentum)
    ema: Any        # EMA shadow params (None if disabled)


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], TrainState]
    update: Callable[[TrainState, Any], tuple[TrainState, dict]]


def global_norm(grads, rt=None) -> jax.Array:
    """Post-aggregation global norm; partial-sums + scalar reduction (OPAU)."""
    leaves = jax.tree.leaves(grads)
    if rt is not None and not rt.run_cfg.opau and rt.mesh is not None:
        # naive placement baseline: replicate the aggregated grads first
        from jax.sharding import NamedSharding, PartitionSpec as P
        leaves = [jax.lax.with_sharding_constraint(
            g, NamedSharding(rt.mesh, P())) for g in leaves]
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm: float, rt=None):
    norm = global_norm(grads, rt)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def _ema_update(ema, params, decay):
    if ema is None:
        return None
    return jax.tree.map(
        lambda e, p: (e.astype(jnp.float32) * decay
                      + p.astype(jnp.float32) * (1 - decay)).astype(e.dtype),
        ema, params)


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = 1.0, ema_decay: float = 0.0,
          rt=None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params) -> TrainState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return TrainState(
            step=jnp.zeros((), jnp.int32),
            params=params,
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
            ema=jax.tree.map(lambda p: p.astype(jnp.float32), params)
            if ema_decay > 0 else None,
        )

    def update(state: TrainState, grads) -> tuple[TrainState, dict]:
        metrics = {}
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm, rt)
            metrics["grad_norm"] = gnorm
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            upd32 = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                upd32 = upd32 + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * upd32).astype(p.dtype), m, v

        out = jax.tree.map(upd, state.params, grads, state.m, state.v)
        params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        ema = _ema_update(state.ema, params, ema_decay)
        return TrainState(step, params, m, v, ema), metrics

    return Optimizer("adamw", init, update)


def momentum(lr: float | Callable = 1e-2, mu: float = 0.9,
             clip_norm: Optional[float] = None, ema_decay: float = 0.0,
             rt=None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params) -> TrainState:
        return TrainState(
            step=jnp.zeros((), jnp.int32), params=params,
            m=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            v=None,
            ema=jax.tree.map(lambda p: p.astype(jnp.float32), params)
            if ema_decay > 0 else None)

    def update(state, grads):
        metrics = {}
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm, rt)
            metrics["grad_norm"] = gnorm
        step = state.step + 1
        lr_t = lr_fn(step)
        m = jax.tree.map(lambda mm, g: mu * mm + g.astype(jnp.float32),
                         state.m, grads)
        params = jax.tree.map(
            lambda p, mm: (p.astype(jnp.float32) - lr_t * mm).astype(p.dtype),
            state.params, m)
        ema = _ema_update(state.ema, params, ema_decay)
        return TrainState(step, params, m, None, ema), metrics

    return Optimizer("momentum", init, update)


def sgd(lr: float | Callable = 1e-2, clip_norm: Optional[float] = None,
        rt=None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params) -> TrainState:
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          m=None, v=None, ema=None)

    def update(state, grads):
        metrics = {}
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm, rt)
            metrics["grad_norm"] = gnorm
        step = state.step + 1
        lr_t = lr_fn(step)
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr_t * g.astype(jnp.float32)).astype(p.dtype),
            state.params, grads)
        return TrainState(step, params, None, None, None), metrics

    return Optimizer("sgd", init, update)


def make_optimizer(rt) -> Optimizer:
    rc = rt.run_cfg
    if rc.optimizer == "adamw":
        return adamw(rc.learning_rate, weight_decay=rc.weight_decay,
                     clip_norm=rc.clip_norm, ema_decay=rc.ema_decay, rt=rt)
    if rc.optimizer == "momentum":
        return momentum(rc.learning_rate, clip_norm=rc.clip_norm,
                        ema_decay=rc.ema_decay, rt=rt)
    if rc.optimizer == "sgd":
        return sgd(rc.learning_rate, clip_norm=rc.clip_norm, rt=rt)
    raise ValueError(f"unknown optimizer {rc.optimizer!r}")
