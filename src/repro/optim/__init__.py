from repro.optim.optimizer import (
    Optimizer, adamw, sgd, momentum, global_norm, clip_by_global_norm,
    TrainState,
)
