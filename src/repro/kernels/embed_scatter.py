"""PS server-side push as a Pallas TPU kernel (backward of the sparse pull).

Scatters the deduped cotangent rows a replica produced into this shard's
slice of the gradient table. The local-space row ids ride in scalar-prefetch
memory (SMEM) and drive the *output* BlockSpec's index_map — grid step ``i``
DMAs cotangent row ``i`` straight onto table row ``ids[i]``; no (Vs, E)
one-hot matmul, no full-table scatter lowering.

Contract (matches ``_bwd_local``'s owner-local scatter):
  * ``ids`` are local-space (already offset by the shard's row base) and come
    from the dedupe buffer: sorted ascending and unique among owned rows, so
    every owned table row is written exactly once (a scatter-add over unique
    indices degenerates to a scatter-write — the adds across duplicate ids
    already happened in the segment-sum that built ``rows``).
  * unowned ids (other shards' rows, negative after offsetting, or the
    capacity sentinel) land in a dump row at index Vs that is sliced off.
  * the output aliases a zeros buffer so rows no id touches read as zero
    gradient; accumulation is in f32 regardless of the wire dtype of
    ``rows``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(ids_ref, rows_ref, zeros_ref, out_ref, *, vs: int):
    del ids_ref, zeros_ref, vs  # routing happens in the output index_map
    out_ref[0] = rows_ref[0].astype(out_ref.dtype)


def embed_scatter_add(ids: jax.Array, rows: jax.Array, vs: int,
                      *, block_e: int = 0, interpret: bool = False) -> jax.Array:
    """ids: (N,) local-space unique ids; rows: (N, E) -> (Vs, E) f32 grads.

    ``block_e`` tiles the feature dim exactly as in embed_gather: grid
    (N, E // block_e), each step routes one (1, block_e) slab onto its
    table row (dump-row routing for unowned ids is per-slab, so every slab
    of an unowned row lands in the dump row). 0 / non-divisor = full row.
    """
    n, e = rows.shape
    be = block_e if block_e and block_e < e and e % block_e == 0 else e

    def out_index(i, j, ids_ref):
        lid = ids_ref[i]
        owned = jnp.logical_and(lid >= 0, lid < vs)
        return (jnp.where(owned, lid, vs), j)

    kernel = functools.partial(_scatter_kernel, vs=vs)
    zeros = jnp.zeros((vs + 1, e), jnp.float32)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n, e // be),
            in_specs=[pl.BlockSpec((1, be), lambda i, j, ids_ref: (i, j)),
                      pl.BlockSpec((1, be), out_index)],
            out_specs=pl.BlockSpec((1, be), out_index),
        ),
        out_shape=jax.ShapeDtypeStruct((vs + 1, e), jnp.float32),
        # the zeros buffer IS the output storage: untouched rows stay zero
        input_output_aliases={2: 0},
        interpret=interpret,
    )(ids.astype(jnp.int32), rows, zeros)
    return out[:vs]
