"""RWKV6 WKV (data-dependent-decay linear attention) as a Pallas TPU kernel.

Grid: (B, H, chunks) with chunks innermost/sequential. The (E_k × E_v) state
lives in fp32 VMEM scratch across chunk iterations; each chunk is processed
in the factored GLA form — two (C×E)·(E×C)/(C×C)·(C×E) MXU matmuls plus the
state update outer product — so the sequential dependency only crosses
chunks, not tokens. This is the TPU-native adaptation of the recurrence
(DESIGN.md: rethink GPU token-recurrent scan as chunked MXU matmuls).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.pallas import CompilerParams

# fp32 holds e^87; clamping at 80 keeps the factored-form pieces finite.
# Exact when per-token |log-decay| * chunk <= 80 (RWKV6 trained decays are
# < 2.7/token, so chunk=32 is exact; tokens decayed below e^-80 are zero).
CLAMP = 80.0


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sT_ref,
                state_scr, *, chunks: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)              # (C, E)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                 # (E,)

    cum = jnp.cumsum(lw, axis=0)                     # (C, E) inclusive
    cin = cum - lw                                   # exclusive
    qf = r * jnp.exp(jnp.clip(cin, -CLAMP, 0.0))
    kf = k * jnp.exp(jnp.clip(-cum, 0.0, CLAMP))

    s_tt = jax.lax.dot_general(qf, kf, (((1,), (1,)), ((), ())))  # (C, C)
    c = lw.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    s_tt = jnp.where(ii > jj, s_tt, 0.0)
    out = jax.lax.dot_general(s_tt, v, (((1,), (0,)), ((), ())))  # (C, E)
    out = out + jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    out = out + jax.lax.dot_general(qf, state_scr[...],
                                    (((1,), (0,)), ((), ())))
    o_ref[0, 0] = out.astype(o_ref.dtype)

    tot = cum[-1:, :]                                # (1, E)
    kdec = k * jnp.exp(jnp.clip(tot - cum, -CLAMP, CLAMP))
    state_scr[...] = state_scr[...] * jnp.exp(
        jnp.clip(tot, -CLAMP, 0.0)).reshape(-1, 1) + \
        jax.lax.dot_general(kdec, v, (((0,), (0,)), ((), ())))

    @pl.when(ci == chunks - 1)
    def _flush():
        sT_ref[0, 0] = state_scr[...]


def wkv(r, k, v, lw, bonus, state, *, chunk: int = 32,
        interpret: bool = False):
    """r/k/v/lw: (B,S,H,E); bonus: (H,E); state: (B,H,E,E) fp32.
    Returns out (B,S,H,E), final state (B,H,E,E)."""
    b, s, h, e = r.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))  # pad decay=e^0
    sp = r.shape[1]
    chunks = sp // chunk
    # layout (B, H, S, E) for clean blocking
    tr = lambda a: a.transpose(0, 2, 1, 3)
    rt, kt, vt, lwt = tr(r), tr(k), tr(v), tr(lw)

    kernel = functools.partial(_wkv_kernel, chunks=chunks, chunk=chunk)
    blk = lambda: pl.BlockSpec((1, 1, chunk, e),
                               lambda bi, hi, ci: (bi, hi, ci, 0))
    out, s_t = pl.pallas_call(
        kernel,
        grid=(b, h, chunks),
        in_specs=[blk(), blk(), blk(), blk(),
                  pl.BlockSpec((1, e), lambda bi, hi, ci: (hi, 0)),
                  pl.BlockSpec((1, 1, e, e), lambda bi, hi, ci: (bi, hi, 0, 0))],
        out_specs=[blk(),
                   pl.BlockSpec((1, 1, e, e),
                                lambda bi, hi, ci: (bi, hi, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, h, sp, e), r.dtype),
                   jax.ShapeDtypeStruct((b, h, e, e), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((e, e), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rt, kt, vt, lwt, bonus, state)
    return out.transpose(0, 2, 1, 3)[:, :s], s_t
