"""Measured autotune of the embedding-kernel feature tiles (block_e).

The Pallas embed_gather / embed_scatter_add kernels take a ``block_e``
feature tile: 0 keeps the fixed full-row block, a lane-multiple divisor of E
pipelines each row through VMEM in slabs. Which wins is a scheduling
question the roofline model can rank but not decide — so this module runs a
small *measured* sweep per (kernel, table shape, buffer rows, dtype,
backend), guided by utils/roofline.py:

  * candidates come from ``roofline.kernel_tile_candidates`` (lane-aligned
    divisors of E that double-buffer within VMEM, plus 0 — the fixed block
    is always in the running, so tuned can never lose to untuned),
  * ``roofline.embed_tile_seconds`` ranks them and the sweep keeps only the
    few cheapest predictions (plus 0) to measure,
  * the measured argmin is cached on disk (JSON, atomic write) keyed by
    shape/dtype/backend, so a given config pays the sweep once per machine.

``ensure_for_plan`` stamps the winners into ``Plan.table_tiles`` (read by
``Runtime.embed_ctx``); with a cold cache and measurement disabled — or no
Pallas path at all (``embed_impl != "pallas"``) — tables fall back to the
fixed full-row block (0, 0). Tile choice never changes the math, only the
schedule, so the fallback is always safe.

Cache location: ``~/.cache/repro/kernel_autotune.json``, overridable via
``REPRO_AUTOTUNE_CACHE``. Delete the file (or change it per-machine) to
invalidate; entries self-invalidate on any key change (shape, dtype,
backend). ``REPRO_AUTOTUNE_NO_MEASURE=1`` forbids new measurements (cache
hits still apply — the CI/offline mode).
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.utils import roofline

DEFAULT_CACHE = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                             "kernel_autotune.json")
# tables can be huge (256k x 1k); per-step work is one row, independent of
# Vs, so the sweep measures against a row-capped proxy table
_VS_PROXY = 4096
_MEASURE_CANDS = 4          # 0 + the (this - 1) cheapest roofline predictions


def cache_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE", DEFAULT_CACHE)


def _load() -> dict:
    try:
        with open(cache_path()) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _save(cache: dict) -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def _key(kernel: str, vs: int, e: int, n: int, dtype) -> str:
    return (f"{kernel}:{vs}x{e}:n{n}:{jnp.dtype(dtype).name}"
            f":{jax.default_backend()}")


def measurement_allowed() -> bool:
    return os.environ.get("REPRO_AUTOTUNE_NO_MEASURE", "0") in ("0", "")


def _time_us(fn: Callable[[], jax.Array], repeats: int = 3) -> float:
    fn().block_until_ready()              # compile + warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn().block_until_ready()
        ts.append((time.perf_counter() - t0) * 1e6)
    return sorted(ts)[len(ts) // 2]


def _sweep_candidates(e: int, n: int, itemsize: int) -> list[int]:
    cands = roofline.kernel_tile_candidates(e, itemsize)
    if len(cands) <= _MEASURE_CANDS:
        return cands
    tiled = sorted(
        (be for be in cands if be),
        key=lambda be: roofline.embed_tile_seconds(n, e, be, itemsize))
    return [0] + tiled[:_MEASURE_CANDS - 1]


def tune(kernel: str, vs: int, e: int, n: int, dtype,
         cache: Optional[dict] = None) -> tuple[int, dict]:
    """Measured best block_e for one kernel/shape. Returns
    (best_block, {block: median_us}); (0, {}) when the sweep cannot run
    (degenerate shape, measurement forbidden on a cold cache, or no Pallas).
    Mutates/persists the disk cache unless ``cache`` is passed in (the
    caller then owns persistence).
    """
    own_cache = cache is None
    cache = _load() if own_cache else cache
    key = _key(kernel, vs, e, n, dtype)
    hit = cache.get(key)
    if hit is not None:
        return int(hit["best"]), {int(k): v for k, v in hit["us"].items()}
    cands = _sweep_candidates(e, n, jnp.dtype(dtype).itemsize)
    if len(cands) <= 1 or n <= 0 or not measurement_allowed():
        return 0, {}
    try:
        from repro.kernels import ops
        vs_m = min(vs, _VS_PROXY)
        ids = (jnp.arange(n, dtype=jnp.int32) * 7919) % vs_m
        us = {}
        if kernel == "gather":
            table = jnp.ones((vs_m, e), dtype)
            for be in cands:
                us[be] = _time_us(
                    lambda be=be: ops.embed_gather(table, ids, block_e=be))
        else:
            rows = jnp.ones((n, e), jnp.dtype(dtype))
            for be in cands:
                us[be] = _time_us(
                    lambda be=be: ops.embed_scatter_add(ids, rows, vs_m,
                                                        block_e=be))
    except Exception:                      # no Pallas / backend refusal
        return 0, {}
    best = min(us, key=us.get)
    cache[key] = {"best": int(best),
                  "us": {str(k): float(v) for k, v in us.items()}}
    if own_cache:
        _save(cache)
    return int(best), us


def ensure_for_plan(plan, rt, specs=None) -> dict:
    """Stamp measured (gather_block, scatter_block) tiles for every sparse
    table into ``plan.table_tiles``. ``specs`` is the model's ParamSpec tree
    (for table shapes); without it — or off the Pallas path — tables keep
    the fixed blocks. Returns the stamped dict."""
    if rt.run_cfg.embed_impl != "pallas" or specs is None:
        return {}
    from repro.models.layers import ParamSpec
    from repro.utils.tree import path_name
    shapes = {}
    jax.tree_util.tree_map_with_path(
        lambda path, s: shapes.__setitem__(path_name(path), s.shape)
        if s.sparse else None,
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    cache = _load()
    before = json.dumps(cache, sort_keys=True)
    for name, shape in shapes.items():
        if len(shape) != 2:
            continue
        vs, e = int(shape[0]), int(shape[1])
        n = int(plan.table_capacity.get(name, 0)) or \
            rt.embed_capacity_for(name)
        gb, _ = tune("gather", vs, e, n, rt.param_dtype, cache=cache)
        wire = plan.table_wire.get(name, rt.wire_dtype)
        sb, _ = tune("scatter", vs, e, n, wire, cache=cache)
        plan.table_tiles[name] = (int(gb), int(sb))
    if json.dumps(cache, sort_keys=True) != before:
        _save(cache)
    return dict(plan.table_tiles)


def cache_status() -> dict:
    """Autotune cache report for tools/check_env.py."""
    path = cache_path()
    cache = _load()
    return {
        "path": path,
        "exists": os.path.exists(path),
        "entries": len(cache),
        "state": "warm" if cache else "cold",
        "backend_entries": sum(
            1 for k in cache if k.endswith(f":{jax.default_backend()}")),
    }
