"""Pallas TPU kernels for the perf-critical hot-spots, each with a pure-jnp
oracle in ref.py and an interpret=True correctness sweep in tests/.

  flash_attention  blocked online-softmax attention (train/prefill)
  embed_gather     PS server-side sparse row pull (scalar-prefetch gather)
  embed_scatter    PS server-side sparse push (ids-in-SMEM scatter of
                   deduped cotangent rows into the table shard)
  wkv              RWKV6 chunked linear-attention recurrence
"""
from repro.kernels import ops  # noqa: F401
