"""Jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels run in interpret mode (correctness path);
on TPU (the target) they compile to Mosaic. ``REPRO_PALLAS_INTERPRET=0``
forces compiled mode.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import embed_gather as _eg
from repro.kernels import embed_scatter as _es
from repro.kernels import wkv as _wkv


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("row_offset", "block_e"))
def embed_gather(table_shard, ids, row_offset: int = 0, *, block_e: int = 0):
    return _eg.embed_gather(table_shard, ids, row_offset, block_e=block_e,
                            interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("vs", "block_e"))
def embed_scatter_add(ids, rows, vs: int, *, block_e: int = 0):
    return _es.embed_scatter_add(ids, rows, vs, block_e=block_e,
                                 interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv(r, k, v, lw, bonus, state, *, chunk: int = 32):
    return _wkv.wkv(r, k, v, lw, bonus, state, chunk=chunk,
                    interpret=_interpret())
