"""PS server-side pull as a Pallas TPU kernel (the sparse hot-spot).

Gathers the deduped rows a replica requested from this shard's slice of the
embedding table, zeroing rows owned by other shards. The row ids ride in
scalar-prefetch memory (SMEM) and drive the table BlockSpec's index_map —
the canonical TPU embedding-gather schedule: one (rows_per_step × E) DMA
from HBM per grid step, no host gather, no full-table traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(ids_ref, table_ref, out_ref, *, row_offset: int,
                   vs: int, n_ids: int):
    i = pl.program_id(0)
    gid = ids_ref[i]
    local = gid - row_offset
    owned = jnp.logical_and(local >= 0, local < vs)
    row = table_ref[0]                               # (E,) block picked by index_map
    out_ref[0] = jnp.where(owned, row, 0).astype(out_ref.dtype)


def embed_gather(table_shard: jax.Array, ids: jax.Array, row_offset: int,
                 *, block_e: int = 0, interpret: bool = False) -> jax.Array:
    """table_shard: (Vs, E); ids: (N,) global ids -> (N, E) owned rows.

    ``block_e`` tiles the feature dim: the grid becomes (N, E // block_e)
    and each step DMAs a (1, block_e) slab, so wide rows pipeline through
    VMEM instead of landing as one block. 0 (or a non-divisor) keeps the
    fixed full-row block. Lane-dim rules apply: block_e must be a multiple
    of 128 to tile cleanly (kernels/autotune.py only proposes such).
    """
    vs, e = table_shard.shape
    n = ids.shape[0]
    be = block_e if block_e and block_e < e and e % block_e == 0 else e

    def table_index(i, j, ids_ref):
        local = ids_ref[i] - row_offset
        return (jnp.clip(local, 0, vs - 1), j)

    kernel = functools.partial(_gather_kernel, row_offset=row_offset,
                               vs=vs, n_ids=n)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n, e // be),
            in_specs=[pl.BlockSpec((1, be), table_index)],
            out_specs=pl.BlockSpec((1, be), lambda i, j, ids_ref: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, e), table_shard.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table_shard)
