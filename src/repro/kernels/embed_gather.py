"""PS server-side pull as a Pallas TPU kernel (the sparse hot-spot).

Gathers the deduped rows a replica requested from this shard's slice of the
embedding table, zeroing rows owned by other shards. The row ids ride in
scalar-prefetch memory (SMEM) and drive the table BlockSpec's index_map —
the canonical TPU embedding-gather schedule: one (rows_per_step × E) DMA
from HBM per grid step, no host gather, no full-table traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(ids_ref, table_ref, out_ref, *, row_offset: int,
                   vs: int, n_ids: int):
    i = pl.program_id(0)
    gid = ids_ref[i]
    local = gid - row_offset
    owned = jnp.logical_and(local >= 0, local < vs)
    row = table_ref[0]                               # (E,) block picked by index_map
    out_ref[0] = jnp.where(owned, row, 0).astype(out_ref.dtype)


def embed_gather(table_shard: jax.Array, ids: jax.Array, row_offset: int,
                 *, interpret: bool = False) -> jax.Array:
    """table_shard: (Vs, E); ids: (N,) global ids -> (N, E) owned rows."""
    vs, e = table_shard.shape
    n = ids.shape[0]

    def table_index(i, ids_ref):
        local = ids_ref[i] - row_offset
        return (jnp.clip(local, 0, vs - 1), 0)

    kernel = functools.partial(_gather_kernel, row_offset=row_offset,
                               vs=vs, n_ids=n)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[pl.BlockSpec((1, e), table_index)],
            out_specs=pl.BlockSpec((1, e), lambda i, ids_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, e), table_shard.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table_shard)
