"""Flash attention as a Pallas TPU kernel.

Grid: (batch*heads, q_blocks, kv_blocks) with the KV dimension innermost and
``arbitrary`` (sequential) semantics — the fp32 (m, l, acc) accumulators live
in VMEM scratch and persist across KV iterations, exactly the TPU-native
online-softmax schedule. Block shapes are MXU-aligned (multiples of 128 on
the matmul dims; head_dim rides whole).

VMEM budget per step (bf16, bq=bk=128, d=128):
  q (128·d) + k,v (128·d) + scratch m,l (128) + acc (128·d) fp32 ≈ 0.2 MB —
far under the ~16 MB VMEM bound, leaving room for double buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.pallas import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, scale: float, bq: int, bk: int,
                  kv_blocks: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < seq_k
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + \
        jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _flush():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q/k/v: (B, S, H, D) with kv pre-expanded to H heads. -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(sk, 8))
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qt = q.transpose(0, 2, 1, 3).reshape(b * h, q.shape[1], d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], d)
    q_blocks = qt.shape[1] // bq
    kv_blocks = kt.shape[1] // bk

    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=d ** -0.5, bq=bq, bk=bk,
        kv_blocks=kv_blocks, seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # m
            pltpu.VMEM((bq,), jnp.float32),      # l
            pltpu.VMEM((bq, d), jnp.float32),    # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out.reshape(b, h, -1, d).transpose(0, 2, 1, 3)
    return out[:, :sq]
