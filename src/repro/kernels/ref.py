"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,S,H,D) (kv pre-expanded). fp32 internal."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def embed_gather_ref(table_shard, ids, row_offset: int) -> jax.Array:
    """Server-side pull: rows of global `ids` owned by this shard, zeros
    elsewhere. table_shard: (Vs, E); ids: (N,)."""
    vs = table_shard.shape[0]
    local = ids - row_offset
    owned = (local >= 0) & (local < vs)
    rows = jnp.take(table_shard, jnp.clip(local, 0, vs - 1), axis=0)
    return jnp.where(owned[:, None], rows, 0)


def embed_scatter_add_ref(ids, rows, vs: int) -> jax.Array:
    """Server-side push: scatter-add cotangent `rows` onto the owned slice
    of the gradient table. ids: (N,) local-space; rows: (N, E) -> (Vs, E)
    f32 (unowned ids — negative or >= Vs — are dropped)."""
    idx = jnp.where((ids >= 0) & (ids < vs), ids, vs)
    d = jnp.zeros((vs + 1, rows.shape[-1]), jnp.float32)
    return d.at[idx].add(rows.astype(jnp.float32))[:vs]


def wkv_ref(r, k, v, lw, bonus, state) -> tuple[jax.Array, jax.Array]:
    """RWKV6 WKV, sequential oracle.

    r/k/v/lw: (B,S,H,E); bonus: (H,E); state: (B,H,E,E) [key x value].
    out[t] = r_t·(state + u⊙k_t v_t^T); state = diag(exp(lw_t))state + k_t v_t^T
    """
    b, s, h, e = r.shape

    def step(st, t):
        rt, kt, vt, lwt = r[:, t], k[:, t], v[:, t], lw[:, t]
        rt, kt, vt = (x.astype(jnp.float32) for x in (rt, kt, vt))
        lwt = lwt.astype(jnp.float32)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out_t = jnp.einsum("bhk,bhkv->bhv", rt, st + bonus[None, :, :, None] * kv)
        st = st * jnp.exp(lwt)[..., None] + kv
        return st, out_t

    state, outs = jax.lax.scan(step, state.astype(jnp.float32),
                               jnp.arange(s))
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), state
