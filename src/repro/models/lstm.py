"""The paper's own models: LM (Jozefowicz BIGLSTM, 800k vocab) and NMT
(GNMT-style 4-layer LSTM enc-dec). These are the canonical *sparse* models —
the hybrid-communication technique's home turf (paper Table 1/4).

LSTM-with-projection cell, scanned over time. The huge embedding +
softmax tables go through the PS exchange exactly like the transformer
archs; the small LSTM weights take the dense AllReduce path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import embedding as emb
from repro.core.xent import sharded_xent
from repro.models.layers import ParamSpec, stack_tree


def lstm_cell_specs(d_in: int, hidden: int, proj: int) -> dict:
    return {
        "w_x": ParamSpec((d_in, 4 * hidden), (None, "lstm_hidden"), fan_in_axes=(0,)),
        "w_h": ParamSpec((proj, 4 * hidden), (None, "lstm_hidden"), fan_in_axes=(0,)),
        "bias": ParamSpec((4 * hidden,), ("lstm_hidden",), init="zeros"),
        "w_proj": ParamSpec((hidden, proj), ("lstm_hidden", None), fan_in_axes=(0,)),
    }


def model_specs(cfg, rt) -> dict:
    d, hidden = cfg.d_model, cfg.d_ff
    vp = rt.padded_vocab
    specs = {
        "embed": ParamSpec((vp, d), ("vocab", "embed"), init="embed", sparse=True),
        "layers": stack_tree(lstm_cell_specs(d, hidden, d), cfg.n_layers),
        "head": ParamSpec((vp, d), ("vocab", "embed"), scale=0.02),
    }
    if cfg.is_encdec:
        specs["enc_layers"] = stack_tree(
            lstm_cell_specs(d, hidden, d), cfg.enc_layers)
        specs["enc_embed"] = ParamSpec((vp, d), ("vocab", "embed"),
                                       init="embed", sparse=True)
        # simple dot cross-attention mixer (GNMT-lite)
        specs["attn_mix"] = ParamSpec((2 * d, d), (None, None), fan_in_axes=(0,))
    return specs


def _lstm_layer(p, xs, state, rt):
    """xs: (B,S,Din); state: (c (B,H), h (B,P)). Scans over time."""
    w_x, w_h, bias, w_proj = p["w_x"], p["w_h"], p["bias"], p["w_proj"]
    gx = xs @ w_x                                  # (B,S,4H) hoisted matmul
    gx = rt.constrain(gx, ("batch", None, "lstm_hidden"))

    def step(carry, g_t):
        c, h = carry
        gates = g_t + h @ w_h + bias
        i, f, g, o = jnp.split(gates.astype(jnp.float32), 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h_new = (jax.nn.sigmoid(o) * jnp.tanh(c)).astype(xs.dtype) @ w_proj
        return (c, h_new), h_new

    (c, h), ys = jax.lax.scan(step, state, gx.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2), (c, h)


def _init_state(cfg, batch, n_layers, dtype=jnp.bfloat16):
    # cell state c stays f32 (the accumulator); the projected h matches the
    # activation dtype so the scan carry round-trips under any compute dtype
    return (jnp.zeros((n_layers, batch, cfg.d_ff), jnp.float32),
            jnp.zeros((n_layers, batch, cfg.d_model), dtype))


def _run_stack(layers_p, x, states, rt):
    n = jax.tree.leaves(layers_p)[0].shape[0]
    cs, hs = states
    new_c, new_h = [], []
    for i in range(n):  # few layers; unrolled for per-layer residuals
        p_i = jax.tree.map(lambda a: a[i], layers_p)
        y, (c, h) = _lstm_layer(p_i, x, (cs[i], hs[i]), rt)
        x = x + y if y.shape == x.shape else y
        new_c.append(c)
        new_h.append(h)
    return x, (jnp.stack(new_c), jnp.stack(new_h))


def forward(params, batch, *, cfg, rt, state=None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x, metrics = emb.lookup(params["embed"], tokens, ctx=rt.embed_ctx(),
                            capacity=rt.embed_capacity_for("embed"))
    x = x.astype(rt.dtype)
    if state is None:
        state = _init_state(cfg, b, cfg.n_layers, rt.dtype)
    if cfg.is_encdec:
        # each table runs its *own* planned exchange (method/capacity/wire
        # dtype can differ) and reports its own census metrics
        src, m2 = emb.lookup(params["enc_embed"], batch["src_tokens"],
                             ctx=rt.embed_ctx("enc_embed"),
                             capacity=rt.embed_capacity_for("enc_embed"),
                             name="enc_embed")
        enc_out, _ = _run_stack(params["enc_layers"], src.astype(rt.dtype),
                                _init_state(cfg, b, cfg.enc_layers, rt.dtype),
                                rt)
        metrics.update(m2)
    x, new_state = _run_stack(params["layers"], x, state, rt)
    if cfg.is_encdec:
        # GNMT-lite dot attention over encoder states
        scores = jnp.einsum("bsd,btd->bst", x.astype(jnp.float32),
                            enc_out.astype(jnp.float32)) * (cfg.d_model ** -0.5)
        ctx_vec = jnp.einsum("bst,btd->bsd", jax.nn.softmax(scores, -1),
                             enc_out.astype(jnp.float32)).astype(x.dtype)
        x = jnp.concatenate([x, ctx_vec], axis=-1) @ params["attn_mix"]
    logits = jnp.einsum("bsd,vd->bsv", x, params["head"].astype(x.dtype))
    logits = rt.constrain(logits, ("batch", None, "vocab"))
    return logits, new_state, metrics


def loss_fn(params, batch, *, cfg, rt):
    logits, _, metrics = forward(params, batch, cfg=cfg, rt=rt)
    per_tok = sharded_xent(logits, batch["labels"], mesh=rt.mesh,
                           model_axis="model", batch_axes=rt.batch_axes,
                           vocab=cfg.vocab_size)
    loss = jnp.mean(per_tok)
    metrics["xent"] = loss
    return loss, metrics
