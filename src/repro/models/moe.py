"""Mixture-of-Experts with sort-based capacity dispatch (TPU-native).

Expert FFNs *are* sparse parameters in the Parallax sense — each token
touches k of E experts (α = k/E) with learned indices — so the same
Table-3-style reasoning that picks the embedding exchange picks the MoE
execution plan:

  ep  experts sharded over ``model`` (E/M per chip); tokens routed to owners
      via all_to_all — the PS push/pull pattern applied to activations.
      Used when E % M == 0 (llama4-maverick: 128 experts / 16 shards).
  tp  experts replicated over ``model`` with expert d_ff sharded; dispatch is
      device-local and expert outputs are psum'd. Used when E < M (grok-1: 8
      experts), where EP cannot divide.

Dispatch is sort-based (argsort by expert id + positional capacity), not
GShard one-hot-einsum — O(T·D + E·C·D) memory instead of O(T·E·C). Tokens
are processed in groups (scan) to bound the dispatch buffers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import P, shard_map
from repro.models.layers import ParamSpec


def moe_specs(cfg, exec_mode: str) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    if exec_mode == "ep":
        axes_in = ("experts", None, None)
        axes_out = ("experts", None, None)
    else:
        axes_in = (None, None, "mlp")
        axes_out = (None, "mlp", None)
    specs = {
        "router": ParamSpec((d, e), (None, None), scale=0.02),
        "w_gate": ParamSpec((e, d, f), axes_in, fan_in_axes=(1,)),
        "w_up": ParamSpec((e, d, f), axes_in, fan_in_axes=(1,)),
        "w_down": ParamSpec((e, f, d), axes_out, fan_in_axes=(1,)),
    }
    if cfg.shared_expert:
        specs["shared_gate"] = ParamSpec((d, f), (None, "mlp"), fan_in_axes=(0,))
        specs["shared_up"] = ParamSpec((d, f), (None, "mlp"), fan_in_axes=(0,))
        specs["shared_down"] = ParamSpec((f, d), ("mlp", None), fan_in_axes=(0,))
    return specs


def _dispatch_indices(eids, gates, n_experts, capacity):
    """Sort-based dispatch. eids/gates: (T, k).

    Returns (slot_dest (T,k) flat index into E*C+1 buffer [E*C = dropped],
             aux metrics).
    """
    t, k = eids.shape
    flat_e = eids.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position of each routed slot within its expert
    start = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos = jnp.arange(t * k) - start[sorted_e]
    keep = pos < capacity
    dest_sorted = jnp.where(keep, sorted_e * capacity + pos, n_experts * capacity)
    # scatter back to slot order
    dest = jnp.zeros((t * k,), jnp.int32).at[order].set(dest_sorted.astype(jnp.int32))
    dropped = jnp.sum(~keep).astype(jnp.int32)
    return dest.reshape(t, k), dropped


def _expert_ffn(xs, w_gate, w_up, w_down, compute_dtype):
    """xs: (E, C, D); w: (E, D, F) / (E, F, D)."""
    xs = xs.astype(compute_dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, w_gate.astype(compute_dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xs, w_up.astype(compute_dtype))
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(compute_dtype))


def _moe_group(flat, router_w, w_gate, w_up, w_down, *, e, k, cf,
               exec_mode, model_axis, m, compute_dtype):
    """One token group on one device. flat: (T, D)."""
    t, d = flat.shape
    cap = max(int(t * k * cf / e) + 1, 4)
    logits = (flat @ router_w.astype(flat.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, k)                      # (T,k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    dest, dropped = _dispatch_indices(eids, gates, e, cap)

    buf = jnp.zeros((e * cap + 1, d), flat.dtype)
    xs = buf.at[dest.reshape(-1)].add(
        jnp.repeat(flat, k, axis=0), mode="drop")[:-1]
    xs = xs.reshape(e, cap, d)

    if exec_mode == "ep" and m > 1:
        e_loc = e // m
        xs = xs.reshape(m, e_loc, cap, d)
        xs = jax.lax.all_to_all(xs, model_axis, split_axis=0, concat_axis=0)
        # (M, E_loc, C, D): peer-m's tokens for my experts
        xs = xs.transpose(1, 0, 2, 3).reshape(e_loc, m * cap, d)
        ys = _expert_ffn(xs, w_gate, w_up, w_down, compute_dtype)
        ys = ys.reshape(e_loc, m, cap, d).transpose(1, 0, 2, 3)
        ys = jax.lax.all_to_all(ys, model_axis, split_axis=0, concat_axis=0)
        ys = ys.reshape(e, cap, d)
    else:
        ys = _expert_ffn(xs, w_gate, w_up, w_down, compute_dtype)
        if exec_mode == "tp" and m > 1:
            ys = jax.lax.psum(ys, model_axis)

    ys_pad = jnp.concatenate(
        [ys.reshape(e * cap, d), jnp.zeros((1, d), ys.dtype)], axis=0)
    picked = ys_pad[dest.reshape(-1)].reshape(t, k, d)
    out = jnp.sum(picked * gates[..., None].astype(picked.dtype), axis=1)

    # GShard load-balance aux (top-1 fraction x mean prob)
    frac = jnp.mean(jax.nn.one_hot(eids[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))
    return out.astype(flat.dtype), aux, dropped


def moe_ffn(params: dict, x: jax.Array, *, cfg, rt, exec_mode: str,
            group_tokens: int = 8192):
    """x: (B, S, D) -> (B, S, D), metrics."""
    b, s, d = x.shape
    e, k, cf = cfg.n_experts, cfg.experts_per_token, cfg.moe_capacity_factor
    mesh = rt.mesh
    model_axis = "model" if (mesh and "model" in mesh.axis_names) else None
    m = mesh.shape[model_axis] if model_axis else 1
    batch_axes = rt.batch_axes or None
    if exec_mode == "ep" and (m <= 1 or e % m != 0):
        exec_mode = "tp"
    seq_shardable = model_axis is not None and m > 1 and s % m == 0 \
        and exec_mode == "ep"

    def local(x_loc, router_w, w_gate, w_up, w_down):
        bl, sl, _ = x_loc.shape
        flat = x_loc.reshape(bl * sl, d)
        t = flat.shape[0]
        g = max(min(group_tokens, t), 1)
        n_groups = (t + g - 1) // g
        if t % g != 0:
            flat = jnp.pad(flat, ((0, n_groups * g - t), (0, 0)))

        def run_group(fl):
            return _moe_group(
                fl, router_w, w_gate, w_up, w_down, e=e, k=k, cf=cf,
                exec_mode=exec_mode, model_axis=model_axis, m=m,
                compute_dtype=rt.dtype)

        if n_groups == 1:
            out, aux, dropped = run_group(flat)
        else:
            outs, auxs, drops = jax.lax.map(
                run_group, flat.reshape(n_groups, g, d))
            out = outs.reshape(n_groups * g, d)
            aux, dropped = jnp.mean(auxs), jnp.sum(drops)
        out = out[:t].reshape(bl, sl, d)
        if mesh is not None:
            token_axes = tuple(a for a in (batch_axes or ())) + \
                ((model_axis,) if seq_shardable else ())
            if token_axes:
                n = 1
                for a in token_axes:
                    n *= mesh.shape[a]
                aux = jax.lax.psum(aux, token_axes) / n
                dropped = jax.lax.psum(dropped, token_axes)
            if not seq_shardable and model_axis and m > 1 and exec_mode == "ep":
                # tokens replicated over model: aux already identical
                pass
        return out, aux, dropped

    if mesh is None:
        out, aux, dropped = local(x, params["router"], params["w_gate"],
                                  params["w_up"], params["w_down"])
    else:
        seq_spec = model_axis if seq_shardable else None
        if exec_mode == "ep":
            wspec = P(model_axis, None, None)
            wspec_down = P(model_axis, None, None)
        else:
            wspec = P(None, None, model_axis)
            wspec_down = P(None, model_axis, None)
        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(batch_axes, seq_spec, None), P(), wspec, wspec, wspec_down),
            out_specs=(P(batch_axes, seq_spec, None), P(), P()),
            check_vma=False,
        )
        out, aux, dropped = fn(x, params["router"], params["w_gate"],
                               params["w_up"], params["w_down"])

    metrics = {"moe_aux": aux, "moe_dropped": dropped}
    if cfg.shared_expert:
        from repro.core import sp
        if sp.sp_active(rt, x):
            g, u = sp.proj_in(rt, x, [params["shared_gate"],
                                      params["shared_up"]], [True, True])
            shared = sp.proj_out(rt, jax.nn.silu(g) * u,
                                 params["shared_down"])
        else:
            h = jax.nn.silu(x @ params["shared_gate"]) * (x @ params["shared_up"])
            h = rt.constrain(h, ("batch", None, "mlp"))
            shared = h @ params["shared_down"]
        out = out + shared.astype(out.dtype)
    return out, metrics


def pick_exec_mode(cfg, rt) -> str:
    if rt.run_cfg.moe_exec in ("ep", "tp"):
        return rt.run_cfg.moe_exec
    m = rt.rules.axis_size("experts")
    if m > 1 and cfg.n_experts % m == 0:
        return "ep"
    return "tp"
