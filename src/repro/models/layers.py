"""Parameter-spec system and basic layers.

Parameters are declared once as ``ParamSpec`` trees with *logical axes*
(vocab/embed/mlp/q_heads/...); the planner (core/plan.py) resolves logical
axes to mesh ``PartitionSpec``s with divisibility-checked fallbacks. The same
spec tree serves initialization (real arrays), the dry-run
(ShapeDtypeStructs), and the sparsity census (core/sparsity.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]          # logical axis names, len == ndim
    init: str = "normal"                     # normal | zeros | ones | embed
    scale: Optional[float] = None            # stddev override for normal
    dtype: Any = None                        # None -> run param dtype
    sparse: bool = False                     # True: rows accessed via int gather
    fan_in_axes: tuple[int, ...] = ()        # axes contributing to fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def dense_spec(d_in: int, d_out: int, axes: tuple, *, scale=None, init="normal"):
    return ParamSpec((d_in, d_out), axes, init=init, scale=scale, fan_in_axes=(0,))


def stacked(spec: ParamSpec, n: int, axis_name: str = "layers") -> ParamSpec:
    """Add a leading stacked-layers dim for scan-over-layers."""
    return ParamSpec(
        (n, *spec.shape), (axis_name, *spec.axes),
        init=spec.init, scale=spec.scale, dtype=spec.dtype, sparse=spec.sparse,
        fan_in_axes=tuple(a + 1 for a in spec.fan_in_axes),
    )


def stack_tree(tree: Any, n: int) -> Any:
    return jax.tree.map(
        lambda s: stacked(s, n), tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def init_param(key, spec: ParamSpec, default_dtype) -> jax.Array:
    dtype = spec.dtype or default_dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape, jnp.float32) * 0.02).astype(dtype)
    # normal with fan-in scaling
    if spec.scale is not None:
        std = spec.scale
    else:
        fan_in = 1
        for a in (spec.fan_in_axes or (0,)):
            fan_in *= spec.shape[a]
        std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def init_tree(key, specs: Any, default_dtype=jnp.bfloat16) -> Any:
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [init_param(k, s, default_dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(specs: Any, default_dtype=jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# functional layers
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def swiglu(x, w_gate, w_up, w_down, constrain=lambda x, a: x):
    """SwiGLU MLP; ``constrain`` pins the hidden activation sharding."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = constrain(h, ("batch", "seq", "mlp"))
    return h @ w_down


def relu_squared_mlp(x, w_in, w_out, constrain=lambda x, a: x):
    h = jnp.square(jax.nn.relu(x @ w_in))
    h = constrain(h, ("batch", "seq", "mlp"))
    return h @ w_out
