"""RWKV6 "Finch" — attention-free time-mix with data-dependent per-channel
decay, plus squared-ReLU channel-mix. [arXiv:2404.05892]

Training uses the chunked linear-attention form (GLA-style factorization):
with per-channel log-decay ``lw = -exp(w)`` and in-chunk cumulative sums
``cum``, the intra-chunk scores factor as

    s[t,i] = < r_t · exp(cum_{t-1}),  k_i · exp(-cum_i) >   (i < t)

so each chunk is two matmuls + a state carry — MXU-friendly, O(S·C) memory.
Decode carries O(1) state per layer: (H, K, V) wkv state + token-shift x.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, rms_norm

CLAMP = 80.0  # fp32-safe clamp; exact while chunk * |log-decay| <= 80


def rwkv_block_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    lora = 64
    return {
        "tm": {  # time mix
            "mu": ParamSpec((5, d), (None, None), init="zeros"),  # r,k,v,w,g shifts
            "w_r": ParamSpec((d, d), (None, "heads_hd"), fan_in_axes=(0,)),
            "w_k": ParamSpec((d, d), (None, "heads_hd"), fan_in_axes=(0,)),
            "w_v": ParamSpec((d, d), (None, "heads_hd"), fan_in_axes=(0,)),
            "w_g": ParamSpec((d, d), (None, "heads_hd"), fan_in_axes=(0,)),
            "w_o": ParamSpec((d, d), ("heads_hd", None), fan_in_axes=(0,)),
            "w0": ParamSpec((d,), (None,), init="zeros"),
            "w_lora_a": ParamSpec((d, lora), (None, None), scale=0.02),
            "w_lora_b": ParamSpec((lora, d), (None, None), init="zeros"),
            "bonus": ParamSpec((d,), (None,), init="zeros"),        # u
            "ln_w": ParamSpec((d,), (None,), init="ones"),          # group/out norm
        },
        "cm": {  # channel mix
            "mu": ParamSpec((2, d), (None, None), init="zeros"),
            "w_in": ParamSpec((d, f), (None, "mlp"), fan_in_axes=(0,)),
            "w_out": ParamSpec((f, d), ("mlp", None), fan_in_axes=(0,)),
            "w_recv": ParamSpec((d, d), (None, None), fan_in_axes=(0,)),
        },
        "ln1": ParamSpec((d,), (None,), init="ones"),
        "ln2": ParamSpec((d,), (None,), init="ones"),
    }


def _token_shift(x, x_prev):
    """x: (B,S,D); x_prev: (B,D) last token of previous segment."""
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def _chunk_wkv(r, k, v, lw, bonus, state, chunk):
    """Chunked WKV. r/k/v: (B,S,H,hd); lw: (B,S,H,hd) log-decay (<=0).

    state: (B,H,hd,hd) carried. Returns out (B,S,H,hd), new state.
    """
    b, s, h, e = r.shape
    pad = (-s) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, lw = z(r), z(k), z(v), z(lw)
    n = r.shape[1] // chunk
    resh = lambda a: a.reshape(b, n, chunk, h, e).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(lw)

    def body(st, inp):
        rj, kj, vj, lwj = [a.astype(jnp.float32) for a in inp]
        cum = jnp.cumsum(lwj, axis=1)                       # (B,C,H,hd) inclusive
        cin = cum - lwj                                      # exclusive (cum_{t-1})
        qf = rj * jnp.exp(jnp.clip(cin, -CLAMP, 0.0))
        kf = kj * jnp.exp(jnp.clip(-cum, 0.0, CLAMP))
        s_tt = jnp.einsum("bthe,bihe->bhti", qf, kf)         # intra scores
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        s_tt = jnp.where(mask[None, None], s_tt, 0.0)
        out = jnp.einsum("bhti,bihe->bthe", s_tt, vj)
        # diagonal bonus: u ⊙ k_t
        diag = jnp.einsum("bthe,bthe->bth", rj * bonus, kj)
        out = out + diag[..., None] * vj
        # inter-chunk: state contribution
        out = out + jnp.einsum("bthe,bhef->bthf", qf, st)
        # state update
        tot = cum[:, -1:, :, :]                              # (B,1,H,hd)
        kdec = kj * jnp.exp(jnp.clip(tot - cum, -CLAMP, CLAMP))
        st_new = st * jnp.exp(jnp.clip(tot, -CLAMP, 0.0)).squeeze(1)[..., None] \
            + jnp.einsum("bthe,bthf->bhef", kdec, vj)
        return st_new, out

    state, outs = jax.lax.scan(body, state.astype(jnp.float32),
                               (rc, kc, vc, lwc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n * chunk, h, e)[:, :s]
    return out, state


def time_mix(p, x, x_prev, state, *, cfg, rt, chunk=32):
    """x: (B,S,D). Returns (out, (x_last, new_state))."""
    b, s, d = x.shape
    h, e = cfg.n_heads, cfg.head_dim
    xs = _token_shift(x, x_prev)
    mu = p["mu"].astype(x.dtype)
    mix = lambda i: x + (xs - x) * mu[i]
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = (xr @ p["w_r"]).reshape(b, s, h, e)
    k = (xk @ p["w_k"]).reshape(b, s, h, e)
    v = (xv @ p["w_v"]).reshape(b, s, h, e)
    g = xg @ p["w_g"]
    # data-dependent decay (Finch): w = w0 + tanh(xw A) B
    wdelta = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32)) \
        @ p["w_lora_b"].astype(jnp.float32)
    w = p["w0"].astype(jnp.float32) + wdelta
    lw = -jnp.exp(w).reshape(b, s, h, e)                     # log-decay <= 0
    bonus = jnp.exp(p["bonus"].astype(jnp.float32)).reshape(h, e)
    r = rt.constrain(r, ("batch", None, "q_heads", None))
    out, new_state = _chunk_wkv(r, k, v, lw, bonus, state, chunk)
    out = out.reshape(b, s, d).astype(x.dtype)
    # per-head group norm then gate
    out = rms_norm(out.reshape(b, s, h, e),
                   p["ln_w"].reshape(h, e), cfg.norm_eps).reshape(b, s, d)
    out = out * jax.nn.silu(g)
    return out @ p["w_o"], (x[:, -1, :], new_state)


def channel_mix(p, x, x_prev, *, rt):
    xs = _token_shift(x, x_prev)
    mu = p["mu"].astype(x.dtype)
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    hidden = jnp.square(jax.nn.relu(xk @ p["w_in"]))
    hidden = rt.constrain(hidden, ("batch", None, "mlp"))
    out = hidden @ p["w_out"]
    return out * jax.nn.sigmoid(xr @ p["w_recv"]), x[:, -1, :]


def rwkv_block(p, x, carry, *, cfg, rt, chunk=32):
    """One RWKV6 layer. carry = (tm_x, wkv_state, cm_x)."""
    tm_x, wkv_state, cm_x = carry
    h1 = rms_norm(x, p["ln1"], cfg.norm_eps)
    att, (tm_x, wkv_state) = time_mix(p["tm"], h1, tm_x, wkv_state,
                                      cfg=cfg, rt=rt, chunk=chunk)
    x = x + att
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    ffn, cm_x = channel_mix(p["cm"], h2, cm_x, rt=rt)
    x = x + ffn
    return x, (tm_x, wkv_state, cm_x)


def init_rwkv_carry(cfg, batch, dtype=jnp.float32):
    h, e, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return (jnp.zeros((batch, d), dtype),
            jnp.zeros((batch, h, e, e), jnp.float32),
            jnp.zeros((batch, d), dtype))
