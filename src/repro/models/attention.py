"""GQA attention with RoPE.

Implementations:
  naive    full (S,S) score materialization — small tests only.
  chunked  online-softmax over KV blocks via lax.scan — the flash-attention
           *algorithm* in pure XLA ops; memory O(S·C); dry-run default.
  pallas   kernels/flash_attention.py (TPU target, validated interpret=True).

Sharding (DESIGN.md): q heads padded to the model-axis size and sharded;
KV heads replicated (TP > n_kv); decode KV cache sequence-sharded over
``model`` with a psum'd online-softmax combine (flash-decoding).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D). positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def make_qmap(n_heads: int, n_kv: int, padded_heads: int):
    """q-head -> kv-head index map; padded q heads point at kv 0 (their
    weights are zero-initialized, so they contribute nothing). Returns None
    when the map is the identity (MHA, no padding)."""
    q_per_kv = max(n_heads // max(n_kv, 1), 1)
    idx = [min(i // q_per_kv, n_kv - 1) if i < n_heads else 0
           for i in range(padded_heads)]
    if idx == list(range(padded_heads)):
        return None
    return jnp.asarray(idx, jnp.int32)


def _expand_kv(k, qmap):
    """(B,S,KV,D) -> (B,S,H,D) via the q->kv map.

    Implemented as a one-hot einsum, not a gather: the contraction partitions
    cleanly under SPMD (replicated KV -> head-sharded expansion with zero
    communication) and its VJP is another einsum — a gather's scatter-add
    VJP forces involuntary resharding of (B,S,H,D) buffers per KV chunk.
    """
    if qmap is None:
        return k
    onehot = jax.nn.one_hot(qmap, k.shape[2], dtype=k.dtype)  # (H, KV)
    return jnp.einsum("bskd,hk->bshd", k, onehot)


def naive_attention(q, k, v, *, causal: bool = True,
                    q_offset: int = 0, qmap=None) -> jax.Array:
    """q: (B,Sq,H,D), k/v: (B,Sk,KV,D). Returns (B,Sq,H,D)."""
    kq = _expand_kv(k, qmap)
    vq = _expand_kv(v, qmap)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                        kq.astype(jnp.float32))
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        scores = jnp.where(qpos >= kpos, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool = True, chunk: int = 1024,
                      q_offset: int = 0, qmap=None) -> jax.Array:
    """Online-softmax attention, scanned over KV chunks (flash algorithm).

    Never materializes more than (B, Sq, H, chunk) of scores.
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    chunk = min(chunk, sk)
    if sk % chunk != 0:  # pad kv to a chunk multiple (masked out)
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    scale = d ** -0.5

    kc = k.reshape(b, n_chunks, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kv, d).transpose(1, 0, 2, 3, 4)

    qpos = q_offset + jnp.arange(sq)[:, None]
    qf = q.astype(jnp.float32) * scale

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        kj = _expand_kv(kj, qmap).astype(jnp.float32)
        vj = _expand_kv(vj, qmap).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj)
        kpos = j * chunk + jnp.arange(chunk)[None, :]
        mask = kpos <= (sk - 1)
        if causal:
            mask = mask & (qpos >= kpos)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vj)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, qmap=None) -> jax.Array:
    """One-step attention against a (possibly sequence-sharded) KV cache.

    q: (B,1,H,D); caches: (B,S,KV,D) sharded P(batch, kv_seq, None, None).
    ``cache_len`` is a scalar (homogeneous batch — the dry-run decode cells)
    or a per-slot (B,) vector (the serving engine's slot-paged decode: each
    slot masks exactly its own valid prefix, so a freed-and-reused slot never
    attends over a previous request's stale rows).
    Written in global semantics — GSPMD partitions the softmax reduction over
    the sharded cache axis (flash-decoding's psum combine).
    """
    kq = _expand_kv(k_cache, qmap).astype(jnp.float32)
    vq = _expand_kv(v_cache, qmap).astype(jnp.float32)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kq)
    kpos = jnp.arange(k_cache.shape[1])[None, None, None, :]
    cl = jnp.asarray(cache_len)
    if cl.ndim == 1:
        cl = cl[:, None, None, None]
    s = jnp.where(kpos < cl, s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq)
    return out.astype(q.dtype)


def attention(q, k, v, *, impl: str = "chunked", causal: bool = True,
              chunk: int = 1024, q_offset: int = 0, qmap=None) -> jax.Array:
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset,
                               qmap=qmap)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, chunk=chunk,
                                 q_offset=q_offset, qmap=qmap)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(_expand_kv(q, None) if qmap is None else q,
                                    _expand_kv(k, qmap), _expand_kv(v, qmap),
                                    causal=causal)
    raise ValueError(f"unknown attention impl {impl!r}")
