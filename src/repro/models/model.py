"""Unified model API — the object the transform/launcher/trainer consume.

``build_model(cfg, rt)`` returns a Model with:
  specs()           ParamSpec tree (init / abstract / sharding all derive from it)
  init(key)         real parameters
  loss_fn           (params, batch) -> (loss, metrics)        [train shapes]
  prefill_fn        (params, batch) -> (logits, cache, metrics)
  decode_fn         (params, cache, tokens, cache_len) -> (logits, cache)
  input_specs(...)  ShapeDtypeStruct stand-ins for every input (dry-run)
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.runtime import Runtime
from repro.models import encdec, lstm, transformer
from repro.models.layers import abstract_tree, init_tree


@dataclass
class Model:
    cfg: ModelConfig
    rt: Runtime
    specs: Callable[[], Any]
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    init_cache: Callable
    cache_pspecs: Callable
    # (params, tokens (B,S)) -> (logits, kv_cache) where kv_cache has the
    # decode-cache layout — the serving engine's batched prefill collects the
    # per-layer K/V for slot insertion. None for families whose recurrent
    # state cannot be prefix-prefilled exactly under padding (lstm/ssm) and
    # for enc-dec models (their prefill needs encoder inputs).
    prefill_cache_fn: Optional[Callable] = None

    def init(self, key) -> Any:
        return init_tree(key, self.specs(), self.rt.param_dtype)

    def abstract_params(self) -> Any:
        return abstract_tree(self.specs(), self.rt.param_dtype)

    # ------------------------------------------------------------------
    def input_specs(self, shape: Optional[ShapeConfig] = None) -> dict:
        """ShapeDtypeStruct stand-ins for the step inputs (no allocation)."""
        shape = shape or self.rt.shape_cfg
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, i32)
        if shape.kind in ("train", "prefill"):
            specs = {"tokens": tok(b, s)}
            if shape.kind == "train":
                specs["labels"] = tok(b, s)
            if cfg.is_encdec and cfg.family == "audio":
                se = s // encdec.enc_ratio(cfg)
                specs["frames"] = jax.ShapeDtypeStruct((b, se, cfg.d_model),
                                                       jnp.bfloat16)
            if cfg.is_encdec and cfg.family == "lstm":
                specs["src_tokens"] = tok(b, s)
            return specs
        # decode: one new token against a seq_len cache
        return {"tokens": tok(b, 1),
                "cache_len": jax.ShapeDtypeStruct((), i32)}

    def abstract_cache(self, shape: Optional[ShapeConfig] = None) -> Any:
        shape = shape or self.rt.shape_cfg
        cache = jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len))
        return cache


def build_model(cfg: ModelConfig, rt: Runtime) -> Model:
    if cfg.family == "lstm":
        return Model(
            cfg=cfg, rt=rt,
            specs=lambda: lstm.model_specs(cfg, rt),
            loss_fn=partial(lstm.loss_fn, cfg=cfg, rt=rt),
            prefill_fn=lambda p, b: lstm.forward(p, b, cfg=cfg, rt=rt),
            decode_fn=lambda p, state, tokens, cache_len: lstm.forward(
                p, {"tokens": tokens}, cfg=cfg, rt=rt, state=state)[:2],
            init_cache=lambda b, s: lstm._init_state(cfg, b, cfg.n_layers),
            cache_pspecs=lambda: None,
        )
    if cfg.is_encdec:
        def dec_fn(p, cache, tokens, cache_len):
            logits, new_cache, _ = encdec.forward(
                p, {"tokens": tokens}, cfg=cfg, rt=rt, cache=cache,
                cache_len=cache_len)
            return logits, new_cache
        return Model(
            cfg=cfg, rt=rt,
            specs=lambda: encdec.model_specs(cfg, rt),
            loss_fn=partial(encdec.loss_fn, cfg=cfg, rt=rt),
            prefill_fn=lambda p, b: encdec.forward(p, b, cfg=cfg, rt=rt),
            decode_fn=dec_fn,
            init_cache=lambda b, s: encdec.init_cache(
                cfg, rt, b, s, s // encdec.enc_ratio(cfg), rt.dtype),
            cache_pspecs=lambda: encdec.cache_pspec_tree(cfg, rt),
        )

    def dec_fn(p, cache, tokens, cache_len):
        logits, new_cache, _ = transformer.decode_step(
            p, cache, tokens, cache_len, cfg=cfg, rt=rt)
        return logits, new_cache

    def prefill_fn(p, b):
        return transformer.forward(p, b["tokens"], cfg=cfg, rt=rt,
                                   embeds=b.get("embeds"))

    def prefill_cache_fn(p, tokens):
        logits, kv, _ = transformer.forward(p, tokens, cfg=cfg, rt=rt,
                                            collect_kv=True)
        return logits, kv

    # exact bucketed prefill needs a purely positional cache: padded tail
    # tokens are masked out of attention by the per-slot length, but they
    # WOULD corrupt a recurrent carry (ssm) — so those families stay on the
    # decode loop. hybrid carries an ssm state alongside its KV: same story.
    paged = cfg.family in ("dense", "moe", "vlm")

    return Model(
        cfg=cfg, rt=rt,
        specs=lambda: transformer.model_specs(cfg, rt),
        loss_fn=partial(transformer.loss_fn, cfg=cfg, rt=rt),
        prefill_fn=prefill_fn,
        decode_fn=dec_fn,
        init_cache=lambda b, s: transformer.init_cache(cfg, rt, b, s, rt.dtype),
        cache_pspecs=lambda: transformer.cache_pspec_tree(
            cfg, rt, None, None),
        prefill_cache_fn=prefill_cache_fn if paged else None,
    )
