"""Decoder LM assembly for the dense / moe / vlm / ssm / hybrid families.

Layers run under lax.scan over a stacked parameter tree (one lowered layer →
small HLO, fast 512-device compiles) with configurable remat. The embedding
goes through the Parallax PS exchange (core/embedding.py); logits stay
vocab-sharded into the sharded cross-entropy (core/xent.py).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import embedding as emb
from repro.core import sp
from repro.core.xent import sharded_xent
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import ParamSpec, rms_norm, swiglu, stack_tree


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def attn_specs(cfg, rt) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    hp = rt.pad_heads(cfg.n_heads)
    kv = cfg.n_kv_heads
    return {
        "wq": ParamSpec((d, hp * hd), (None, "heads_hd"), fan_in_axes=(0,),
                        init="normal"),
        "wk": ParamSpec((d, kv * hd), (None, "kv_heads"), fan_in_axes=(0,)),
        "wv": ParamSpec((d, kv * hd), (None, "kv_heads"), fan_in_axes=(0,)),
        "wo": ParamSpec((hp * hd, d), ("heads_hd", None), fan_in_axes=(0,)),
    }


def mlp_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), (None, "mlp"), fan_in_axes=(0,)),
        "w_up": ParamSpec((d, f), (None, "mlp"), fan_in_axes=(0,)),
        "w_down": ParamSpec((f, d), ("mlp", None), fan_in_axes=(0,)),
    }


def layer_specs(cfg, rt, moe_exec: str) -> dict:
    d = cfg.d_model
    if cfg.family == "ssm":
        return rwkv_mod.rwkv_block_specs(cfg)
    specs: dict[str, Any] = {
        "ln1": ParamSpec((d,), (None,), init="ones"),
        "attn": attn_specs(cfg, rt),
        "ln2": ParamSpec((d,), (None,), init="ones"),
    }
    if cfg.family == "moe":
        specs["moe"] = moe_mod.moe_specs(cfg, moe_exec)
    else:
        specs["mlp"] = mlp_specs(cfg)
    if cfg.family == "hybrid":
        specs["ssm"] = ssm_mod.ssm_specs(cfg)
    return specs


def model_specs(cfg, rt) -> dict:
    d = cfg.d_model
    vp = rt.padded_vocab
    moe_exec = moe_mod.pick_exec_mode(cfg, rt) if cfg.n_experts else "tp"
    specs = {
        "embed": ParamSpec((vp, d), ("vocab", "embed"), init="embed",
                           sparse=True),
        "layers": stack_tree(layer_specs(cfg, rt, moe_exec), cfg.n_layers),
        "final_norm": ParamSpec((d,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((vp, d), ("vocab", "embed"), scale=0.02)
    return specs


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _zero_padded_qk(p_attn, cfg, rt):
    """Padded q-head columns must be zero for exactness; enforced at init
    (init_params masks them) — nothing to do at runtime."""
    return p_attn


def attn_block(p, x, *, cfg, rt, positions, layer_cache=None, cache_len=None,
               cross_kv=None, causal=True, return_kv=False):
    """Self (or cross) attention sub-block. Returns (out, new_cache).

    ``return_kv``: on the cache-less path, hand back this layer's (K, V)
    at the cache dtype — the serving engine's batched prefill collects them
    across layers and inserts the rows into the live decode cache at the
    request's slot (one dispatch per admission instead of prompt_len).
    """
    b, s, d = x.shape
    hd = cfg.head_dim
    hp = rt.pad_heads(cfg.n_heads)
    kv = cfg.n_kv_heads
    qmap = attn_mod.make_qmap(cfg.n_heads, kv, hp)

    use_sp = sp.sp_active(rt, x) and cross_kv is None and layer_cache is None
    if use_sp:
        # §Perf iteration A: one bf16 AG for the whole block half
        if sp.kv_local_favorable(rt, cfg):
            # replicated-KV weights: seq-local matmul + small output AG
            # beats the m-fold redundant full-seq matmul (§Perf iter A2)
            (qf,) = sp.proj_in(rt, x, [p["wq"]], [True])
            kf, vf = sp.local_proj(rt, x, [p["wk"], p["wv"]])
        else:
            qf, kf, vf = sp.proj_in(rt, x, [p["wq"], p["wk"], p["wv"]],
                                    [True, False, False])
        q = qf.reshape(b, s, hp, hd)
        k = kf.reshape(b, s, kv, hd)
        v = vf.reshape(b, s, kv, hd)
        if cfg.rope_theta:
            q = attn_mod.rope(q, positions, cfg.rope_theta)
            k = attn_mod.rope(k, positions, cfg.rope_theta)
    else:
        q = (x @ p["wq"]).reshape(b, s, hp, hd)
        q = rt.constrain(q, ("batch", None, "q_heads", None))
        if cross_kv is None:
            k = (x @ p["wk"]).reshape(b, s, kv, hd)
            v = (x @ p["wv"]).reshape(b, s, kv, hd)
            if cfg.rope_theta:
                q = attn_mod.rope(q, positions, cfg.rope_theta)
                k = attn_mod.rope(k, positions, cfg.rope_theta)
        else:
            k, v = cross_kv

    if layer_cache is not None:
        k_cache, v_cache = layer_cache
        if cross_kv is None:
            cl = jnp.asarray(cache_len)
            if cl.ndim == 1:
                # per-slot write: row b lands at its own length (the serving
                # engine's slot-paged decode). A one-hot select rather than a
                # scatter: it partitions cleanly on the sharded cache axis,
                # and an out-of-range slot (len >= S) simply writes nowhere.
                hit = jnp.arange(k_cache.shape[1])[None, :] == cl[:, None]
                k_cache = jnp.where(hit[:, :, None, None],
                                    k.astype(k_cache.dtype), k_cache)
                v_cache = jnp.where(hit[:, :, None, None],
                                    v.astype(v_cache.dtype), v_cache)
            else:
                # homogeneous batch: write the new K/V at cache_len
                # (sequence-sharded dim; GSPMD lowers the dynamic update on
                # the sharded axis)
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    k_cache, k.astype(k_cache.dtype), cache_len, axis=1)
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    v_cache, v.astype(v_cache.dtype), cache_len, axis=1)
        out = attn_mod.decode_attention(
            q, k_cache, v_cache,
            cache_len + (1 if cross_kv is None else 0), qmap=qmap)
        new_cache = (k_cache, v_cache)
    else:
        out = attn_mod.attention(
            q, k, v, impl=rt.run_cfg.attention_impl,
            causal=(causal and cross_kv is None),
            chunk=rt.run_cfg.attention_chunk, qmap=qmap)
        new_cache = (k.astype(rt.dtype), v.astype(rt.dtype)) \
            if (return_kv and cross_kv is None) else None
    if hp > cfg.n_heads:
        # zero padded heads BEFORE the o-proj: keeps the padded columns
        # gradient-isolated, so padding is exactly output- and
        # training-equivalent to the unpadded model (DESIGN.md §2).
        mask = (jnp.arange(hp) < cfg.n_heads).astype(out.dtype)
        out = out * mask[None, None, :, None]
    if use_sp:
        return sp.proj_out(rt, out.reshape(b, s, hp * hd), p["wo"]), new_cache
    out = rt.constrain(out, ("batch", None, "q_heads", None))
    out = out.reshape(b, s, hp * hd) @ p["wo"]
    return out, new_cache


def decoder_layer(p, x, *, cfg, rt, positions, layer_cache=None,
                  cache_len=None, moe_exec="tp", collect_kv=False):
    """Pre-norm decoder layer; returns (x, new_cache, metrics)."""
    metrics = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        # hymba: parallel attention + SSM heads on the same normed input
        kv_cache = layer_cache[:2] if layer_cache is not None else None
        h_ssm = layer_cache[2] if layer_cache is not None else \
            ssm_mod.init_ssm_state(cfg, x.shape[0])
        attn_out, new_kv = attn_block(
            p["attn"], h, cfg=cfg, rt=rt, positions=positions,
            layer_cache=kv_cache, cache_len=cache_len, return_kv=collect_kv)
        ssm_out, h_ssm = ssm_mod.ssm_mix(p["ssm"], h, h_ssm, cfg=cfg, rt=rt)
        attn_out = (attn_out + ssm_out) * 0.5
        new_cache = (*new_kv, h_ssm) if new_kv is not None else None
    else:
        attn_out, new_cache = attn_block(
            p["attn"], h, cfg=cfg, rt=rt, positions=positions,
            layer_cache=layer_cache, cache_len=cache_len,
            return_kv=collect_kv)
    x = x + attn_out
    x = rt.constrain(x, rt_residual_axes(rt, x))

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        ffn_out, m = moe_mod.moe_ffn(p["moe"], h2, cfg=cfg, rt=rt,
                                     exec_mode=moe_exec)
        metrics.update(m)
    elif sp.sp_active(rt, h2):
        g, u = sp.proj_in(rt, h2, [p["mlp"]["w_gate"], p["mlp"]["w_up"]],
                          [True, True])
        ffn_out = sp.proj_out(rt, jax.nn.silu(g) * u, p["mlp"]["w_down"])
    else:
        ffn_out = swiglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                         p["mlp"]["w_down"], constrain=rt.constrain)
    x = x + ffn_out
    x = rt.constrain(x, rt_residual_axes(rt, x))
    return x, new_cache, metrics


def rt_residual_axes(rt, x):
    """Sequence-parallel residuals when the seq dim divides the model axis."""
    s = x.shape[1]
    m = rt.rules.axis_size("seq_sp")
    if rt.shape_cfg.kind != "decode" and m > 1 and s % m == 0:
        return ("batch", "seq_sp", None)
    return ("batch", None, None)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _layer_carry_init(cfg, rt, batch, cache_seq, dtype):
    """Per-layer decode cache (stacked over layers by the caller)."""
    hd, kv = cfg.head_dim, cfg.n_kv_heads
    if cfg.family == "ssm":
        return rwkv_mod.init_rwkv_carry(cfg, batch, dtype)
    kvc = (jnp.zeros((batch, cache_seq, kv, hd), dtype),
           jnp.zeros((batch, cache_seq, kv, hd), dtype))
    if cfg.family == "hybrid":
        return (*kvc, ssm_mod.init_ssm_state(cfg, batch))
    return kvc


def init_cache(cfg, rt, batch, cache_seq, dtype=jnp.bfloat16):
    one = _layer_carry_init(cfg, rt, batch, cache_seq, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers, *a.shape)), one)


def cache_pspec_tree(cfg, rt, batch, cache_seq):
    """PartitionSpecs matching init_cache's structure (for in_shardings)."""
    from repro.compat import P
    if rt.mesh is None:
        return None
    batch_axes = rt.rules.rules.get("batch")
    kv_seq = rt.rules.rules.get("kv_seq")
    if cfg.family == "ssm":
        return (P(None, batch_axes, None),
                P(None, batch_axes, None, None, None),
                P(None, batch_axes, None))
    kvspec = P(None, batch_axes, kv_seq, None, None)
    if cfg.family == "hybrid":
        return (kvspec, kvspec, P(None, batch_axes, None, None))
    return (kvspec, kvspec)


def forward(params, tokens, *, cfg, rt, cache=None, cache_len=None,
            embeds=None, collect_kv=False):
    """tokens (B,S) -> vocab-sharded logits (B,S,Vp), new cache, metrics.

    ``embeds``: precomputed frontend embeddings (modality stubs) added after
    lookup — for the chameleon VQ stub tokens suffice; seamless uses encdec.py.

    ``cache_len`` may be a scalar (homogeneous batch) or a per-slot (B,)
    vector — the serving engine's slot-paged decode, where every sequence in
    the batch sits at its own position. ``collect_kv`` makes the cache-less
    (prefill) path return the per-layer K/V stack instead of None, for
    insertion into a live decode cache.
    """
    moe_exec = moe_mod.pick_exec_mode(cfg, rt) if cfg.n_experts else "tp"
    b, s = tokens.shape
    ctx = rt.embed_ctx()
    x, emetrics = emb.lookup(params["embed"], tokens, ctx=ctx,
                             capacity=rt.embed_capacity_for("embed"))
    x = x.astype(rt.dtype)
    if embeds is not None:
        x = x + embeds.astype(rt.dtype)
    x = rt.constrain(x, rt_residual_axes(rt, x))

    if cache_len is None and cache is None:
        positions = jnp.arange(s)
    else:
        base = jnp.asarray(cache_len if cache_len is not None else 0)
        if base.ndim == 1:
            positions = base[:, None] + jnp.arange(s)[None, :]   # (B, S)
        else:
            positions = base + jnp.arange(s)

    remat = rt.run_cfg.remat
    policy = None if remat == "full" else \
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable

    def layer_fn(x, inp):
        p, layer_cache = inp
        if cfg.family == "ssm":
            x, new_carry = rwkv_mod.rwkv_block(p, x, layer_cache, cfg=cfg, rt=rt)
            return x, (new_carry, {})
        x, new_cache, metrics = decoder_layer(
            p, x, cfg=cfg, rt=rt, positions=positions,
            layer_cache=layer_cache, cache_len=cache_len, moe_exec=moe_exec,
            collect_kv=collect_kv)
        return x, (new_cache, metrics)

    if cache is None and cfg.family == "ssm":
        # rwkv layers always carry (token-shift, wkv-state) — init fresh
        cache = init_cache(cfg, rt, b, 1, rt.dtype)

    if cache is not None:
        if remat in ("block", "full"):
            layer_fn = jax.checkpoint(layer_fn, policy=policy)
        xs = (params["layers"], cache)
        x, (new_cache, metrics) = jax.lax.scan(layer_fn, x, xs)
    elif collect_kv:
        # batched prefill: the scan stacks each layer's (K, V) into the
        # (n_layers, B, S, KV, hd) decode-cache layout
        def kv_fn(x, p):
            x, out = layer_fn(x, (p, None))
            return x, out
        if remat in ("block", "full"):
            kv_fn = jax.checkpoint(kv_fn, policy=policy)
        x, (new_cache, metrics) = jax.lax.scan(kv_fn, x, params["layers"])
    else:
        def no_cache_fn(x, p):
            x, (_, metrics) = layer_fn(x, (p, None))
            return x, metrics
        if remat in ("block", "full"):
            no_cache_fn = jax.checkpoint(no_cache_fn, policy=policy)
        x, metrics = jax.lax.scan(no_cache_fn, x, params["layers"])
        new_cache = None
    metrics = jax.tree.map(lambda a: jnp.sum(a, axis=0), metrics) if metrics else {}
    metrics.update(emetrics)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype))
    logits = rt.constrain(logits, ("batch", None, "vocab"))
    return logits, new_cache, metrics


def loss_fn(params, batch, *, cfg, rt):
    """batch: {tokens (B,S), labels (B,S)} -> scalar loss, metrics."""
    logits, _, metrics = forward(params, batch["tokens"], cfg=cfg, rt=rt,
                                 embeds=batch.get("embeds"))
    per_tok = sharded_xent(
        logits, batch["labels"], mesh=rt.mesh, model_axis="model",
        batch_axes=rt.batch_axes, vocab=cfg.vocab_size)
    loss = jnp.mean(per_tok)
    if "moe_aux" in metrics:
        loss = loss + 0.01 * metrics["moe_aux"] / cfg.n_layers
    metrics["xent"] = jnp.mean(per_tok)
    return loss, metrics


def decode_step(params, cache, tokens, cache_len, *, cfg, rt):
    """One serving step: tokens (B,1) + caches -> logits (B,1,Vp), cache'."""
    logits, new_cache, metrics = forward(
        params, tokens, cfg=cfg, rt=rt, cache=cache, cache_len=cache_len)
    return logits, new_cache, metrics
