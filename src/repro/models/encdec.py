"""Encoder-decoder backbone (seamless-m4t): transformer encoder over stub
frame embeddings + causal decoder with cross-attention.

Shapes: the cell's ``seq_len`` is split enc:dec as (seq_len//4, seq_len) —
audio frames are time-compressed ~4x by the (stubbed) conformer adaptor.
Decode caches: decoder self-attn KV + per-layer cross-attn KV precomputed
from the encoder output at prefill time.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import embedding as emb
from repro.core.xent import sharded_xent
from repro.models import attention as attn_mod
from repro.models.layers import ParamSpec, rms_norm, swiglu, stack_tree
from repro.models.transformer import (
    attn_specs, mlp_specs, attn_block, rt_residual_axes)


def enc_ratio(cfg) -> int:
    return 4 if cfg.frontend_stub else 1


def enc_layer_specs(cfg, rt) -> dict:
    d = cfg.d_model
    return {
        "ln1": ParamSpec((d,), (None,), init="ones"),
        "attn": attn_specs(cfg, rt),
        "ln2": ParamSpec((d,), (None,), init="ones"),
        "mlp": mlp_specs(cfg),
    }


def dec_layer_specs(cfg, rt) -> dict:
    d = cfg.d_model
    s = enc_layer_specs(cfg, rt)
    s["ln_cross"] = ParamSpec((d,), (None,), init="ones")
    s["cross"] = attn_specs(cfg, rt)
    return s


def model_specs(cfg, rt) -> dict:
    d = cfg.d_model
    vp = rt.padded_vocab
    return {
        "embed": ParamSpec((vp, d), ("vocab", "embed"), init="embed", sparse=True),
        "enc_layers": stack_tree(enc_layer_specs(cfg, rt), cfg.enc_layers),
        "enc_norm": ParamSpec((d,), (None,), init="ones"),
        "dec_layers": stack_tree(dec_layer_specs(cfg, rt), cfg.n_layers),
        "final_norm": ParamSpec((d,), (None,), init="ones"),
        "head": ParamSpec((vp, d), ("vocab", "embed"), scale=0.02),
    }


def encode(params, frames, *, cfg, rt):
    """frames: (B, S_enc, D) precomputed frontend embeddings (stub)."""
    x = frames.astype(rt.dtype)
    x = rt.constrain(x, rt_residual_axes(rt, x))
    positions = jnp.arange(x.shape[1])

    def layer(x, p):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        a, _ = attn_block(p["attn"], h, cfg=cfg, rt=rt, positions=positions,
                          causal=False)
        x = rt.constrain(x + a, rt_residual_axes(rt, x))
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        f = swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                   p["mlp"]["w_down"], constrain=rt.constrain)
        return rt.constrain(x + f, rt_residual_axes(rt, x)), None

    if rt.run_cfg.remat in ("block", "full"):
        layer = jax.checkpoint(layer)
    x, _ = jax.lax.scan(layer, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(p_cross, enc_out, cfg, rt):
    b, se, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p_cross["wk"]).reshape(b, se, kv, hd)
    v = (enc_out @ p_cross["wv"]).reshape(b, se, kv, hd)
    return k, v


def decode_stack(params, tokens, enc_out, *, cfg, rt, cache=None,
                 cache_len=None):
    """Decoder over text tokens with cross-attention to enc_out (or cached
    cross KV). Returns (logits, new_cache, metrics)."""
    b, s = tokens.shape
    ctx = rt.embed_ctx()
    x, emetrics = emb.lookup(params["embed"], tokens, ctx=ctx,
                             capacity=rt.embed_capacity_for("embed"))
    x = x.astype(rt.dtype)
    x = rt.constrain(x, rt_residual_axes(rt, x))
    base = jnp.asarray(cache_len if cache_len is not None else 0)
    # scalar cache_len: homogeneous batch; (B,) vector: per-slot positions
    # (the serving engine's slot-paged decode — attn_block masks per slot)
    positions = base[:, None] + jnp.arange(s)[None, :] if base.ndim == 1 \
        else base + jnp.arange(s)

    def layer(x, inp):
        p, layer_cache = inp
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if layer_cache is not None:
            self_kv = (layer_cache[0], layer_cache[1])
            cross_k, cross_v = layer_cache[2], layer_cache[3]
            a, new_self = attn_block(p["attn"], h, cfg=cfg, rt=rt,
                                     positions=positions,
                                     layer_cache=self_kv, cache_len=cache_len)
        else:
            cross_k, cross_v = _cross_kv(p["cross"], enc_out, cfg, rt)
            a, new_self = attn_block(p["attn"], h, cfg=cfg, rt=rt,
                                     positions=positions)
        x = rt.constrain(x + a, rt_residual_axes(rt, x))
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        c, _ = attn_block(p["cross"], h, cfg=cfg, rt=rt, positions=positions,
                          cross_kv=(cross_k, cross_v), causal=False)
        x = rt.constrain(x + c, rt_residual_axes(rt, x))
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        f = swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                   p["mlp"]["w_down"], constrain=rt.constrain)
        x = rt.constrain(x + f, rt_residual_axes(rt, x))
        new_cache = (*new_self, cross_k, cross_v) if new_self is not None else None
        return x, new_cache

    if rt.run_cfg.remat in ("block", "full") and cache is None:
        layer = jax.checkpoint(layer)

    if cache is not None:
        x, new_cache = jax.lax.scan(layer, x, (params["dec_layers"], cache))
    else:
        x, _ = jax.lax.scan(lambda x, p: layer(x, (p, None)), x,
                            params["dec_layers"])
        new_cache = None

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["head"].astype(x.dtype))
    logits = rt.constrain(logits, ("batch", None, "vocab"))
    return logits, new_cache, emetrics


def forward(params, batch, *, cfg, rt, cache=None, cache_len=None):
    """Training/prefill forward. batch: {frames, tokens}."""
    if cache is not None:
        return decode_stack(params, batch["tokens"], None, cfg=cfg, rt=rt,
                            cache=cache, cache_len=cache_len)
    enc_out = encode(params, batch["frames"], cfg=cfg, rt=rt)
    if rt.sparse_push_overlapped("embed"):
        # overlap schedule: gate the decoder table with the encoder output
        # so the table's in-backward row push is issued before the encoder
        # backward runs (emb.overlap_gate pins d_enc_out on the pushed grad)
        table, enc_out = emb.overlap_gate(params["embed"], enc_out)
        params = {**params, "embed": table}
    return decode_stack(params, batch["tokens"], enc_out, cfg=cfg, rt=rt)


def init_cache(cfg, rt, batch, cache_seq, enc_seq, dtype=jnp.bfloat16):
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    one = (jnp.zeros((batch, cache_seq, kv, hd), dtype),
           jnp.zeros((batch, cache_seq, kv, hd), dtype),
           jnp.zeros((batch, enc_seq, kv, hd), dtype),
           jnp.zeros((batch, enc_seq, kv, hd), dtype))
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers, *a.shape)), one)


def cache_pspec_tree(cfg, rt):
    from repro.compat import P
    if rt.mesh is None:
        return None
    batch_axes = rt.rules.rules.get("batch")
    kv_seq = rt.rules.rules.get("kv_seq")
    kvspec = P(None, batch_axes, kv_seq, None, None)
    return (kvspec, kvspec, kvspec, kvspec)


def loss_fn(params, batch, *, cfg, rt):
    logits, _, metrics = forward(params, batch, cfg=cfg, rt=rt)
    per_tok = sharded_xent(
        logits, batch["labels"], mesh=rt.mesh, model_axis="model",
        batch_axes=rt.batch_axes, vocab=cfg.vocab_size)
    loss = jnp.mean(per_tok)
    metrics["xent"] = loss
    return loss, metrics
