"""Selective SSM (Mamba-style, S4D-real) for hymba's parallel SSM heads.

Recurrence  h[t,d,n] = a[t,d]·h[t-1,d,n] + (dt[t,d]·x[t,d])·B[t,n]
            y[t,d]   = Σ_n C[t,n]·h[t,d,n]
with data-dependent a[t,d] = exp(dt[t,d]·A_d), A_d = -exp(A_log_d).

Chunked parallel form (same GLA factorization as rwkv.py):
    y[t,d] = exp(cum[t,d]) · Σ_{i<=t} (C_t·B_i) · (dt·x·exp(-cum))[i,d]
i.e. one (C×C) score matmul + one (C×D) einsum per chunk + state carry.
Decode carries h (B, D, N) — O(1) per step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec

CLAMP = 80.0  # fp32-safe clamp; exact while chunk * |log-decay| <= 80


def ssm_specs(cfg) -> dict:
    d, n = cfg.d_model, cfg.ssm_state
    return {
        "w_in": ParamSpec((d, d), (None, "heads_hd"), fan_in_axes=(0,)),
        "w_gate": ParamSpec((d, d), (None, "heads_hd"), fan_in_axes=(0,)),
        "w_b": ParamSpec((d, n), (None, None), scale=0.02),
        "w_c": ParamSpec((d, n), (None, None), scale=0.02),
        "w_dt": ParamSpec((d, d), (None, "heads_hd"), scale=0.02),
        "dt_bias": ParamSpec((d,), (None,), init="zeros"),
        "a_log": ParamSpec((d,), (None,), init="zeros"),
        "w_out": ParamSpec((d, d), ("heads_hd", None), fan_in_axes=(0,)),
    }


def _chunk_ssm(u, dt, b_t, c_t, a_d, h0, chunk):
    """u/dt: (B,S,D); b_t/c_t: (B,S,N); a_d: (D,) negative. h0: (B,D,N)."""
    bsz, s, d = u.shape
    n = b_t.shape[-1]
    pad = (-s) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_t = jnp.pad(b_t, ((0, 0), (0, pad), (0, 0)))
        c_t = jnp.pad(c_t, ((0, 0), (0, pad), (0, 0)))
    nc = u.shape[1] // chunk
    r5 = lambda a: a.reshape(bsz, nc, chunk, a.shape[-1]).transpose(1, 0, 2, 3)
    uc, dtc, bc, cc = r5(u), r5(dt), r5(b_t), r5(c_t)

    def body(h, inp):
        uj, dtj, bj, cj = [a.astype(jnp.float32) for a in inp]
        la = dtj * a_d[None, None, :]                    # (B,C,D) log decay <= 0
        cum = jnp.cumsum(la, axis=1)                     # inclusive
        decay_out = jnp.exp(jnp.clip(cum, -CLAMP, 0.0))
        scores = jnp.einsum("btn,bin->bti", cj, bj)      # (B,C,C)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        scores = jnp.where(mask[None], scores, 0.0)
        src = dtj * uj * jnp.exp(jnp.clip(-cum, -CLAMP, CLAMP))
        y = decay_out * jnp.einsum("bti,bid->btd", scores, src)
        # inter-chunk: y += exp(cum_t) * (C_t · h[d,:])
        y = y + decay_out * jnp.einsum("btn,bdn->btd", cj, h)
        # state update: h' = exp(tot)·h + Σ_i exp(tot-cum_i)·(dt·u)_i ⊗ B_i
        tot = cum[:, -1, :]                              # (B,D)
        kdec = dtj * uj * jnp.exp(jnp.clip(tot[:, None, :] - cum, -CLAMP, CLAMP))
        h_new = h * jnp.exp(jnp.clip(tot, -CLAMP, 0.0))[..., None] \
            + jnp.einsum("btd,btn->bdn", kdec, bj)
        return h_new, y

    h, ys = jax.lax.scan(body, h0.astype(jnp.float32), (uc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, nc * chunk, d)[:, :s]
    return y, h


def ssm_mix(p, x, h0, *, cfg, rt, chunk=128):
    """x: (B,S,D) -> (y, h). Selective-SSM branch."""
    u = x @ p["w_in"]
    u = rt.constrain(u, ("batch", None, "heads_hd"))
    gate = jax.nn.silu(x @ p["w_gate"])
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    b_t = (x @ p["w_b"]).astype(jnp.float32)
    c_t = (x @ p["w_c"]).astype(jnp.float32)
    a_d = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, h = _chunk_ssm(u.astype(jnp.float32), dt, b_t, c_t, a_d, h0, chunk)
    y = (y.astype(x.dtype) * gate) @ p["w_out"]
    return y, h


def init_ssm_state(cfg, batch):
    return jnp.zeros((batch, cfg.d_model, cfg.ssm_state), jnp.float32)
