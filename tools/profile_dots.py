"""Dump the largest dot-FLOP contributors for one dry-run cell."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys, argparse, collections
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs import RunConfig
from repro.launch.dryrun import lower_cell
from repro.utils.hlo import parse_module, _multipliers, _dot_flops

ap = argparse.ArgumentParser()
ap.add_argument("--arch", required=True)
ap.add_argument("--shape", required=True)
ap.add_argument("--explicit-sp", action="store_true")
ap.add_argument("--top", type=int, default=15)
args = ap.parse_args()

compiled, rt, plan, model = lower_cell(
    args.arch, args.shape, multi_pod=False,
    run_cfg=RunConfig(capacity_mode="capped", remat="full",
                      explicit_sp=args.explicit_sp))
comps, entry, sym = parse_module(compiled.as_text())
mult, _ = _multipliers(comps, entry)
rows = []
for cname, comp in comps.items():
    m = mult.get(cname, 0.0)
    if not m: continue
    for op in comp.ops:
        if op.kind in ("dot", "dot-general"):
            fl = _dot_flops(op, sym) * m
            mm = re.search(r'op_name="([^"]+)"', op.line)
            src = re.sub(r'jit\(\w+\)/', '', mm.group(1))[:110] if mm else "?"
            rows.append((fl, m, src))
rows.sort(reverse=True)
total = sum(r[0] for r in rows)
print(f"total dot flops/chip: {total:.3e}")
for fl, m, src in rows[:args.top]:
    print(f"{fl:.2e} x{int(m):4d}  {src}")
