#!/usr/bin/env python
"""One-line environment drift diagnosis.

    PYTHONPATH=src python tools/check_env.py [--json]

Prints the JAX version, device count, repro.compat capability probes, and
optional-dependency presence, then a PASS/WARN verdict — so a broken
environment shows up as one readable line instead of 16 cryptic test
failures. tests/test_compat.py::test_check_env_smoke runs this on every
suite invocation.
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

OPTIONAL_DEPS = ("hypothesis",)


def collect() -> dict:
    import jax
    from repro import compat

    report = {
        "python": sys.version.split()[0],
        "jax": compat.capabilities(),
        "jaxlib": getattr(__import__("jaxlib"), "__version__", "?"),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "local_device_count": jax.local_device_count(),
        "process_count": jax.process_count(),
        "devices": [str(d) for d in jax.devices()[:8]],
        "remesh": _remesh_eligibility(),
        "attribution": _attribution_eligibility(),
        "topology": _host_topology(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "optional_deps": {
            name: importlib.util.find_spec(name) is not None
            for name in OPTIONAL_DEPS
        },
        "embed_impl_pallas": _probe_pallas(),
        "kernel_autotune": _autotune_status(),
        "fused_apply": _fused_apply_eligibility(),
        "serving": _serve_eligibility(),
        "analysis": _analysis_status(),
    }
    report["ok"] = bool(report["jax"]["supported"])
    return report


def _analysis_status() -> dict:
    """Static-analysis availability and the repo's current lint status
    (analysis/lint.py): the CI `lint` job fails on any finding, so a
    non-zero count here predicts that failure locally."""
    try:
        from repro.analysis.lint import lint_repo
        findings = lint_repo()
        return {"available": True, "lint_findings": len(findings),
                "clean": not findings,
                "kinds": sorted({f.kind for f in findings})}
    except Exception as e:
        return {"available": False, "clean": False,
                "error": f"{type(e).__name__}: {e}"}


def _autotune_status() -> dict:
    """Embedding-kernel autotune cache state (kernels/autotune.py): cold
    means the first --kernel-autotune run on this machine pays the measured
    sweep (or keeps fixed tiles if REPRO_AUTOTUNE_NO_MEASURE=1)."""
    from repro.kernels import autotune
    st = autotune.cache_status()
    st["measurement_allowed"] = autotune.measurement_allowed()
    return st


def _fused_apply_eligibility() -> dict:
    """Would the default config get the fused bucket-apply here? Mirrors
    core/buckets.fused_apply_eligible: needs a bucketed dense exchange
    (bucket_bytes > 0, a data axis), an optimizer with a bucket-native
    update (adamw | momentum), zero_stage 0, and opau."""
    from repro.configs.base import RunConfig
    cfg = RunConfig()
    reasons = []
    if not cfg.fused_apply:
        reasons.append("fused_apply disabled")
    if cfg.optimizer not in ("adamw", "momentum"):
        reasons.append(f"optimizer {cfg.optimizer} has no fused update")
    if cfg.zero_stage != 0:
        reasons.append(f"zero_stage {cfg.zero_stage} shards the moments")
    if not cfg.opau:
        reasons.append("opau off (no aggregated update)")
    if not cfg.bucket_bytes:
        reasons.append("bucket_bytes 0 (per-tensor exchange)")
    return {"eligible": not reasons, "blockers": reasons,
            "optimizer": cfg.optimizer,
            "requires": "bucketed dense exchange on a data-parallel mesh"}


def _serve_eligibility() -> dict:
    """What the rebuilt serving engine (runtime/server.py) gets on this
    host: which families can take the batched-prefill path (a positional
    KV cache — recurrent carries fall back to ToyServer), how many
    prefill executables a default-sized engine would trace (one per
    power-of-two length bucket), and whether sampling runs on device."""
    from repro.runtime.server import MIN_BUCKET, ServerConfig, \
        prefill_buckets
    scfg = ServerConfig()
    buckets = prefill_buckets(scfg.max_seq, MIN_BUCKET)
    return {
        "paged_families": ["dense", "moe", "vlm"],
        "toy_fallback_families": ["lstm", "ssm", "hybrid", "encdec"],
        "max_seq": scfg.max_seq,
        "prefill_buckets": buckets,
        "prefill_executables": len(buckets),
        "sampling": ("device argmax" if scfg.greedy
                     else f"device categorical @T={scfg.temperature}"),
        "detokenize_thread": True,   # engine always runs host work off-path
    }


def _remesh_eligibility() -> dict:
    """Can the elastic auto-remesh path (Trainer.remesh_on_straggle /
    launch/mesh.shrink_mesh) actually shrink a data axis here? It needs at
    least 2 devices on that axis — a 1-device host can exercise the
    escalation policy but never the shrink itself."""
    import jax
    n = jax.device_count()
    return {
        "devices": n,
        "hosts": jax.process_count(),
        "max_data_parallel": n,               # all-data mesh upper bound
        "can_shrink_data_axis": n >= 2,
    }


def _attribution_eligibility() -> dict:
    """Can straggler *attribution* (RunConfig.heartbeat -> the monitor's
    per-slice EMAs -> an attributed eviction) do real work here? Per-slice
    heartbeats need >= 2 data slices to compare against each other, and the
    attributed shrink only resolves to shrink_mesh(drop_process_index=...)
    when each data slice is wholly owned by one process — on a
    single-process host the eviction still drops the attributed *grid*
    slice, and the bounded-staleness fallback (RunConfig.max_staleness +
    --stale-on-jitter) is available regardless."""
    import jax
    from repro.launch.mesh import make_mesh, slice_for_process
    n = jax.device_count()
    hosts = jax.process_count()
    per_process_slices = None
    if hosts > 1 and n % hosts == 0:
        # would every process map to one whole data slice on the natural
        # (hosts, n // hosts) mesh? (the drop_process_index fast path)
        mesh = make_mesh((hosts, n // hosts), ("data", "model"))
        owned = [slice_for_process(mesh, p) for p in range(hosts)]
        per_process_slices = all(s is not None for s in owned)
    return {
        "heartbeats_comparable": n >= 2,      # >= 2 slices to EMA against
        "process_eviction": bool(per_process_slices),
        "grid_eviction": n >= 2,              # single-controller fallback
        "probation_readmit": n >= 2,          # grow needs a slice to return
        "stale_fallback": True,               # plan-level, mesh-independent
    }


def _host_topology() -> dict:
    """Detected host topology — the H and L of the two-level exchange
    schedule (core/cost_model.py). Hierarchical pricing additionally needs
    fitted inter-host α/β constants (tools/profile_collectives.py fit →
    RunConfig.hw_profile); the default roofline HW is single-tier."""
    import jax
    from repro.utils.roofline import HW
    per_host: dict[int, int] = {}
    for d in jax.devices():
        p = getattr(d, "process_index", 0)
        per_host[p] = per_host.get(p, 0) + 1
    sizes = sorted(set(per_host.values()))
    return {
        "hosts": len(per_host),
        "local_devices_per_host": sizes,
        "uniform": len(sizes) <= 1,
        "hierarchical_hw": HW.hierarchical,
    }


def _probe_pallas() -> dict:
    """Can RunConfig.embed_impl='pallas' serve the sparse hot path here?
    Off-TPU the kernels run in interpret mode — available but slow."""
    import jax
    try:
        import numpy as np
        from repro.kernels import ops
        out = ops.embed_gather(np.zeros((8, 4), np.float32),
                               np.zeros((4,), np.int32))
        return {"available": bool(np.asarray(out).shape == (4, 4)),
                "interpret_mode": jax.default_backend() != "tpu"}
    except Exception as e:  # pallas import / lowering failure
        return {"available": False, "error": f"{type(e).__name__}: {e}"}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="machine-readable single-line report")
    args = ap.parse_args()
    report = collect()
    if args.json:
        print(json.dumps(report))
        return 0 if report["ok"] else 1

    from repro.compat import MIN_SUPPORTED
    j = report["jax"]
    print(f"python {report['python']}  jax {j['jax_version']}  "
          f"jaxlib {report['jaxlib']}  backend={report['backend']}  "
          f"devices={report['device_count']} "
          f"(local={report['local_device_count']}, "
          f"hosts={report['process_count']})")
    print(f"compat: explicit_sharding={j['explicit_sharding']}  "
          f"axis_types={j['axis_types']}  set_mesh={j['set_mesh']}  "
          f"top_level_shard_map={j['top_level_shard_map']}  "
          f"supported(>= {'.'.join(map(str, MIN_SUPPORTED))})"
          f"={j['supported']}")
    missing = [k for k, v in report["optional_deps"].items() if not v]
    present = [k for k, v in report["optional_deps"].items() if v]
    print("optional deps: "
          + "  ".join([f"{k}=yes" for k in present]
                      + [f"{k}=no (tests fall back to tests/_prop.py shim)"
                         for k in missing]))
    pal = report["embed_impl_pallas"]
    if pal.get("available"):
        mode = "interpret mode (off-TPU)" if pal.get("interpret_mode") \
            else "compiled (TPU)"
        print(f"embed_impl=pallas: available, {mode}")
    else:
        print("embed_impl=pallas: UNAVAILABLE "
              f"({pal.get('error', 'unknown')}) — use embed_impl=jnp")
    at = report["kernel_autotune"]
    print(f"kernel autotune: cache {at['state']} "
          f"({at['entries']} entries, {at['backend_entries']} for this "
          f"backend) at {at['path']}  "
          f"measurement={'allowed' if at['measurement_allowed'] else 'OFF'}")
    fa = report["fused_apply"]
    if fa["eligible"]:
        print(f"fused apply: eligible (optimizer={fa['optimizer']}; "
              f"needs {fa['requires']})")
    else:
        print("fused apply: NOT eligible — " + "; ".join(fa["blockers"]))
    topo = report["topology"]
    tier = "fitted (two-level pricing active on multi-host meshes)" \
        if topo["hierarchical_hw"] else \
        "unset — run tools/profile_collectives.py fit for two-level pricing"
    print(f"topology: hosts={topo['hosts']} "
          f"local_devices={topo['local_devices_per_host']} "
          f"uniform={topo['uniform']}  inter α/β: {tier}")
    rm = report["remesh"]
    print(f"elastic remesh: data axis can shrink="
          f"{rm['can_shrink_data_axis']} "
          f"(devices={rm['devices']}, hosts={rm['hosts']}; "
          f"remesh_on_straggle drops one data slice per escalation)")
    at = report["attribution"]
    evict = "by process" if at["process_eviction"] else \
        "by grid slice" if at["grid_eviction"] else "n/a (1 device)"
    print(f"straggler attribution: heartbeats comparable="
          f"{at['heartbeats_comparable']}  eviction resolves {evict}  "
          f"probation/readmit={at['probation_readmit']}  "
          f"stale fallback=always (plan-level)")
    an = report["analysis"]
    if an.get("available"):
        status = "clean" if an["clean"] else \
            f"{an['lint_findings']} finding(s) {an['kinds']}"
        print(f"static analysis: spmd lint {status}; plan-contract checker "
              "available (RunConfig.verify_contract, tools/spmd_lint.py)")
    else:
        print("static analysis: UNAVAILABLE "
              f"({an.get('error', 'unknown')})")
    sv = report["serving"]
    print(f"serving: paged engine for {'/'.join(sv['paged_families'])} "
          f"({sv['prefill_executables']} prefill buckets "
          f"{sv['prefill_buckets']} at max_seq={sv['max_seq']}), "
          f"sampling={sv['sampling']}, detokenize thread="
          f"{'on' if sv['detokenize_thread'] else 'off'}; "
          f"{'/'.join(sv['toy_fallback_families'])} -> ToyServer")
    print("PASS" if report["ok"] else
          "WARN: JAX older than the supported range — tier-1 results are "
          "not meaningful")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
