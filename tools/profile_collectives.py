"""Dump the largest collectives (with op_name provenance) for one dry-run cell."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys, argparse, collections
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RunConfig, SHAPES, get_config
from repro.launch.dryrun import lower_cell
from repro.utils.hlo import parse_module, _multipliers, _shape_bytes, _COLLECTIVE_KINDS

ap = argparse.ArgumentParser()
ap.add_argument("--arch", required=True)
ap.add_argument("--shape", required=True)
ap.add_argument("--multi-pod", action="store_true")
ap.add_argument("--remat", default="block")
ap.add_argument("--comm-mode", default="hybrid")
ap.add_argument("--top", type=int, default=25)
args = ap.parse_args()

compiled, rt, plan, model = lower_cell(
    args.arch, args.shape, multi_pod=args.multi_pod,
    run_cfg=RunConfig(comm_mode=args.comm_mode, capacity_mode="capped",
                      remat=args.remat))
text = compiled.as_text()
comps, entry, _ = parse_module(text)
mult, _ = _multipliers(comps, entry)
rows = []
for cname, comp in comps.items():
    m = mult.get(cname, 0.0)
    if not m: continue
    for op in comp.ops:
        kind = next((c for c in _COLLECTIVE_KINDS
                     if op.kind in (c, c + "-start")), None)
        if kind is None: continue
        b = _shape_bytes(op.type_str) * m
        mm = re.search(r'op_name="([^"]+)"', op.line)
        src = mm.group(1) if mm else "?"
        src = re.sub(r'jit\(\w+\)/', '', src)[:140]
        rows.append((b, m, kind, op.type_str[:48], src))
rows.sort(reverse=True)
agg = collections.defaultdict(float)
for b, m, kind, t, src in rows:
    agg[kind] += b
print({k: f"{v/1e9:.1f}GB" for k, v in agg.items()})
for b, m, kind, t, src in rows[:args.top]:
    print(f"{b/1e9:8.2f}GB x{int(m):4d} {kind:18s} {t:48s} {src}")
