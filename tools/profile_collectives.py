#!/usr/bin/env python
"""Collective profiling: dump a cell's largest collectives, or fit the
planner's α/β link constants from measured ring times.

dump — the largest collectives (with op_name provenance) in one dry-run
cell's post-SPMD HLO:

    PYTHONPATH=src python tools/profile_collectives.py dump \
        --arch seamless-m4t-medium --shape train_4k

fit — time flat psums over a device mesh at several buffer sizes, fit the
α + β·b line per link tier by least squares, and emit ``hw_profile.json``
— the file ``RunConfig.hw_profile`` / ``launch/train.py --hw-profile``
feeds back into ``core/cost_model.resolve_hw``, so the planner's argmin
and the two-level-schedule choice run on measured constants instead of
the roofline defaults:

    PYTHONPATH=src python tools/profile_collectives.py fit \
        --devices 8 --hosts 2 -o hw_profile.json

With ``--hosts H > 1`` the device mesh gets a leading "pod" axis (the
layout launch/mesh.make_production_mesh uses): psums over the intra axis
fit (α₁, β₁) = ``link_latency``/``link_bw`` and psums over the pod axis
fit (α₂, β₂) = ``inter_latency``/``inter_bw``. On a real multi-host world
the pod axis crosses actual inter-host links and the fit measures them;
on one process the "hosts" are simulated groups — physically meaningless
timings, but a structurally valid profile for exercising the two-level
machinery end to end.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _build_parser():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd")
    dp = sub.add_parser("dump", help="largest collectives of one dry-run cell")
    dp.add_argument("--arch", required=True)
    dp.add_argument("--shape", required=True)
    dp.add_argument("--multi-pod", action="store_true")
    dp.add_argument("--remat", default="block")
    dp.add_argument("--comm-mode", default="hybrid")
    dp.add_argument("--top", type=int, default=25)
    fp = sub.add_parser("fit", help="fit α/β link constants, emit a profile")
    fp.add_argument("--devices", type=int, default=8,
                    help="total devices (fake CPU devices off-accelerator)")
    fp.add_argument("--hosts", type=int, default=1,
                    help="host groups; > 1 adds a pod axis and fits the "
                         "inter tier")
    fp.add_argument("--sizes", type=int, nargs="+",
                    default=[1 << 12, 1 << 16, 1 << 20, 1 << 23],
                    help="buffer sizes (bytes) to time")
    fp.add_argument("--iters", type=int, default=10,
                    help="timed repetitions per size (min is kept)")
    fp.add_argument("-o", "--out", default="hw_profile.json")
    return ap


def cmd_dump(args) -> int:
    import collections
    import re

    from repro.configs import RunConfig
    from repro.launch.dryrun import lower_cell
    from repro.utils.hlo import (_COLLECTIVE_KINDS, _multipliers,
                                 _shape_bytes, parse_module)

    compiled, rt, plan, model = lower_cell(
        args.arch, args.shape, multi_pod=args.multi_pod,
        run_cfg=RunConfig(comm_mode=args.comm_mode, capacity_mode="capped",
                          remat=args.remat))
    text = compiled.as_text()
    comps, entry, _ = parse_module(text)
    mult, _ = _multipliers(comps, entry)
    rows = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if not m:
            continue
        for op in comp.ops:
            kind = next((c for c in _COLLECTIVE_KINDS
                         if op.kind in (c, c + "-start")), None)
            if kind is None:
                continue
            b = _shape_bytes(op.type_str) * m
            mm = re.search(r'op_name="([^"]+)"', op.line)
            src = mm.group(1) if mm else "?"
            src = re.sub(r'jit\(\w+\)/', '', src)[:140]
            rows.append((b, m, kind, op.type_str[:48], src))
    rows.sort(reverse=True)
    agg = collections.defaultdict(float)
    for b, m, kind, t, src in rows:
        agg[kind] += b
    print({k: f"{v/1e9:.1f}GB" for k, v in agg.items()})
    for b, m, kind, t, src in rows[:args.top]:
        print(f"{b/1e9:8.2f}GB x{int(m):4d} {kind:18s} {t:48s} {src}")
    return 0


def _fit_line(xs, ys):
    """Least squares t = α + b/β over (wire bytes, seconds) samples.
    Returns (alpha seconds, beta bytes/s)."""
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    slope = sxy / sxx if sxx else 0.0
    alpha = my - slope * mx
    return max(alpha, 0.0), (1.0 / slope if slope > 0 else float("inf"))


def _time_psum(mesh, axes, nbytes, iters):
    """Min wall time of one jitted psum of an nbytes f32 buffer over the
    given mesh axes (warm cache; min-of-iters rejects scheduler noise)."""
    import jax
    import jax.numpy as jnp

    from repro.compat import P, shard_map

    n = max(nbytes // 4, 1)
    fn = jax.jit(shard_map(lambda x: jax.lax.psum(x, axes), mesh=mesh,
                           in_specs=P(), out_specs=P(), check_vma=False))
    x = jnp.ones((n,), jnp.float32)
    fn(x).block_until_ready()                       # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def cmd_fit(args) -> int:
    import jax

    from repro.compat import make_mesh

    ndev = args.devices
    hosts = max(args.hosts, 1)
    if ndev % hosts:
        print(f"devices={ndev} not divisible by hosts={hosts}",
              file=sys.stderr)
        return 2
    if jax.device_count() < ndev:
        print(f"need {ndev} devices, have {jax.device_count()} "
              "(re-run with fewer --devices)", file=sys.stderr)
        return 2
    local = ndev // hosts
    mesh = make_mesh((hosts, local), ("pod", "data"))

    def ring(n, b):                 # per-chip ring all-reduce wire bytes
        return 2.0 * (n - 1) / n * b if n > 1 else 0.0

    tiers = {"intra": (("data",), local)}
    if hosts > 1:
        tiers["inter"] = (("pod",), hosts)
    prof: dict = {"devices": ndev, "hosts": hosts, "samples": {}}
    for tier, (axes, n) in tiers.items():
        xs, ys = [], []
        for size in args.sizes:
            t = _time_psum(mesh, axes, size, args.iters)
            xs.append(ring(n, size))
            ys.append(t)
            prof["samples"][f"{tier}_{size}"] = t
        alpha, beta = _fit_line(xs, ys)
        if tier == "intra":
            prof["link_latency"], prof["link_bw"] = alpha, beta
        else:
            prof["inter_latency"], prof["inter_bw"] = alpha, beta
    with open(args.out, "w") as f:
        json.dump(prof, f, indent=1)
    print(f"wrote {args.out}:")
    print(f"  intra: alpha={prof['link_latency']:.3e}s "
          f"beta={prof['link_bw']:.3e}B/s")
    if hosts > 1:
        print(f"  inter: alpha={prof['inter_latency']:.3e}s "
              f"beta={prof['inter_bw']:.3e}B/s")
    print("use via RunConfig(hw_profile=...) or "
          "launch/train.py --hw-profile")
    return 0


def main() -> int:
    ap = _build_parser()
    args = ap.parse_args()
    if args.cmd is None:
        ap.print_help()
        return 2
    if args.cmd == "fit":
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.devices}")
    else:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    return cmd_dump(args) if args.cmd == "dump" else cmd_fit(args)


if __name__ == "__main__":
    raise SystemExit(main())
