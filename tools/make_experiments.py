"""Assemble EXPERIMENTS.md from results/dryrun*, results/perf and the
hand-maintained §Perf log (tools/perf_log.md)."""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline_table import markdown as roofline_md  # noqa: E402

HEADER = """# EXPERIMENTS

Hardware model: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s ICI per
chip, 16 GB HBM. Meshes: 16x16 (`data`,`model`; 256 chips) and 2x16x16
(`pod`,`data`,`model`; 512 chips). This container is CPU-only: every number
below is *static analysis of the compiled (post-SPMD) HLO*, not wall time.

## Measurement conventions & caveats (§Dry-run)

* **compile**: `jax.jit(step).lower(...).compile()` with 512 fake host
  devices; success for every (arch × shape × mesh) cell is deliverable (e).
* **FLOPs**: XLA's `cost_analysis()` counts while-loop bodies once
  (verified), so we parse the HLO call graph and multiply by
  `known_trip_count` (utils/hlo.py; exact on scan fixtures —
  tests/test_hlo_parser.py). Dot FLOPs include remat recompute and padding
  waste (that is the point: `MODEL_FLOPS/HLO_FLOPs` exposes them).
* **collective bytes**: per-chip, ring-cost scaled (AR 2(N-1)/N, AG/RS/A2A
  (N-1)/N), trip-count corrected. The CPU backend widens bf16 arithmetic to
  f32, so collectives that ride bf16 on TPU appear f32 here; we count them
  at 2 bytes/elem when OPSW is on (`f32_collective_scale=0.5`). The CPU SPMD
  partitioner also lacks the AR→RS fusion pass — the paper-faithful BASELINE
  therefore overstates TP-boundary traffic vs a real TPU lowering; the
  explicit-SP §Perf iteration removes that dependence (its collectives are
  bf16 RS/AG by construction).
* **memory term**: analytic streaming-traffic model (utils/traffic.py) —
  the CPU HLO materializes buffers a TPU Pallas kernel keeps in VMEM; the
  raw HLO byte proxy is recorded in each JSON as a diagnostic.
* **peak bytes/chip**: XLA buffer assignment on CPU; f32 widening roughly
  doubles temp buffers vs the TPU bf16 lowering (TPU estimate ≈ args +
  temps/2).
* **roofline fraction** = (MODEL_FLOPS/(chips·peak)) / max(compute, memory,
  collective) — useful-compute MFU at the modeled bound. Decode shapes are
  bandwidth-bound by nature; their fraction is small by construction and
  the interesting number is the memory term itself.
* `long_500k` cells run only for rwkv6-7b and hymba-1.5b (sub-quadratic);
  the eight pure-full-attention archs skip them (DESIGN.md §4):
  a 500k dense KV cache is architecturally infeasible (e.g. mistral-large:
  ≈236 GB per sequence).
* Sparse-exchange buffers are capacity-bounded (`capped`, cf=1.0 on
  E[unique]); training examples/tests default to `exact` (never drops).

"""


def section(title, body):
    return f"\n## {title}\n\n{body}\n"


def perf_files(tag_dir="results/perf"):
    out = {}
    for f in sorted(glob.glob(os.path.join(tag_dir, "*.json"))):
        d = json.load(open(f))
        if d.get("ok"):
            out[os.path.basename(f)] = d
    return out


def fmt_cell(d):
    r = d["roofline"]
    return (f"compute {r['compute_s']:.2f}s / memory {r['memory_s']:.2f}s / "
            f"collective {r['collective_s']:.2f}s → dominant "
            f"{r['dominant']}, roofline {r['roofline_fraction']:.3f}")


def main():
    parts = [HEADER]

    parts.append(section(
        "§Dry-run + §Roofline — paper-faithful BASELINE "
        "(hybrid comm, capped capacity, full remat; GSPMD-auto sharding)",
        "Every cell below compiled successfully on both meshes "
        "(`results/dryrun/*.json` carries memory_analysis, cost_analysis, "
        "collective schedule and the plan).\n\n" + roofline_md()))

    opt_dir = os.path.join("results", "dryrun_opt")
    if os.path.isdir(opt_dir) and glob.glob(os.path.join(opt_dir, "*.json")):
        parts.append(section(
            "§Roofline — beyond-paper OPTIMIZED "
            "(explicit-SP collectives + auto dense strategy)",
            roofline_md(out_dir=opt_dir)))

    if os.path.exists("bench_output.txt"):
        lines = [l for l in open("bench_output.txt")
                 if "," in l and not l.startswith("roofline/")]
        if lines:
            parts.append(section(
                "Paper-table benchmarks (benchmarks/run.py CSV: "
                "name,us_per_call,derived)",
                "```\n" + "".join(lines) + "```\n"
                "Table 3 note: `ps` analytic == HLO-measured exactly; "
                "AllGatherv rows differ by the paper's send+receive vs "
                "one-way accounting convention (DESIGN.md §9.3)."))
    if os.path.exists("tools/perf_log.md"):
        parts.append(open("tools/perf_log.md").read())

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
