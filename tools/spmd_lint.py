#!/usr/bin/env python
"""Repo-wide SPMD hygiene lint (CI gate).

Runs the AST rules in ``repro.analysis.lint`` over ``src/``,
``benchmarks/`` and ``tools/`` (or explicit paths) and exits nonzero on
any finding. ``--json`` prints the findings as a JSON list for tooling.

    PYTHONPATH=src python tools/spmd_lint.py
    PYTHONPATH=src python tools/spmd_lint.py --json src/repro/core
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.lint import lint_paths, lint_repo  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src benchmarks tools)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    args = ap.parse_args(argv)

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    if args.paths:
        findings = lint_paths(args.paths, root)
    else:
        findings = lint_repo(root)

    if args.as_json:
        print(json.dumps([f.to_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(str(f))
        print(f"spmd_lint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
